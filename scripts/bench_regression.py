#!/usr/bin/env python3
"""Bench-regression check (advisory by default, hard-fail opt-in).

Compares fresh BENCH_*.json files (written by the in-crate bench harness,
rust/src/bench.rs) against the committed baseline under
benchmarks/baseline/. The primary metric is GFLOP/s (higher is better);
benches without a flop count fall back to mean_ms (lower is better).

Regressions beyond the threshold emit GitHub Actions `::warning::`
annotations and the script exits 0 — advisory, because CI runners are too
noisy for a blanket hard perf gate. Files named via `--hard-fail BASENAME`
(repeatable) opt into enforcement: their regressions emit `::error::` and
the script exits 1. An empty or missing baseline for a hard-fail file
produces no comparisons, so the gate stays dormant until a trusted
baseline is committed.

Refreshing the baseline: download the bench artifacts from a trusted CI
run and commit them into benchmarks/baseline/ (same file names), or run
the benches locally/on CI and pass --update-baseline to copy the fresh
JSON files into the baseline directory in one step (then commit).
"""

import argparse
import json
import os
import shutil
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"::notice::could not read {path}: {e}")
        return None


def result_map(doc):
    return {r.get("name"): r for r in doc.get("results", [])}


def compare(base, fresh, threshold):
    """Yield (name, metric, base_val, new_val, rel_change) for regressions."""
    bmap = result_map(base)
    for r in fresh.get("results", []):
        name = r.get("name")
        b = bmap.get(name)
        if b is None:
            print(f"  new benchmark (no baseline): {name}")
            continue
        if r.get("gflops") is not None and b.get("gflops") is not None:
            new_v, base_v, metric, higher_better = (
                r["gflops"], b["gflops"], "GFLOP/s", True)
        elif r.get("mean_ms") is not None and b.get("mean_ms") is not None:
            new_v, base_v, metric, higher_better = (
                r["mean_ms"], b["mean_ms"], "mean_ms", False)
        else:
            continue
        if base_v <= 0:
            continue
        # relative regression, positive = worse
        rel = (base_v - new_v) / base_v if higher_better else (new_v - base_v) / base_v
        status = "REGRESSION" if rel > threshold else "ok"
        print(f"  {name}: {metric} {base_v:.3f} -> {new_v:.3f} "
              f"({-rel * 100.0:+.1f}%) {status}")
        if rel > threshold:
            yield name, metric, base_v, new_v, rel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline",
                    help="directory with committed BENCH_*.json baselines")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression that triggers a warning")
    ap.add_argument("--update-baseline", action="store_true",
                    help="after diffing, copy each fresh JSON over the "
                         "committed baseline (commit the result to arm "
                         "future diffs)")
    ap.add_argument("--hard-fail", action="append", default=[],
                    metavar="BASENAME",
                    help="fresh-file basename whose regressions fail the "
                         "gate (exit 1) instead of warning; repeatable")
    ap.add_argument("fresh", nargs="+", help="fresh BENCH_*.json files")
    args = ap.parse_args()

    warned = 0
    failed = 0
    for path in args.fresh:
        name = os.path.basename(path)
        hard = name in args.hard_fail
        print(f"== {name}{' [hard-fail]' if hard else ''}")
        fresh = load(path)
        if fresh is None:
            continue
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            print(f"::notice::no committed baseline for {name}; "
                  f"commit the CI artifact to benchmarks/baseline/ to enable the diff")
            continue
        base = load(base_path)
        if base is None:
            continue
        for bench, metric, bv, nv, rel in compare(base, fresh, args.threshold):
            level = "error" if hard else "warning"
            if hard:
                failed += 1
            else:
                warned += 1
            print(f"::{level} title=bench regression::{name}:{bench} {metric} "
                  f"regressed {rel * 100.0:.1f}% (baseline {bv:.3f}, now {nv:.3f})")

    if args.update_baseline:
        os.makedirs(args.baseline, exist_ok=True)
        for path in args.fresh:
            if load(path) is None:
                continue  # never overwrite a baseline with unreadable data
            dst = os.path.join(args.baseline, os.path.basename(path))
            shutil.copyfile(path, dst)
            print(f"baseline updated: {dst}")

    if failed:
        print(f"\n{failed} hard-fail regression(s); failing the gate.")
        return 1
    if warned:
        print(f"\n{warned} advisory regression warning(s); not failing the gate.")
    else:
        print("\nno regressions beyond threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
