#!/usr/bin/env python3
"""Promote trusted CI bench artifacts into the committed baseline.

Given one or more downloaded CI artifact directories (or individual
BENCH_*.json files), validate each bench JSON and copy it into
benchmarks/baseline/ under its own basename. This is the supported way to
arm (or refresh) the regression gate described in
benchmarks/baseline/README.md: download the bench artifacts from a
trusted run on main, point this script at the download directory, review
the printed diff, and commit the result.

Validation is deliberately strict — a malformed file silently committed
as baseline would disarm the hard-fail gate for that bench forever:

  * the file must parse as JSON with a top-level {"results": [...]}
  * every result needs a "name" and a positive "mean_ms"
  * by default the basename must already exist in the baseline directory
    (pass --allow-new to promote a brand-new bench file)
  * an artifact with an EMPTY results list is refused unless --allow-empty
    (promoting an empty file would silently disarm the gate)

Exit status: 0 if every requested file promoted, 1 otherwise. With
--dry-run nothing is written; the exit status still reflects validation.
"""

import argparse
import json
import os
import shutil
import sys


def find_bench_jsons(paths):
    """Expand files/directories into BENCH_*.json paths (dirs recurse)."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.startswith("BENCH_") and f.endswith(".json"):
                        out.append(os.path.join(root, f))
        else:
            out.append(p)
    return out


def validate(path, allow_empty):
    """Return (doc, error): doc is the parsed JSON on success, else None."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return None, f"unreadable: {e}"
    results = doc.get("results")
    if not isinstance(results, list):
        return None, 'missing top-level {"results": [...]}'
    if not results and not allow_empty:
        return None, "empty results list (would disarm the gate); " \
                     "pass --allow-empty to promote anyway"
    for i, r in enumerate(results):
        if not isinstance(r, dict) or not r.get("name"):
            return None, f"result {i} has no name"
        mean = r.get("mean_ms")
        if not isinstance(mean, (int, float)) or mean <= 0:
            return None, f'result {r.get("name")!r} has no positive mean_ms'
    return doc, None


def main():
    ap = argparse.ArgumentParser(
        description="copy trusted CI bench artifacts into the committed "
                    "baseline directory")
    ap.add_argument("sources", nargs="+",
                    help="artifact directories (searched recursively for "
                         "BENCH_*.json) and/or individual files")
    ap.add_argument("--baseline", default="benchmarks/baseline",
                    help="committed baseline directory (default: "
                         "benchmarks/baseline)")
    ap.add_argument("--allow-new", action="store_true",
                    help="permit basenames with no existing baseline file")
    ap.add_argument("--allow-empty", action="store_true",
                    help="permit artifacts with an empty results list")
    ap.add_argument("--dry-run", action="store_true",
                    help="validate and report, write nothing")
    args = ap.parse_args()

    files = find_bench_jsons(args.sources)
    if not files:
        print("error: no BENCH_*.json files found in the given sources",
              file=sys.stderr)
        return 1

    failed = 0
    promoted = 0
    seen = {}
    for path in files:
        name = os.path.basename(path)
        if name in seen:
            print(f"error: {name} appears twice ({seen[name]} and {path}); "
                  f"pass an unambiguous set", file=sys.stderr)
            failed += 1
            continue
        seen[name] = path
        doc, err = validate(path, args.allow_empty)
        if err:
            print(f"error: {path}: {err}", file=sys.stderr)
            failed += 1
            continue
        dst = os.path.join(args.baseline, name)
        if not os.path.exists(dst) and not args.allow_new:
            print(f"error: {name} has no existing baseline at {dst}; "
                  f"pass --allow-new if this bench is genuinely new",
                  file=sys.stderr)
            failed += 1
            continue
        n = len(doc.get("results", []))
        verb = "would promote" if args.dry_run else "promoted"
        if not args.dry_run:
            os.makedirs(args.baseline, exist_ok=True)
            shutil.copyfile(path, dst)
        print(f"{verb}: {path} -> {dst} ({n} result(s))")
        promoted += 1

    print(f"\n{promoted} file(s) {'validated' if args.dry_run else 'promoted'}, "
          f"{failed} rejected.")
    return 1 if failed or not promoted else 0


if __name__ == "__main__":
    sys.exit(main())
