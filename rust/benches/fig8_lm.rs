//! Fig 8 / Table 5: GPT-2 language-model training-step throughput, dense
//! vs Pixelfly vs BigBird, on the PJRT engine; plus params/FLOPs columns.

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::{TrainConfig, Trainer};
use pixelfly::runtime::{artifacts_dir, Engine};
use pixelfly::util::Rng;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.rtxt").exists() {
        println!("fig8_lm: artifacts not built, skipping");
        return;
    }
    let mut suite = BenchSuite::new("fig8_lm");
    let presets = ["gpt2_s_dense", "gpt2_s_pixelfly", "gpt2_s_bigbird"];
    let mut rows = Vec::new();
    for preset in presets {
        let mut engine = Engine::new(&dir).unwrap();
        let cfg = TrainConfig {
            preset: preset.into(),
            steps: 1,
            eval_batches: 0,
            ..Default::default()
        };
        let mut trainer = match Trainer::new(&mut engine, cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("skip {preset}: {e}");
                continue;
            }
        };
        let mut rng = Rng::new(0);
        trainer.step_once(&mut rng).unwrap();
        suite.bench(preset, "train step", || {
            trainer.step_once(&mut rng).unwrap();
        });
        let key = format!("{preset}.train_step");
        let a = trainer.engine.manifest.artifact(&key).unwrap();
        rows.push((preset, suite.last_mean_ms(), a.param_count, a.flops_fwd,
                   a.batch * a.cfg::<usize>("seq_len").unwrap_or(1)));
    }
    suite.report();

    println!("\n=== Table 5 (scaled): params/FLOPs/tokens-per-sec ===");
    println!("{:<20} {:>10} {:>12} {:>10} {:>12} {:>9}",
             "model", "params", "fwd FLOPs", "step(ms)", "tokens/s", "speedup");
    let base = rows.first().map(|(_, ms, ..)| *ms);
    for (p, ms, params, flops, toks) in &rows {
        let sp = base.map(|b| b / ms).unwrap_or(f64::NAN);
        println!("{p:<20} {params:>10} {flops:>12} {ms:>10.1} {:>12.0} {sp:>8.2}x",
                 *toks as f64 / (ms / 1e3));
    }
    println!("(paper: Pixelfly-GPT2 68M vs 117M params, 18.5G vs 48.4G FLOPs, 2.1x)");
}
