//! Checkpoint-I/O bench (PR 7): the crash-safety layer's three costs,
//! measured on gpt2-s.
//!
//! * `snapshot_write`   — one synchronous atomic checkpoint (encode + tmp
//!   + fsync + rename + dir fsync); the GFLOP/s column reads as GB/s
//! * `load_restore`     — parse + CRC-verify + copy every tensor into a
//!   live model (the `train --resume` cost); GB/s likewise
//! * `load_to_first_token` — cold `serve --weights` warm start: compile,
//!   load, freeze into decode, one token
//! * `train_step_*`     — hot step time with and without a background
//!   [`Snapshotter`] riding the loop. Hard assert: the overhead stays
//!   under 5% in full mode (the bench is the acceptance test for
//!   "snapshots never block a step"); quick CI mode gets a loose 50%
//!   noise guard and always prints the number.

use std::time::Instant;

use pixelfly::bench::{BenchResult, BenchSuite};
use pixelfly::ckpt::{writer, Snapshot, Snapshotter};
use pixelfly::coordinator::budget::rule_of_thumb;
use pixelfly::costmodel::Device;
use pixelfly::models::preset;
use pixelfly::nn::{compile, Model};
use pixelfly::sparse::exec;
use pixelfly::sparse::Matrix;
use pixelfly::util::stats::Summary;

const BLOCK: usize = 16;
const SEED: u64 = 42;
const LR: f32 = 0.02;
const MOM: f32 = 0.9;

fn compile_gpt2s() -> Model {
    let schema = preset("gpt2-s", 1).expect("gpt2-s preset");
    let dev = Device::with_block(BLOCK);
    let alloc = rule_of_thumb(&schema, 0.2, &dev);
    compile(&schema, &alloc, BLOCK, SEED).expect("compile gpt2-s")
}

fn main() {
    let mut suite = BenchSuite::new("checkpoint_io");
    let threads = exec::threads();
    let dir = std::env::temp_dir().join("pxck-bench-checkpoint-io");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench temp dir");

    let mut model = compile_gpt2s();
    model.train(2, LR, MOM, SEED); // real momentum, not all-zero pages

    // ---- snapshot write bandwidth --------------------------------------
    let mut snap = Snapshot::new();
    model.snapshot_into(&mut snap, 1, "bench");
    let bytes = snap.encode().len();
    let mib = bytes as f64 / (1 << 20) as f64;
    let path = dir.join(writer::step_filename(1));
    let note = format!("{mib:.1} MiB ckpt, atomic tmp+fsync+rename, threads={threads}");
    suite.bench_with_flops("snapshot_write", &note, bytes as f64, || {
        model.save_checkpoint(&path, 1, "bench").expect("save");
    });
    let write_ms = suite.last_mean_ms();

    // ---- load + restore into a live model (train --resume) -------------
    let note = format!("{mib:.1} MiB ckpt, parse + CRC + tensor copy-in");
    suite.bench_with_flops("load_restore", &note, bytes as f64, || {
        model.load_checkpoint(&path).expect("load");
    });
    let load_ms = suite.last_mean_ms();

    // ---- load-to-first-token (serve --weights warm start) --------------
    let samples = if suite.quick { 2 } else { 5 };
    let mut ns: Vec<f64> = Vec::new();
    let mut sink = 0.0f32;
    for _ in 0..samples {
        let t0 = Instant::now();
        let mut m = compile_gpt2s();
        m.load_checkpoint(&path).expect("warm-start load");
        let mut sess = m.into_decode(1).expect("gpt2-s decodes");
        let x = Matrix::zeros(1, sess.in_dim());
        let y = sess.step(&x, &[0], &[0]).expect("first token");
        sink += y.row(0)[0];
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    std::hint::black_box(sink);
    let first_token = Summary::from_ns(&mut ns);
    let first_token_ms = first_token.mean_ms();
    suite.results.push(BenchResult {
        name: "load_to_first_token".into(),
        summary: first_token,
        gflops: None,
        scratch_bytes: None,
        phases: None,
        note: "compile + load + freeze + 1 decode step (serve --weights)".into(),
    });

    // ---- snapshot overhead on the training loop ------------------------
    // Same seed, same batch, same step count: the only difference between
    // the two runs is the Snapshotter offer (one param memcpy) every
    // other step plus the background writer competing for the disk.
    let steps = if suite.quick { 8 } else { 24 };
    let every = 2;
    let mut base = compile_gpt2s();
    let rep0 = base.train(steps, LR, MOM, SEED);
    let t_base = rep0.step_time.clone().expect("step timing");

    let snapdir = dir.join("snaps");
    let mut with_snaps = compile_gpt2s();
    let snapper = Snapshotter::start(&snapdir, 2).expect("snapshotter");
    let rep1 = with_snaps.train_resumable(steps, LR, MOM, SEED, 0,
                                          Some((&snapper, every, "bench")));
    let srep = snapper.finish();
    assert!(srep.errors.is_empty(), "snapshot errors: {:?}", srep.errors);
    let t_snap = rep1.step_time.clone().expect("step timing");
    let overhead = (t_snap.mean_ns - t_base.mean_ns) / t_base.mean_ns * 100.0;

    suite.results.push(BenchResult {
        name: "train_step_no_snapshot".into(),
        summary: t_base.clone(),
        gflops: None,
        scratch_bytes: None,
        phases: None,
        note: format!("{steps} steps, gpt2-s"),
    });
    suite.results.push(BenchResult {
        name: "train_step_with_snapshots".into(),
        summary: t_snap.clone(),
        gflops: None,
        scratch_bytes: None,
        phases: None,
        note: format!("every {every} steps -> {} written, {} superseded; \
                       overhead {overhead:+.2}%", srep.written, srep.dropped),
    });
    println!("snapshot overhead: base {:.2}ms/step, with snapshots {:.2}ms/step \
              -> {overhead:+.2}% ({} written, {} superseded)",
             t_base.mean_ms(), t_snap.mean_ms(), srep.written, srep.dropped);
    // Quick mode runs too few steps for a tight bound on shared CI boxes;
    // full mode enforces the acceptance criterion.
    let cap = if suite.quick { 50.0 } else { 5.0 };
    assert!(overhead < cap,
            "background snapshots must not slow the training step \
             (overhead {overhead:+.2}% >= {cap}% cap)");

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("\ncheckpoint contract: {mib:.1} MiB snapshot writes in \
              {write_ms:.2}ms, restores in {load_ms:.2}ms, serve warm start \
              to first token {first_token_ms:.1}ms, snapshot overhead \
              {overhead:+.2}%/step.");
}
