//! Fig 6: RigL vs Pixelfly training-cost comparison.
//!
//! RigL's dynamic mask needs (a) a dense gradient pass on update steps and
//! (b) a mask/kernel rebuild after each update; Pixelfly's mask is static.
//! We measure both on the Rust substrate at matched density: per-step
//! sparse GEMM latency, the amortized RigL overhead, and the block-cover
//! inflation of RigL's unstructured-at-block-level mask.

use pixelfly::bench::BenchSuite;
use pixelfly::costmodel::Device;
use pixelfly::patterns::flat_butterfly_mask;
use pixelfly::rigl::{init_random, rigl_step_cost, RigLConfig};
use pixelfly::sparse::{dense::matmul_blocked_into, BsrMatrix, Matrix};
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 128);
    let block = 32;
    let nb = n / block;
    let mut suite = BenchSuite::new("fig6_rigl");
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);

    // matched density: pixelfly stride-4 vs RigL random at same block count
    let pix_mask = flat_butterfly_mask(nb, 4);
    let density = pix_mask.density();
    let mut rigl = init_random(nb, nb, density, 1);

    let pix = BsrMatrix::random(&pix_mask, block, 0.1, &mut Rng::new(2));
    let mut y = Matrix::zeros(batch, n);
    suite.bench("pixelfly_step", &format!("density={density:.3} static mask"), || {
        pix.matmul_into(&x, &mut y);
        std::hint::black_box(&y);
    });
    let t_pix = suite.last_mean_ms();

    // RigL steady-state: sparse fwd + periodic (dense grad + mask rebuild)
    let w_dense = Matrix::randn(n, n, 0.1, &mut Rng::new(3));
    let grads = Matrix::randn(n, n, 0.1, &mut Rng::new(4));
    let cfg = RigLConfig { period: 100, alpha: 0.3, total_steps: 10_000 };
    let mut step = 0usize;
    suite.bench("rigl_step_amortized", "sparse fwd + dense grad every 100", || {
        let w = BsrMatrix::from_dense(&w_dense, &rigl.mask, block);
        w.matmul_into(&x, &mut y);
        if step % cfg.period == 0 {
            // dense gradient pass + mask update + kernel rebuild
            let mut g = Matrix::zeros(batch, n);
            matmul_blocked_into(&x, &grads, &mut g);
            rigl.update(&w_dense.data, &grads.data, n, n, step, &cfg);
        }
        step += 1;
        std::hint::black_box(&y);
    });
    let t_rigl = suite.last_mean_ms();

    // dense baseline
    suite.bench("dense_step", "", || {
        matmul_blocked_into(&x, &w_dense, &mut y);
        std::hint::black_box(&y);
    });
    let t_dense = suite.last_mean_ms();
    suite.report();

    println!("\n=== Fig 6 (shape check) ===");
    println!("pixelfly speedup vs dense: {:.2}x (paper: 2.1x)", t_dense / t_pix);
    println!("rigl     speedup vs dense: {:.2}x (paper: 0.8x — no speedup)",
             t_dense / t_rigl);

    // cost-model view with UNSTRUCTURED RigL (element-level), the paper's
    // actual baseline: its block cover is ~dense
    let dev = Device::with_block(32);
    let mut r2 = Rng::new(5);
    let unstructured =
        pixelfly::patterns::baselines::random_element_mask(n, density / 10.0, &mut r2);
    let c_unstr = pixelfly::costmodel::masked_gemm_cost(&unstructured, batch, &dev);
    let c_dense = pixelfly::costmodel::dense_gemm_cost(n, n, batch, &dev);
    println!("unstructured RigL cost-model speedup: {:.2}x (cover density {:.0}%)",
             c_dense.total / c_unstr.total,
             100.0 * unstructured.actual_density(32));
    assert!(t_dense / t_pix > t_dense / t_rigl,
            "pixelfly must out-speed RigL at matched density");
}
