//! Fig 11 / Appendix J: flat butterfly vs sequential butterfly product.
//!
//! Same O(n log k) FLOPs; the product form pays log2(k) full activation
//! passes.  The paper measures up to 3x on a V100; the shape (flat wins,
//! gap grows with stride) must hold on the Rust substrate too.

use pixelfly::bench::BenchSuite;
use pixelfly::sparse::butterfly_mm::ButterflyProduct;
use pixelfly::sparse::{Matrix, Workspace};
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 512); // paper: 2048 on V100
    let block = args.usize_or("block", 32);
    let mut suite = BenchSuite::new("fig11_flat_vs_product");
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);

    let nb = n / block;
    let mut speedups = Vec::new();
    let mut ws = Workspace::new();
    let mut k = 2;
    while k <= nb {
        let bp = ButterflyProduct::random(n, block, k, 0.1, &mut rng);
        let flat = bp.flatten();
        // in-place apply with workspace scratch: both sides of the
        // comparison are zero-alloc, so the measured gap is pure
        // scheduling/memory traffic (the paper's claim), not allocator
        // noise
        let mut y = x.clone();
        bp.apply_assign(&mut y, &mut ws); // warmup sizes the scratch
        let warm_allocs = ws.alloc_events();
        suite.bench(&format!("product_k{k}"), &format!("{} factors", bp.factors.len()), || {
            y.data.copy_from_slice(&x.data);
            bp.apply_assign(&mut y, &mut ws);
            std::hint::black_box(&y);
        });
        assert_eq!(ws.alloc_events(), warm_allocs,
                   "product apply must be zero-alloc after warmup");
        suite.set_scratch_bytes(ws.peak_bytes());
        let tp = suite.last_mean_ms();
        let mut y = Matrix::zeros(batch, n);
        suite.bench(&format!("flat_k{k}"), "1 sparse GEMM", || {
            flat.matmul_into(&x, &mut y);
            std::hint::black_box(&y);
        });
        let tf = suite.last_mean_ms();
        speedups.push((k, tp / tf));
        k *= 2;
    }
    suite.report();

    println!("\nflat-vs-product speedup by max stride (paper: up to ~3x):");
    for (k, s) in &speedups {
        println!("  k={k:<4} {s:.2}x");
    }
    // the paper's qualitative claims: flat never loses, and the speedup at
    // the largest stride exceeds the one at the smallest
    assert!(speedups.iter().all(|(_, s)| *s > 0.9),
            "flat should not lose: {speedups:?}");
    assert!(speedups.last().unwrap().1 >= speedups.first().unwrap().1 * 0.8,
            "gap should grow (or hold) with stride: {speedups:?}");
}
