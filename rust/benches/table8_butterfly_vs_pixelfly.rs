//! Table 8: Pixelfly (flat) vs original Butterfly (product) as the sparse
//! layer inside a model — step time on the PJRT engine (mixer presets) and
//! the layer-level gap on the Rust substrate at matched parameter count.

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::{TrainConfig, Trainer};
use pixelfly::runtime::{artifacts_dir, Engine};
use pixelfly::sparse::butterfly_mm::ButterflyProduct;
use pixelfly::sparse::Matrix;
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let mut suite = BenchSuite::new("table8_butterfly_vs_pixelfly");

    // layer-level comparison (matched params: same factors, flat vs product)
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 256);
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);
    let bp = ButterflyProduct::random(n, 32, 32, 0.1, &mut rng);
    let flat = bp.flatten();
    suite.bench("butterfly_product_layer", "log2(32)=5 sequential GEMMs", || {
        std::hint::black_box(bp.matmul(&x));
    });
    let t_prod = suite.last_mean_ms();
    let mut y = Matrix::zeros(batch, n);
    suite.bench("pixelfly_flat_layer", "1 sparse GEMM", || {
        flat.matmul_into(&x, &mut y);
        std::hint::black_box(&y);
    });
    let t_flat = suite.last_mean_ms();

    // model-level (PJRT artifacts): mixer with butterfly-product layers vs
    // pixelfly layers (mixer_s_butterfly uses mlp_ratio=1 for square GEMMs)
    let dir = artifacts_dir();
    let mut model_rows = Vec::new();
    if dir.join("manifest.rtxt").exists() {
        for preset in ["mixer_s_butterfly", "mixer_s_pixelfly", "mixer_s_dense"] {
            let mut engine = Engine::new(&dir).unwrap();
            let cfg = TrainConfig { preset: preset.into(), steps: 1, eval_batches: 0,
                                    ..Default::default() };
            if let Ok(mut t) = Trainer::new(&mut engine, cfg) {
                let mut r = Rng::new(0);
                t.step_once(&mut r).unwrap();
                suite.bench(preset, "train step", || {
                    t.step_once(&mut r).unwrap();
                });
                model_rows.push((preset, suite.last_mean_ms()));
            }
        }
    }
    suite.report();

    println!("\n=== Table 8 (shape check) ===");
    println!("layer-level flat vs product: {:.2}x (paper: pixelfly 2.3x vs butterfly 0.8x\n\
              relative to dense => ~2.9x between them)", t_prod / t_flat);
    for (p, ms) in &model_rows {
        println!("  {p:<22} {ms:.1} ms/step");
    }
    assert!(t_flat < t_prod, "flat layer must beat the sequential product");
}
