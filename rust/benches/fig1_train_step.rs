//! Fig 1: end-to-end train-step throughput, sparse vs dense, entirely on
//! the Rust substrate — the paper's headline claim ("sparse models train
//! up to 2.5x faster than the dense …") made measurable without PJRT.
//!
//! Two sections, each at seq/n ∈ {1k, 4k} (quick mode: 1k only), block 32:
//!
//! - **MLP block**: two n×n layers (GELU then identity), sparse BSR at
//!   10% block density through the fused-epilogue forward + transpose-
//!   free backward + pattern-frozen dW engine, vs the dense `DenseLinear`
//!   baseline on the same panel-tiled GEMMs.
//! - **Attention block**: fused streaming attention (stats forward +
//!   Flash-style recompute backward) + sparse output projection, pixelfly
//!   mask vs the FULL mask through the same engine (the dense-equivalent
//!   computation; fig7 established the fused full-mask kernel tracks the
//!   dense oracle).
//!
//! Every result row carries the fwd/bwd/update split (shared `PhaseCols`
//! formatter, folded over the timed iterations only) with per-phase
//! GFLOP/s in `BENCH_fig1_train_step.json`. Hard asserts enforce the
//! training-tier contract on the steady state: zero workspace
//! allocations after warmup for the attention step (backward included;
//! the MLP chain is scratch-free by construction — it has no workspace
//! to meter), O(block²)+O(seq) attention scratch — never seq×seq — and
//! sparse-beats-dense on the largest MLP shape.

use std::time::Duration;

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::{AttnTrainStep, DenseLinear, Linear, SparseLinear, TrainStep};
use pixelfly::patterns::{baselines, BlockMask};
use pixelfly::sparse::exec;
use pixelfly::sparse::{Activation, AttnPlan, BsrMatrix, Matrix};
use pixelfly::util::Rng;

/// Relative L2 error of `got` against the reference `want`.
fn rel_err(want: &[f32], got: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in want.iter().zip(got) {
        num += ((a - b) as f64).powi(2);
        den += (*a as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

/// Bench one TrainStep, accumulating the phase split over exactly the
/// TIMED iterations (warmup invocations are skipped, so the fwd/bwd/upd
/// columns describe the same samples as the row's mean_ms) and attaching
/// it plus per-phase GFLOP/s to the suite row.
fn bench_mlp(suite: &mut BenchSuite, name: &str, note: &str, ts: &mut TrainStep,
             weight_elems: f64, x: &Matrix, target: &Matrix) {
    let (ff, bf, uf) = ts.phase_flops();
    // time_it invokes the closure (warmup + iters) times; fold phases
    // over the timed tail only
    let warmup = suite.warmup as u32;
    let mut agg = [Duration::ZERO; 3];
    let mut calls = 0u32;
    ts.step(x, target, 1e-4, 0.9); // size every buffer before timing
    suite.bench_with_flops(name, note, ff + bf + uf, || {
        let (loss, t) = ts.step(x, target, 1e-4, 0.9);
        calls += 1;
        if calls > warmup {
            agg[0] += t.fwd;
            agg[1] += t.bwd;
            agg[2] += t.update;
        }
        std::hint::black_box(loss);
    });
    let timed = calls.saturating_sub(warmup).max(1);
    let ms = |d: Duration| d.as_secs_f64() * 1e3 / timed as f64;
    suite.set_phase_split([ms(agg[0]), ms(agg[1]), ms(agg[2])], Some([ff, bf, uf]));
    // the MLP chain's allocation freedom is structural: member-owned
    // buffers + scratch-free BSR backward engine — there is no workspace
    // to meter, hence the honest 0 here (attention rows meter theirs)
    suite.set_scratch_bytes(0);
    // first-order traffic model for the GB/s column: the weights are
    // streamed ~8x per step (fwd, dX, dW write, optimizer read w/g/m +
    // write w/m) and each activation panel crosses memory ~6x across
    // fwd+bwd; all f32 on this tier
    let acts = (x.rows * x.cols) as f64;
    suite.set_bytes_moved(4.0 * (8.0 * weight_elems + 6.0 * acts));
}

fn main() {
    let mut suite = BenchSuite::new("fig1_train_step");
    let b = 32usize;
    let threads = exec::threads();
    let kernel = exec::kernel_name();
    let sizes: &[usize] = if suite.quick { &[1024] } else { &[1024, 4096] };

    // --- MLP block: sparse engine vs dense baseline --------------------
    let mut mlp_means: Vec<(usize, f64, f64)> = Vec::new(); // (n, sparse, dense)
    for &n in sizes {
        let nb = n / b;
        let batch = if suite.quick { 64 } else { 128 };
        let density = 0.10;
        let mut rng = Rng::new(100);
        let mask1 = baselines::random_mask(nb, nb, density, &mut rng);
        let mask2 = baselines::random_mask(nb, nb, density, &mut rng);
        let scale = 1.0 / (n as f32).sqrt();
        let mut sparse = TrainStep::new(
            vec![
                Linear::Sparse(SparseLinear::random(&mask1, b, Activation::Gelu, scale,
                                                    &mut rng)),
                Linear::Sparse(SparseLinear::random(&mask2, b, Activation::Identity,
                                                    scale, &mut rng)),
            ],
            batch,
        );
        let mut dense = TrainStep::new(
            vec![
                Linear::Dense(DenseLinear::random(n, n, Activation::Gelu, scale,
                                                  &mut rng)),
                Linear::Dense(DenseLinear::random(n, n, Activation::Identity, scale,
                                                  &mut rng)),
            ],
            batch,
        );
        let x = Matrix::randn(batch, n, 1.0, &mut rng);
        let target = Matrix::randn(batch, n, 0.5, &mut rng);
        let note = format!("n={n} b={b} batch={batch} density={:.0}% \
                            threads={threads} {kernel}",
                           100.0 * density);
        let sparse_welems = ((mask1.nnz() + mask2.nnz()) * b * b) as f64;
        let dense_welems = (2 * n * n) as f64;
        bench_mlp(&mut suite, &format!("mlp_sparse_n{n}"), &note, &mut sparse,
                  sparse_welems, &x, &target);
        bench_mlp(&mut suite, &format!("mlp_dense_n{n}"), &note, &mut dense,
                  dense_welems, &x, &target);
        let sp = suite.mean_ms_of(&format!("mlp_sparse_n{n}")).unwrap();
        let de = suite.mean_ms_of(&format!("mlp_dense_n{n}")).unwrap();
        mlp_means.push((n, sp, de));
    }

    // --- attention block: pixelfly mask vs full mask, same engine -------
    let mut attn_means: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &seq in sizes {
        let nb = seq / b;
        let d = 64usize;
        let mut rng = Rng::new(200);
        let sparse_mask = baselines::pixelfly_attention_mask(nb, 4, 1);
        let full_mask = BlockMask::ones(nb, nb);
        let x = Matrix::randn(seq, d, 1.0, &mut rng);
        let target = Matrix::randn(seq, d, 0.5, &mut rng);
        let wo_mask = BlockMask::ones(d / b, d / b);
        for (tag, mask) in [("sparse", &sparse_mask), ("dense", &full_mask)] {
            let wo = Linear::Sparse(SparseLinear::random(&wo_mask, b,
                                                         Activation::Identity,
                                                         1.0 / (d as f32).sqrt(),
                                                         &mut rng));
            let mut ts = AttnTrainStep::new(mask, true, seq, d, wo);
            // attention fwd ≈ plan flops; backward recomputes score tiles
            // for dQ and again for dK/dV plus the dP dots ≈ 2.5x fwd; the
            // projection contributes its own fwd+bwd+update on top
            let af = ts.attn_flops();
            let flops = af * 3.5
                + ts.wo.fwd_flops(seq) + ts.wo.bwd_flops(seq) + ts.wo.update_flops();
            let note = format!("seq={seq} b={b} d={d} mask density={:.3} causal \
                                threads={threads} {kernel}",
                               mask.density());
            let warmup = suite.warmup as u32;
            let mut agg = [Duration::ZERO; 3];
            let mut calls = 0u32;
            ts.step(&x, &target, 1e-4, 0.9); // warmup sizes every buffer
            let warm_allocs = ts.alloc_events();
            suite.bench_with_flops(&format!("attn_{tag}_seq{seq}"), &note, flops, || {
                let (loss, t) = ts.step(&x, &target, 1e-4, 0.9);
                calls += 1;
                if calls > warmup {
                    agg[0] += t.fwd;
                    agg[1] += t.bwd;
                    agg[2] += t.update;
                }
                std::hint::black_box(loss);
            });
            assert_eq!(ts.alloc_events(), warm_allocs,
                       "attn_{tag}: steady-state step (incl. backward) must not allocate");
            // scratch: fwd tiles + bwd tiles per worker + the O(seq) D
            // row, with generous slack for checkout fragmentation — and
            // categorically never a seq×seq score/probability buffer
            let bound = 4 * 4
                * (threads * (AttnPlan::scratch_elems(b, d)
                              + AttnPlan::backward_scratch_elems(b))
                   + seq);
            assert!(ts.peak_scratch_bytes() <= bound,
                    "attn_{tag}: scratch {}B exceeds the O(threads·b²+seq) bound {bound}B",
                    ts.peak_scratch_bytes());
            assert!(ts.peak_scratch_bytes() < seq * seq * 4,
                    "attn_{tag}: backward must never materialize seq x seq");
            let timed = calls.saturating_sub(warmup).max(1);
            let ms = |dur: Duration| dur.as_secs_f64() * 1e3 / timed as f64;
            suite.set_phase_split([ms(agg[0]), ms(agg[1]), ms(agg[2])], None);
            suite.set_scratch_bytes(ts.peak_scratch_bytes());
        }
        let sp = suite.mean_ms_of(&format!("attn_sparse_seq{seq}")).unwrap();
        let de = suite.mean_ms_of(&format!("attn_dense_seq{seq}")).unwrap();
        attn_means.push((seq, sp, de, sparse_mask.density()));
    }

    // --- overlap scheduler: dW ∥ dX deferred backward vs serial --------
    // The Module-API chain (the scheduler lives in
    // `Sequential::backward_overlap`): same sparse 2-layer MLP shapes as
    // the headline section, stepped under `off` (sequential backward +
    // whole-model update pass) and `dw` (critical-path dX on this
    // thread, per-layer dW + eager fused update on the overlap worker).
    // Gradients and post-update params are bit-identical across the two
    // schedules (pinned in tests); this section measures what the
    // overlap buys in wall-clock.
    let mut ov_means: Vec<(usize, f64, f64)> = Vec::new(); // (n, off, dw)
    {
        use pixelfly::nn::{Module, Sequential, SparseLinear as NnSparseLinear};
        use pixelfly::sparse::exec::Workspace;
        for &n in sizes {
            let nb = n / b;
            let batch = if suite.quick { 64 } else { 128 };
            let mut rng = Rng::new(400);
            let mask1 = baselines::random_mask(nb, nb, 0.10, &mut rng);
            let mask2 = baselines::random_mask(nb, nb, 0.10, &mut rng);
            let scale = 1.0 / (n as f32).sqrt();
            let mut chain = Sequential::new(vec![
                Box::new(NnSparseLinear::random(&mask1, b, Activation::Gelu, scale,
                                                &mut rng)) as Box<dyn Module>,
                Box::new(NnSparseLinear::random(&mask2, b, Activation::Identity,
                                                scale, &mut rng)),
            ]);
            let mut ws = Workspace::new();
            let x = Matrix::randn(batch, n, 1.0, &mut rng);
            let gy0 = Matrix::randn(batch, n, 0.5, &mut rng);
            let mut y = Matrix::zeros(batch, n);
            let mut gy = Matrix::zeros(batch, n);
            let note = format!("n={n} b={b} batch={batch} density=10% \
                                threads={threads} {kernel}");
            for (tag, mode) in [("off", exec::OverlapMode::Off),
                                ("dw", exec::OverlapMode::Dw)] {
                exec::set_overlap(Some(mode));
                let mut step = |chain: &mut Sequential, ws: &mut Workspace,
                                y: &mut Matrix, gy: &mut Matrix| {
                    exec::step_scope(|| {
                        chain.forward_into(&x, y, ws);
                        gy.data.copy_from_slice(&gy0.data);
                        if exec::overlap_mode().dw() {
                            chain.backward_overlap(&x, y, gy, None, ws,
                                                   Some((1e-4, 0.9)), None);
                        } else {
                            chain.backward_into(&x, y, gy, None, ws);
                            chain.update(1e-4, 0.9);
                        }
                    });
                };
                step(&mut chain, &mut ws, &mut y, &mut gy); // size every buffer
                suite.bench(&format!("overlap_{tag}_n{n}"), &note, || {
                    step(&mut chain, &mut ws, &mut y, &mut gy);
                });
            }
            exec::set_overlap(None); // restore env/default resolution
            let off = suite.mean_ms_of(&format!("overlap_off_n{n}")).unwrap();
            let dw = suite.mean_ms_of(&format!("overlap_dw_n{n}")).unwrap();
            ov_means.push((n, off, dw));
        }
    }

    // --- precision tiers: bf16 executor sweeps vs the f32 plan ---------
    // Same plan, same three schedules (forward / dX / dW); weights and
    // activation panels stream as bf16 with f32 accumulate. Hard-asserts
    // pin the reduced-storage tier within the documented error bound
    // against the f32 sweeps it rides alongside; the GB/s column uses
    // exact streamed-byte counts, so the table shows the traffic the
    // tier saves, not just the latency.
    {
        let n = sizes[0];
        let nb = n / b;
        let batch = if suite.quick { 64 } else { 128 };
        let mut rng = Rng::new(300);
        let mask = baselines::random_mask(nb, nb, 0.10, &mut rng);
        let mut w = BsrMatrix::random(&mask, b, 0.1, &mut rng);
        let plan = w.plan(threads);
        let x = Matrix::randn(batch, n, 1.0, &mut rng);
        let dy = Matrix::randn(batch, n, 1.0, &mut rng);
        let mut y = Matrix::zeros(batch, n);
        let mut dx = Matrix::zeros(batch, n);
        let mut dw = vec![0.0f32; w.blocks.len()];
        let welems = w.blocks.len() as f64;
        let acts = (batch * n) as f64;
        let note = format!("n={n} b={b} batch={batch} threads={threads} {kernel}");

        // f32 reference sweeps (captured before the tier engages)
        plan.execute(&w, &x, &mut y);
        let y_ref = y.data.clone();
        plan.execute_dx(&w, &dy, &mut dx);
        let dx_ref = dx.data.clone();
        for v in dw.iter_mut() {
            *v = 0.0;
        }
        plan.execute_dw(&w, &x, &dy, &mut dw);
        let dw_ref = dw.clone();

        // streamed bytes per sweep. f32: weights + both panels at 4B.
        // bf16: weights at 2B; each packed panel costs 4B read + 2B
        // write (caller-side pack) + 2B kernel read; f32 outputs stay 4B.
        let f32_sweep = 4.0 * welems + 8.0 * acts;
        suite.bench(&format!("prec_fwd_f32_n{n}"), &note,
                    || plan.execute(&w, &x, &mut y));
        suite.set_bytes_moved(f32_sweep);
        suite.bench(&format!("prec_dx_f32_n{n}"), &note,
                    || plan.execute_dx(&w, &dy, &mut dx));
        suite.set_bytes_moved(f32_sweep);
        suite.bench(&format!("prec_dw_f32_n{n}"), &note,
                    || plan.execute_dw(&w, &x, &dy, &mut dw));
        suite.set_bytes_moved(f32_sweep);

        // engage the reduced-storage training tier on this matrix
        exec::set_precision(exec::Precision::Bf16);
        w.refresh_bf16();
        assert!(w.blocks_bf16.is_some(), "bf16 shadow must engage under the tier");

        plan.execute(&w, &x, &mut y);
        let e_fwd = rel_err(&y_ref, &y.data);
        plan.execute_dx(&w, &dy, &mut dx);
        let e_dx = rel_err(&dx_ref, &dx.data);
        for v in dw.iter_mut() {
            *v = 0.0;
        }
        plan.execute_dw(&w, &x, &dy, &mut dw);
        let e_dw = rel_err(&dw_ref, &dw);
        // the pinned training-tier bound: bf16 storage with f32
        // accumulate stays within 1e-2 relative error of the f32 sweeps
        assert!(e_fwd <= 1e-2, "bf16 forward rel error {e_fwd:.2e} > 1e-2");
        assert!(e_dx <= 1e-2, "bf16 dX rel error {e_dx:.2e} > 1e-2");
        assert!(e_dw <= 1e-2, "bf16 dW rel error {e_dw:.2e} > 1e-2");

        suite.bench(&format!("prec_fwd_bf16_n{n}"),
                    &format!("{note} rel_err={e_fwd:.1e}"),
                    || plan.execute(&w, &x, &mut y));
        suite.set_bytes_moved(2.0 * welems + 12.0 * acts);
        suite.bench(&format!("prec_dx_bf16_n{n}"),
                    &format!("{note} rel_err={e_dx:.1e}"),
                    || plan.execute_dx(&w, &dy, &mut dx));
        suite.set_bytes_moved(2.0 * welems + 12.0 * acts);
        suite.bench(&format!("prec_dw_bf16_n{n}"),
                    &format!("{note} rel_err={e_dw:.1e}"),
                    || plan.execute_dw(&w, &x, &dy, &mut dw));
        suite.set_bytes_moved(4.0 * welems + 16.0 * acts);

        // restore the global default so nothing leaks past this section
        exec::set_precision(exec::Precision::F32);
        w.drop_precision_shadows();
    }

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }

    println!("\ntrain-step speedups (sparse vs dense, full fwd+bwd+update):");
    for (n, sp, de) in &mlp_means {
        println!("  mlp  n={n:<5} {:.2}x  (sparse {sp:.2}ms, dense {de:.2}ms)", de / sp);
    }
    for (seq, sp, de, dens) in &attn_means {
        println!("  attn seq={seq:<4} {:.2}x  (mask density {dens:.3})", de / sp);
    }
    println!("\noverlap scheduler (dw vs off, full fwd+bwd+update):");
    for (n, off, dw) in &ov_means {
        println!("  mlp  n={n:<5} {:.2}x  (dw {dw:.2}ms, off {off:.2}ms)", off / dw);
    }

    // Acceptance: sparse train-step beats dense at ≤25% density on the
    // largest MLP shape that ran (4k/b32 in full mode, 1k in quick). At
    // 10% block density the engine has a ~10x flop advantage; anything
    // ≤ 1x means the backward tier lost the speedup the forward won.
    let (n, sp, de) = *mlp_means.last().unwrap();
    assert!(sp < de,
            "sparse train step must beat dense at 10% density \
             (n={n}: sparse {sp:.2}ms vs dense {de:.2}ms)");

    // Acceptance: the overlapped schedule wins wall-clock on the largest
    // shape in full mode (4k, where there is real dW work to hide). The
    // quick 1k shape is dispatch-noise territory on small CI hosts, so
    // there the gate only rejects a real regression, not jitter.
    let (n, off, dw) = *ov_means.last().unwrap();
    if suite.quick {
        assert!(dw <= off * 1.25,
                "overlap=dw must not regress the train step by >25% \
                 (n={n}: dw {dw:.2}ms vs off {off:.2}ms)");
    } else {
        assert!(dw < off,
                "overlap=dw must beat the serial schedule at n={n}: \
                 dw {dw:.2}ms vs off {off:.2}ms");
    }
}
