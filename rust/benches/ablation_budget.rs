//! §5.3 ablations as benches:
//! (i)   low-rank/butterfly split (accuracy proxy: NTK distance)
//! (ii)  block-size sweep (latency at fixed density — Table 7's axis)
//! (iii) budget allocation (projected end-to-end speedup).

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::budget::{self, Allocation};
use pixelfly::costmodel::Device;
use pixelfly::models::{self, LayerType};
use pixelfly::ntk;
use pixelfly::patterns::{baselines, flat_butterfly_mask, BlockMask};
use pixelfly::sparse::{BsrMatrix, Matrix};
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let mut suite = BenchSuite::new("ablation_budget");

    // (ii) block-size sweep at fixed density
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 256);
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);
    println!("=== (ii) block-size sweep at ~12% density ===");
    for b in [8usize, 16, 32, 64] {
        let nb = n / b;
        let ms = 2usize; // diag + 1 stride => density (log2(2)+1)/nb
        let mask = flat_butterfly_mask(nb, ms.min(nb));
        let w = BsrMatrix::random(&mask, b, 0.1, &mut Rng::new(1));
        let mut y = Matrix::zeros(batch, n);
        suite.bench(&format!("block_{b}"), &format!("density={:.3}", mask.density()), || {
            w.matmul_into(&x, &mut y);
            std::hint::black_box(&y);
        });
    }

    // (i) low-rank share ablation via NTK distance (accuracy proxy)
    println!("\n=== (i) low-rank share (NTK distance to dense; lower=better) ===");
    let nb = 16;
    let block = 4;
    let dim = nb * block;
    let mut noise = Rng::new(2);
    let data: Vec<Vec<f32>> = (0..16)
        .map(|i| {
            let mut c = Rng::new(700 + (i / 2) as u64);
            (0..dim).map(|_| c.normal_f32() + 0.3 * noise.normal_f32()).collect()
        })
        .collect();
    let dense_g = ntk::ntk_gram(&data, &ntk::supports_from_mask(&BlockMask::ones(nb, nb), block));
    let total_budget = nb * nb / 4;
    println!("{:>14} {:>12}", "lowrank share", "NTK dist");
    for share in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
        let g_blocks = ((share * total_budget as f64) as usize / (2 * nb)).min(nb / 2);
        let bf_budget = total_budget - (2 * g_blocks * nb).min(total_budget);
        let ms = pixelfly::patterns::butterfly::max_stride_for_budget(nb, bf_budget.max(nb));
        let mask = baselines::pixelfly_attention_mask(nb, if share < 1.0 { ms } else { 1 }, g_blocks);
        let g = ntk::ntk_gram(&data, &ntk::supports_from_mask(&mask, block));
        println!("{share:>14.2} {:>12.4}", ntk::relative_distance(&dense_g, &g));
    }
    println!("(paper: ~1/4 low-rank + 3/4 butterfly is best)");

    // (iii) budget allocation strategies
    println!("\n=== (iii) allocation strategy -> projected speedup (vit-s16) ===");
    let dev = Device::with_block(32);
    let schema = models::preset("vit-s16", 32).unwrap();
    let mk = |attn: f64, mlp: f64| Allocation {
        densities: vec![
            (LayerType::AttnProj, attn),
            (LayerType::AttnScore, attn),
            (LayerType::Mlp, mlp),
        ],
        lowrank_share: 0.25,
    };
    for (name, alloc) in [
        ("attention-only", mk(0.1, 1.0)),
        ("mlp-only", mk(1.0, 0.1)),
        ("balanced", budget::rule_of_thumb(&schema, 0.1, &dev)),
    ] {
        println!("  {name:<16} {:.2}x", budget::projected_speedup(&schema, &alloc, &dev));
    }
    suite.report();
}
