//! Fig 5 / Table 4: vision-model training-step throughput, dense vs
//! Pixelfly (Mixer + ViT), on the PJRT engine with the AOT artifacts.
//!
//! The accuracy columns of Fig 5 come from `examples/train_mixer_image`;
//! this bench regenerates the Speedup column (step-time ratio at equal
//! batch) plus params/FLOPs (Table 4 columns) from the manifest.

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::{TrainConfig, Trainer};
use pixelfly::runtime::{artifacts_dir, Engine};
use pixelfly::util::Rng;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.rtxt").exists() {
        println!("fig5_vision: artifacts not built, skipping (run `make artifacts`)");
        return;
    }
    let mut suite = BenchSuite::new("fig5_vision");
    let presets = ["mixer_s_dense", "mixer_s_pixelfly", "mixer_s_random",
                   "vit_s_dense", "vit_s_pixelfly", "vit_s_bigbird"];
    let mut rows = Vec::new();
    for preset in presets {
        let mut engine = Engine::new(&dir).unwrap();
        let cfg = TrainConfig {
            preset: preset.into(),
            steps: 1,
            eval_batches: 0,
            ..Default::default()
        };
        let mut trainer = match Trainer::new(&mut engine, cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("skip {preset}: {e}");
                continue;
            }
        };
        let mut rng = Rng::new(0);
        trainer.step_once(&mut rng).unwrap(); // compile+warm
        suite.bench(preset, "", || {
            trainer.step_once(&mut rng).unwrap();
        });
        let (params, flops) = {
            let key = format!("{preset}.train_step");
            let a = trainer.engine.manifest.artifact(&key).unwrap();
            (a.param_count, a.flops_fwd)
        };
        rows.push((preset, suite.last_mean_ms(), params, flops));
    }
    suite.report();

    println!("\n=== Table 4 (scaled): params/FLOPs/step-time ===");
    println!("{:<22} {:>10} {:>12} {:>12} {:>9}", "model", "params", "fwd FLOPs",
             "step(ms)", "speedup");
    for family in ["mixer_s", "vit_s"] {
        let base = rows.iter().find(|(p, ..)| *p == format!("{family}_dense"))
            .map(|(_, ms, ..)| *ms);
        for (p, ms, params, flops) in rows.iter().filter(|(p, ..)| p.starts_with(family)) {
            let sp = base.map(|b| b / ms).unwrap_or(f64::NAN);
            println!("{p:<22} {params:>10} {flops:>12} {ms:>12.1} {sp:>8.2}x");
        }
    }
}
