//! Fig 7: attention-bottleneck model (T2T-ViT-style) with sparse-attention
//! baselines — BigBird, Sparse Transformer, Pixelfly — via the AOT
//! forward_eval artifacts (Pallas block-sparse attention kernel) plus the
//! cost model at paper scale.
//!
//! The substrate section benches the fused streaming attention engine
//! against the materializing two-pass kernel and the dense oracle at
//! seq ∈ {1k, 4k}, block 32, causal and non-causal, and writes
//! `BENCH_fig7_attention.json` with GFLOP/s and peak-scratch-bytes
//! columns. Hard assertions enforce the engine contract: zero-alloc after
//! warmup, scratch O(threads·block²·d) (never seq×seq or per-row seq),
//! and ≤1e-4 max-abs-diff vs `dense_attention` on full masks.

use pixelfly::bench::BenchSuite;
use pixelfly::costmodel::{attention_cost, Device};
use pixelfly::patterns::{baselines, BlockMask};
use pixelfly::runtime::engine::Literal;
use pixelfly::runtime::{artifacts_dir, engine, Engine};
use pixelfly::sparse::attention::{self, AttnPlan};
use pixelfly::sparse::exec::{self, Workspace};
use pixelfly::sparse::Matrix;
use pixelfly::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig7_attention_baselines");
    let dir = artifacts_dir();
    let presets = ["t2t_dense", "t2t_pixelfly", "t2t_bigbird", "t2t_sparsetrans"];
    let mut measured: Vec<(String, f64)> = Vec::new();

    if cfg!(not(feature = "pjrt")) {
        println!("built without the pjrt feature; cost-model section only \
                  (rebuild with --features pjrt to measure artifacts)");
    } else if dir.join("manifest.rtxt").exists() {
        for preset in presets {
            let key = format!("{preset}.forward_eval");
            let mut eng = Engine::new(&dir).unwrap();
            if eng.manifest.artifacts.get(&key).is_none() {
                println!("skip {key} (not built — use `make artifacts` with --full)");
                continue;
            }
            let spec = eng.manifest.artifact(&key).unwrap().clone();
            let params = eng.load_initial_state(preset, &key).unwrap();
            // synthetic batch
            let xs = &spec.inputs[spec.n_param_leaves];
            let ys = &spec.inputs[spec.n_param_leaves + 1];
            let mut rng = Rng::new(0);
            let x = engine::f32_literal(&xs.dims, &rng.normal_vec(xs.elements(), 1.0)).unwrap();
            let yv: Vec<i32> = (0..ys.elements()).map(|_| rng.below(10) as i32).collect();
            let y = engine::i32_literal(&ys.dims, &yv).unwrap();
            let mut args: Vec<&Literal> = params.iter().collect();
            args.push(&x);
            args.push(&y);
            let art = eng.load(&key).unwrap();
            // warm
            art.exe.execute::<&Literal>(&args).unwrap();
            suite.bench(preset, "forward_eval (pallas attention)", || {
                std::hint::black_box(art.exe.execute::<&Literal>(&args).unwrap());
            });
            measured.push((preset.to_string(), suite.last_mean_ms()));
        }
        suite.report();
    } else {
        println!("artifacts not built; cost-model section only");
    }

    if let Some(base) = measured.iter().find(|(p, _)| p == "t2t_dense").map(|(_, m)| *m) {
        println!("\nmeasured attention-model speedups (scaled seq=256):");
        for (p, m) in &measured {
            println!("  {p:<18} {:.2}x", base / m);
        }
    }

    // --- substrate: fused streaming vs materializing vs dense ------------
    // (own suite so CI uploads BENCH_fig7_attention.json per the roadmap's
    // cross-PR perf tracking)
    {
        let mut fs = BenchSuite::new("fig7_attention");
        let b = 32usize;
        let d = 64usize;
        let threads = exec::threads();
        let seqs: &[usize] = if fs.quick { &[1024] } else { &[1024, 4096] };
        for &seq in seqs {
            let nb = seq / b;
            let mask = baselines::pixelfly_attention_mask(nb, 4, 1);
            let mut rng = Rng::new(7);
            let q = Matrix::randn(seq, d, 1.0, &mut rng);
            let k = Matrix::randn(seq, d, 1.0, &mut rng);
            let v = Matrix::randn(seq, d, 1.0, &mut rng);
            let mut out = Matrix::zeros(seq, d);
            for causal in [false, true] {
                let tag = if causal { "causal" } else { "full" };
                let plan = attention::plan_for(&mask, causal, threads);
                let flops = plan.flops(b, d);
                let note = format!("seq={seq} b={b} d={d} mask density={:.3} {}",
                                   mask.density(), exec::kernel_name());

                // fused online-softmax engine (zero-alloc once warm)
                let mut ws = Workspace::new();
                plan.execute(&q, &k, &v, &mut out, &mut ws); // warmup sizes scratch
                let warm_allocs = ws.alloc_events();
                fs.bench_with_flops(&format!("fused_{tag}_seq{seq}"), &note, flops, || {
                    plan.execute(&q, &k, &v, &mut out, &mut ws);
                    std::hint::black_box(&out);
                });
                assert_eq!(ws.alloc_events(), warm_allocs,
                           "fused attention must be zero-alloc after warmup");
                let bound = threads.max(1) * AttnPlan::scratch_elems(b, d) * 4;
                assert!(ws.peak_bytes() <= bound,
                        "fused scratch {}B exceeds the O(threads*(b^2+b*d)) bound {bound}B",
                        ws.peak_bytes());
                assert!(ws.peak_bytes() < seq * seq * 4,
                        "fused attention must never materialize a seq x seq buffer");
                fs.set_scratch_bytes(ws.peak_bytes());
                // streamed-byte model for the GB/s column: each visited
                // block pair streams a q, k and v tile (b·d f32 each);
                // the pair-visit count falls out of the plan's flop count
                // (4·b²·d flops per visit), plus one output panel write
                let visits = flops / (4.0 * (b * b * d) as f64);
                fs.set_bytes_moved(visits * (3 * b * d * 4) as f64
                                   + (seq * d * 4) as f64);

                // materializing two-pass baseline (per-row seq-length scores)
                let mut ws2 = Workspace::new();
                plan.execute_materializing(&q, &k, &v, &mut out, &mut ws2);
                fs.bench_with_flops(&format!("materializing_{tag}_seq{seq}"), &note, flops, || {
                    plan.execute_materializing(&q, &k, &v, &mut out, &mut ws2);
                    std::hint::black_box(&out);
                });
                fs.set_scratch_bytes(ws2.peak_bytes());

                // dense oracle column (O(seq^2); the 4k full-mode run is
                // long, so dense is measured at 1k where the comparison
                // already tells the story)
                if seq <= 1024 {
                    // causal skips the dot AND the V pass for j > i, so it
                    // only performs ~seq(seq+1)/2 of the seq² pair visits
                    let dflops = if causal {
                        2.0 * (seq * (seq + 1)) as f64 * d as f64
                    } else {
                        4.0 * (seq * seq) as f64 * d as f64
                    };
                    fs.bench_with_flops(&format!("dense_{tag}_seq{seq}"),
                                        &format!("seq={seq} dense oracle"), dflops, || {
                        std::hint::black_box(attention::dense_attention(&q, &k, &v, causal));
                    });
                }
            }
            // acceptance: fused output matches the dense oracle on a full
            // mask within 1e-4 max-abs-diff (the tolerance is mandated by
            // the PR's acceptance criteria; softmax-normalised outputs are
            // convex combinations of unit-scale v rows, so the observed
            // diff sits orders of magnitude below it even with FMA
            // reordering — if this ever trips, investigate, don't loosen)
            if seq <= 1024 {
                let ones = BlockMask::ones(nb, nb);
                let got = attention::block_sparse_attention(&q, &k, &v, &ones, false);
                let want = attention::dense_attention(&q, &k, &v, false);
                let diff = got.max_abs_diff(&want);
                assert!(diff < 1e-4, "fused vs dense oracle max-abs-diff {diff}");
                println!("fused vs dense oracle (full mask, seq={seq}): max|diff|={diff:.2e}");

                // bf16 training-tier bound: under the reduced-storage
                // tier the attention projections hand the kernel
                // bf16-rounded panels while softmax/accumulate stay f32
                // (by design — see DESIGN.md "Precision tiers"). Pin the
                // end-to-end effect: fused attention on bf16-rounded
                // Q/K/V stays within 1e-2 max-abs of the f32 oracle.
                let round = |m: &Matrix| Matrix {
                    rows: m.rows,
                    cols: m.cols,
                    data: m.data.iter().map(|&x| exec::quant::bf16_round(x)).collect(),
                };
                let (qb, kb, vb) = (round(&q), round(&k), round(&v));
                let got16 = attention::block_sparse_attention(&qb, &kb, &vb, &ones,
                                                             false);
                let diff16 = got16.max_abs_diff(&want);
                assert!(diff16 < 1e-2,
                        "bf16-rounded attention max-abs-diff {diff16} > 1e-2");
                println!("bf16-rounded vs f32 oracle (full mask, seq={seq}): \
                          max|diff|={diff16:.2e}");
            }
        }
        fs.report();
        match fs.write_json_default() {
            Ok(p) => println!("json -> {}", p.display()),
            Err(e) => eprintln!("json write failed: {e}"),
        }
    }

    // cost model at paper scale: T2T stage seq ~ 3136 -> nearest pow2 4096
    println!("\ncost-model projection at T2T scale (seq=3136→4096, b=32, d=64):");
    let dev = Device::with_block(32);
    let nb = 4096 / 32;
    let dense = attention_cost(&BlockMask::ones(nb, nb), 32, 64, 1, &dev);
    let mut rng = Rng::new(1);
    let rows: Vec<(&str, BlockMask)> = vec![
        ("pixelfly", baselines::pixelfly_attention_mask(nb, 4, 1)),
        ("bigbird", baselines::bigbird_mask(nb, 1, 1, 2, &mut rng)),
        ("sparse_transformer", baselines::sparse_transformer_mask(nb, None)),
    ];
    println!("{:<20} {:>10} {:>12}", "pattern", "density", "speedup");
    for (name, mask) in rows {
        let c = attention_cost(&mask, 32, 64, 1, &dev);
        println!("{name:<20} {:>10.3} {:>11.1}x", mask.density(), dense.total / c.total);
    }
    println!("(paper Fig 7 end-to-end: BigBird 0.9x, SparseTrans 1.3x, Pixelfly 1.4x —\n\
              end-to-end gains are smaller than attention-only gains because the\n\
              rest of the model is unsparsified; see plan_budget example)");
}
