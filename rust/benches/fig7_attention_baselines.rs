//! Fig 7: attention-bottleneck model (T2T-ViT-style) with sparse-attention
//! baselines — BigBird, Sparse Transformer, Pixelfly — via the AOT
//! forward_eval artifacts (Pallas block-sparse attention kernel) plus the
//! cost model at paper scale.

use pixelfly::bench::BenchSuite;
use pixelfly::costmodel::{attention_cost, Device};
use pixelfly::patterns::{baselines, BlockMask};
use pixelfly::runtime::engine::Literal;
use pixelfly::runtime::{artifacts_dir, engine, Engine};
use pixelfly::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig7_attention_baselines");
    let dir = artifacts_dir();
    let presets = ["t2t_dense", "t2t_pixelfly", "t2t_bigbird", "t2t_sparsetrans"];
    let mut measured: Vec<(String, f64)> = Vec::new();

    if cfg!(not(feature = "pjrt")) {
        println!("built without the pjrt feature; cost-model section only \
                  (rebuild with --features pjrt to measure artifacts)");
    } else if dir.join("manifest.rtxt").exists() {
        for preset in presets {
            let key = format!("{preset}.forward_eval");
            let mut eng = Engine::new(&dir).unwrap();
            if eng.manifest.artifacts.get(&key).is_none() {
                println!("skip {key} (not built — use `make artifacts` with --full)");
                continue;
            }
            let spec = eng.manifest.artifact(&key).unwrap().clone();
            let params = eng.load_initial_state(preset, &key).unwrap();
            // synthetic batch
            let xs = &spec.inputs[spec.n_param_leaves];
            let ys = &spec.inputs[spec.n_param_leaves + 1];
            let mut rng = Rng::new(0);
            let x = engine::f32_literal(&xs.dims, &rng.normal_vec(xs.elements(), 1.0)).unwrap();
            let yv: Vec<i32> = (0..ys.elements()).map(|_| rng.below(10) as i32).collect();
            let y = engine::i32_literal(&ys.dims, &yv).unwrap();
            let mut args: Vec<&Literal> = params.iter().collect();
            args.push(&x);
            args.push(&y);
            let art = eng.load(&key).unwrap();
            // warm
            art.exe.execute::<&Literal>(&args).unwrap();
            suite.bench(preset, "forward_eval (pallas attention)", || {
                std::hint::black_box(art.exe.execute::<&Literal>(&args).unwrap());
            });
            measured.push((preset.to_string(), suite.last_mean_ms()));
        }
        suite.report();
    } else {
        println!("artifacts not built; cost-model section only");
    }

    if let Some(base) = measured.iter().find(|(p, _)| p == "t2t_dense").map(|(_, m)| *m) {
        println!("\nmeasured attention-model speedups (scaled seq=256):");
        for (p, m) in &measured {
            println!("  {p:<18} {:.2}x", base / m);
        }
    }

    // cost model at paper scale: T2T stage seq ~ 3136 -> nearest pow2 4096
    println!("\ncost-model projection at T2T scale (seq=3136→4096, b=32, d=64):");
    let dev = Device::with_block(32);
    let nb = 4096 / 32;
    let dense = attention_cost(&BlockMask::ones(nb, nb), 32, 64, 1, &dev);
    let mut rng = Rng::new(1);
    let rows: Vec<(&str, BlockMask)> = vec![
        ("pixelfly", baselines::pixelfly_attention_mask(nb, 4, 1)),
        ("bigbird", baselines::bigbird_mask(nb, 1, 1, 2, &mut rng)),
        ("sparse_transformer", baselines::sparse_transformer_mask(nb, None)),
    ];
    println!("{:<20} {:>10} {:>12}", "pattern", "density", "speedup");
    for (name, mask) in rows {
        let c = attention_cost(&mask, 32, 64, 1, &dev);
        println!("{name:<20} {:>10.3} {:>11.1}x", mask.density(), dense.total / c.total);
    }
    println!("(paper Fig 7 end-to-end: BigBird 0.9x, SparseTrans 1.3x, Pixelfly 1.4x —\n\
              end-to-end gains are smaller than attention-only gains because the\n\
              rest of the model is unsparsified; see plan_budget example)");
}
