//! Dispatch-latency microbench for the resident worker-pool runtime:
//! resident doorbell dispatch vs scoped spawn-per-call vs serial, across
//! raw task counts, the 1k/b32 GEMM headline shape, fused attention, and
//! a single-sequence `InferenceSession::run` serving row.
//!
//! Hard asserts (the PR-5 runtime contract):
//! - resident dispatch strictly beats scoped spawn-per-call on the
//!   1k/b32/10% GEMM at batch 32 and on single-sequence inference;
//! - steady-state dispatch allocates nothing: after warmup, repeated
//!   scratch-carrying dispatches leave BOTH the caller workspace counter
//!   and the resident workers' pinned-workspace counter
//!   (`pool::worker_alloc_events`) flat.
//!
//! `PIXELFLY_PAR_FLOPS` is pinned before the first engine call so the
//! serial-vs-parallel cutover cannot flap with CI timer noise — the
//! bench measures the dispatch substrate, not the calibrator.

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::budget::rule_of_thumb;
use pixelfly::costmodel::Device;
use pixelfly::models::transformer_schema;
use pixelfly::nn::compile;
use pixelfly::patterns::baselines;
use pixelfly::sparse::exec::{self, pool};
use pixelfly::sparse::exec::pool::PoolMode;
use pixelfly::sparse::{AttnPlan, BsrMatrix, Matrix, Workspace};
use pixelfly::util::Rng;

fn main() {
    // pin the cutover BEFORE anything triggers calibration: every op at
    // or above 1 MFLOP goes parallel, deterministically, in both modes
    std::env::set_var("PIXELFLY_PAR_FLOPS", "1e6");
    let threads = exec::threads().max(2);
    exec::set_threads(threads);

    let mut suite = BenchSuite::new("pool_dispatch");
    let kernel = exec::kernel_name();

    // --- raw dispatch latency: empty job batches ------------------------
    for n_tasks in [4usize, 32, 256] {
        let note = format!("n_tasks={n_tasks} threads={threads}");
        suite.bench(&format!("dispatch{n_tasks}_resident"), &note, || {
            pool::run_tasks_in(PoolMode::Resident, n_tasks, threads, |t| {
                std::hint::black_box(t);
            });
        });
        suite.bench(&format!("dispatch{n_tasks}_scoped"), &note, || {
            pool::run_tasks_in(PoolMode::Scoped, n_tasks, threads, |t| {
                std::hint::black_box(t);
            });
        });
        suite.bench(&format!("dispatch{n_tasks}_serial"), &note, || {
            pool::run_tasks_in(PoolMode::Resident, n_tasks, 1, |t| {
                std::hint::black_box(t);
            });
        });
    }

    // --- the 1k/b32 headline GEMM at small batch ------------------------
    // batch 32 keeps the per-dispatch work small enough that the launch
    // tax is a visible fraction — exactly the serving regime the resident
    // pool exists for
    let (n, b, batch, density) = (1024usize, 32usize, 32usize, 0.10);
    let mut rng = Rng::new(11);
    let mask = baselines::random_mask(n / b, n / b, density, &mut rng);
    let w = BsrMatrix::random(&mask, b, 0.5, &mut rng);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);
    let mut y = Matrix::zeros(batch, w.cols_elems());
    let flops = 2.0 * (batch * w.nnz_blocks()) as f64 * (b * b) as f64;
    let note = format!("n={n} b={b} batch={batch} density={:.0}% threads={threads} \
                        {kernel}", 100.0 * density);
    let plan = w.plan(threads);
    let serial_plan = w.plan(1);
    exec::set_pool_mode(Some(PoolMode::Resident));
    suite.bench_with_flops("gemm1k_b32_resident", &note, flops, || {
        plan.execute(&w, &x, &mut y);
    });
    exec::set_pool_mode(Some(PoolMode::Scoped));
    suite.bench_with_flops("gemm1k_b32_scoped", &note, flops, || {
        plan.execute(&w, &x, &mut y);
    });
    exec::set_pool_mode(None);
    suite.bench_with_flops("gemm1k_b32_serial", &note, flops, || {
        serial_plan.execute(&w, &x, &mut y);
    });
    let res = suite.mean_ms_of("gemm1k_b32_resident").unwrap();
    let sco = suite.mean_ms_of("gemm1k_b32_scoped").unwrap();
    assert!(res < sco,
            "resident dispatch must beat scoped spawn-per-call at 1k/b32 \
             (resident {res:.3}ms vs scoped {sco:.3}ms)");

    // --- fused attention + the zero-alloc steady-state contract ---------
    let (seq, ab, d) = (1024usize, 32usize, 64usize);
    let amask = baselines::pixelfly_attention_mask(seq / ab, 4, 1);
    let aplan = AttnPlan::new(&amask, false, threads);
    let mut ws = Workspace::new();
    let (q, k, v) = (Matrix::randn(seq, d, 1.0, &mut rng),
                     Matrix::randn(seq, d, 1.0, &mut rng),
                     Matrix::randn(seq, d, 1.0, &mut rng));
    let mut out = Matrix::zeros(seq, d);
    let anote = format!("seq={seq} b={ab} d={d} density={:.3} threads={threads} \
                         {kernel}", amask.density());
    exec::set_pool_mode(Some(PoolMode::Resident));
    // warm until the caller + every resident worker has sized its pinned
    // scratch, then require a long flat tail: steady-state dispatch must
    // not touch the allocator on either side of the worker boundary
    let mut flat_streak = 0usize;
    let mut prev = ws.alloc_events() + pool::worker_alloc_events();
    for _ in 0..50 {
        aplan.execute(&q, &k, &v, &mut out, &mut ws);
        let now = ws.alloc_events() + pool::worker_alloc_events();
        if now == prev {
            flat_streak += 1;
        } else {
            flat_streak = 0;
            prev = now;
        }
    }
    assert!(flat_streak >= 10,
            "steady-state resident dispatch must stop allocating \
             (caller + worker workspaces still moving after 50 rounds)");
    suite.bench_with_flops("attn1k_resident", &anote, aplan.flops(ab, d), || {
        aplan.execute(&q, &k, &v, &mut out, &mut ws);
    });
    suite.set_scratch_bytes(ws.peak_bytes());
    exec::set_pool_mode(Some(PoolMode::Scoped));
    suite.bench_with_flops("attn1k_scoped", &anote, aplan.flops(ab, d), || {
        aplan.execute(&q, &k, &v, &mut out, &mut ws);
    });
    exec::set_pool_mode(None);

    // --- single-sequence serving latency --------------------------------
    // seq-1024 transformer (block-16 grid = 64 blocks, power of two):
    // ~40 job batches per run — the whole-step dispatch shape. One model
    // per mode so each session's zero-alloc self-assert sees one
    // consistent scratch pattern.
    let schema = transformer_schema("pool-bench", 256, 4, 1024, 4, 1);
    let dev = Device::with_block(16);
    let alloc = rule_of_thumb(&schema, 0.2, &dev);
    let mut rng = Rng::new(12);
    let xs = Matrix::randn(1024, 256, 1.0, &mut rng);
    let mut infer_ms = [0.0f64; 2];
    for (slot, mode) in [(0usize, PoolMode::Resident), (1, PoolMode::Scoped)] {
        exec::set_pool_mode(Some(mode));
        let model = compile(&schema, &alloc, 16, 7).expect("compile pool-bench");
        let fwd = model.flops().fwd;
        let mut sess = model.into_inference().strict();
        sess.run(&xs).unwrap(); // warmup (strict() keeps zero-alloc a hard assert)
        let name = format!("infer_seq1k_{}", mode.name());
        let inote = format!("seq=1024 d=256 layers=4 budget=0.2 threads={threads} \
                             {kernel}");
        suite.bench_with_flops(&name, &inote, fwd, || {
            std::hint::black_box(sess.run(&xs).unwrap().data[0]);
        });
        suite.set_scratch_bytes(sess.peak_scratch_bytes());
        infer_ms[slot] = suite.mean_ms_of(&name).unwrap();
    }
    exec::set_pool_mode(None);
    assert!(infer_ms[0] < infer_ms[1],
            "resident dispatch must beat scoped spawn on single-sequence \
             InferenceSession::run (resident {:.3}ms vs scoped {:.3}ms)",
            infer_ms[0], infer_ms[1]);

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("\npool dispatch contract: resident beats scoped at 1k/b32 GEMM \
              ({res:.3}ms vs {sco:.3}ms) and at seq-1k inference ({:.3}ms vs \
              {:.3}ms); steady-state dispatch allocation-free.",
             infer_ms[0], infer_ms[1]);
}
