//! Fig 9: LRA-style long-sequence throughput — dense vs Pixelfly forward
//! pass with the Pallas block-sparse attention kernel actually skipping
//! blocks (the lra_* eval artifacts), plus Reformer-like bucketing on the
//! Rust substrate.

use pixelfly::bench::BenchSuite;
use pixelfly::costmodel::{attention_cost, Device};
use pixelfly::patterns::{baselines, BlockMask};
use pixelfly::runtime::engine::Literal;
use pixelfly::runtime::{artifacts_dir, engine, Engine};
use pixelfly::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig9_lra");
    let dir = artifacts_dir();
    let mut measured: Vec<(String, f64)> = Vec::new();
    if cfg!(not(feature = "pjrt")) {
        println!("built without the pjrt feature; cost-model section only \
                  (rebuild with --features pjrt to measure artifacts)");
    } else if dir.join("manifest.rtxt").exists() {
        for preset in ["lra_dense", "lra_pixelfly"] {
            let key = format!("{preset}.forward_eval");
            let mut eng = Engine::new(&dir).unwrap();
            if eng.manifest.artifacts.get(&key).is_none() {
                println!("skip {key} (needs --full artifacts)");
                continue;
            }
            let spec = eng.manifest.artifact(&key).unwrap().clone();
            let params = eng.load_initial_state(preset, &key).unwrap();
            let xs = &spec.inputs[spec.n_param_leaves];
            let ys = &spec.inputs[spec.n_param_leaves + 1];
            let mut rng = Rng::new(0);
            let x = engine::f32_literal(&xs.dims, &rng.normal_vec(xs.elements(), 1.0)).unwrap();
            let yv: Vec<i32> = (0..ys.elements()).map(|_| rng.below(2) as i32).collect();
            let y = engine::i32_literal(&ys.dims, &yv).unwrap();
            let mut args: Vec<&Literal> = params.iter().collect();
            args.push(&x);
            args.push(&y);
            let art = eng.load(&key).unwrap();
            art.exe.execute::<&Literal>(&args).unwrap();
            suite.bench(preset, "seq=512 pallas attention", || {
                std::hint::black_box(art.exe.execute::<&Literal>(&args).unwrap());
            });
            measured.push((preset.to_string(), suite.last_mean_ms()));
        }
        suite.report();
        if let (Some(d), Some(p)) = (
            measured.iter().find(|(n, _)| n == "lra_dense").map(|(_, m)| *m),
            measured.iter().find(|(n, _)| n == "lra_pixelfly").map(|(_, m)| *m),
        ) {
            println!("\nmeasured forward speedup at seq=512: {:.2}x", d / p);
        }
    }

    // cost model across the LRA sequence lengths (paper: 1024-4096)
    println!("\ncost-model attention speedup by sequence length (b=32, d=64):");
    let dev = Device::with_block(32);
    println!("{:>8} {:>12} {:>14}", "seq", "pixelfly", "reformer-like");
    for seq in [1024usize, 2048, 4096] {
        let nb = seq / 32;
        let dense = attention_cost(&BlockMask::ones(nb, nb), 32, 64, 8, &dev);
        let pix = attention_cost(&baselines::pixelfly_attention_mask(nb, 4, 1), 32, 64, 8, &dev);
        let mut rng = Rng::new(1);
        let rf = attention_cost(&baselines::reformer_bucket_mask(nb, 8, &mut rng), 32, 64, 8, &dev);
        // reformer pays hash + gather ~2x on its visible blocks
        println!("{seq:>8} {:>11.1}x {:>13.2}x", dense.total / pix.total,
                 dense.total / (2.0 * rf.total));
    }
    println!("(paper Fig 9: Pixelfly 5.2x end-to-end, Reformer 0.8x)");
}
