//! Table 7: microbenchmark of sparsity patterns on a block device.
//!
//! For each pattern (random at group sizes 1..32, vanilla butterfly,
//! pixelfly), build the element mask at its *expected* density, take its
//! hardware block cover (32x32), and measure the BSR matmul latency on
//! the Rust substrate.  The paper's phenomenon: expected density can be
//! 1.25% while the cover ("actual density") is ~100%, so latency tracks
//! the cover, not the nominal density — and only block-aligned patterns
//! (pixelfly) stay fast.

use pixelfly::bench::BenchSuite;
use pixelfly::patterns::baselines::{random_grouped_mask, reformer_bucket_mask};
use pixelfly::patterns::butterfly::butterfly_factor_mask;
use pixelfly::patterns::flat_butterfly_mask;
use pixelfly::sparse::{BsrMatrix, Matrix};
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 1024); // paper uses 4096; scaled default
    let batch = args.usize_or("batch", 256);
    let hw = 32;
    let mut suite = BenchSuite::new("table7_microbench");
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);

    let mut run = |suite: &mut BenchSuite, name: String,
                   mask: &pixelfly::patterns::BlockMask| {
        let cover = mask.block_cover(hw, hw);
        let w = BsrMatrix::random(&cover, hw, 0.1, &mut Rng::new(1));
        let mut y = Matrix::zeros(batch, w.cols_elems());
        let note = format!("expected={:.2}% actual={:.2}%",
                           100.0 * mask.density(),
                           100.0 * mask.actual_density(hw));
        suite.bench(&name, &note, || {
            w.matmul_into(&x, &mut y);
            std::hint::black_box(&y);
        });
    };

    // dense reference
    {
        let w = Matrix::randn(n, n, 0.1, &mut Rng::new(2));
        let mut y = Matrix::zeros(batch, n);
        suite.bench("dense", "expected=100% actual=100%", || {
            pixelfly::sparse::dense::matmul_blocked_into(&x, &w, &mut y);
            std::hint::black_box(&y);
        });
    }

    // random masks at paper-style (group, expected-density) pairs
    for (g, dens) in [(1usize, 0.0125), (2, 0.025), (4, 0.05), (8, 0.20),
                      (16, 0.40), (32, 0.80)] {
        let m = random_grouped_mask(n, g, dens, &mut Rng::new(3));
        run(&mut suite, format!("random_{g}x{g}"), &m);
    }

    // vanilla (non-flat) butterfly: element-level factor masks, 1x1 blocks
    {
        let mut acc = pixelfly::patterns::BlockMask::zeros(n, n);
        let mut s = 2;
        while s <= n.min(64) {
            acc = acc.union(&butterfly_factor_mask(n, s));
            s *= 2;
        }
        run(&mut suite, "butterfly_1x1".into(), &acc);
    }

    // reformer-style bucketed mask (block-aligned but irregular)
    {
        let m = reformer_bucket_mask(n / hw, 4, &mut Rng::new(4)).expand(hw);
        run(&mut suite, "reformer_bucketed".into(), &m);
    }

    // pixelfly at multiple strides (block-aligned by construction)
    for ms in [2usize, 4, 8] {
        let m = flat_butterfly_mask(n / hw, ms).expand(hw);
        run(&mut suite, format!("pixelfly_stride{ms}"), &m);
    }

    let out = suite.report();
    // Table-7 sanity: pixelfly must beat the same-expected-density random
    let pix = suite.mean_ms_of("pixelfly_stride2").unwrap();
    let rnd = suite.mean_ms_of("random_1x1").unwrap();
    println!("\npixelfly_stride2 vs random_1x1 (same-order expected density): {:.1}x",
             rnd / pix);
    assert!(pix < rnd, "block-aligned pattern must be faster: {out}");
}
