//! Table 7: microbenchmark of sparsity patterns on a block device.
//!
//! For each pattern (random at group sizes 1..32, vanilla butterfly,
//! pixelfly), build the element mask at its *expected* density, take its
//! hardware block cover (32x32), and measure the BSR matmul latency on
//! the Rust substrate.  The paper's phenomenon: expected density can be
//! 1.25% while the cover ("actual density") is ~100%, so latency tracks
//! the cover, not the nominal density — and only block-aligned patterns
//! (pixelfly) stay fast.
//!
//! The trailing section measures the parallel tiled engine against the
//! serial reference on the headline configuration (4k×4k, block 32, 10%
//! block density) across thread counts, and the whole suite is written to
//! `BENCH_table7_microbench.json` for cross-PR perf tracking.

use pixelfly::bench::BenchSuite;
use pixelfly::patterns::baselines::{random_grouped_mask, random_mask, reformer_bucket_mask};
use pixelfly::patterns::butterfly::butterfly_factor_mask;
use pixelfly::patterns::flat_butterfly_mask;
use pixelfly::sparse::exec::{self, KernelChoice};
use pixelfly::sparse::{BsrMatrix, Matrix};
use pixelfly::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 1024); // paper uses 4096; scaled default
    let batch = args.usize_or("batch", 256);
    let hw = 32;
    let mut suite = BenchSuite::new("table7_microbench");
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);

    let mut run = |suite: &mut BenchSuite, name: String,
                   mask: &pixelfly::patterns::BlockMask| {
        let cover = mask.block_cover(hw, hw);
        let w = BsrMatrix::random(&cover, hw, 0.1, &mut Rng::new(1));
        let mut y = Matrix::zeros(batch, w.cols_elems());
        let note = format!("expected={:.2}% actual={:.2}%",
                           100.0 * mask.density(),
                           100.0 * mask.actual_density(hw));
        let flops = 2.0 * (batch * w.nnz_blocks()) as f64 * (hw * hw) as f64;
        suite.bench_with_flops(&name, &note, flops, || {
            w.matmul_into(&x, &mut y);
            std::hint::black_box(&y);
        });
    };

    // dense reference
    {
        let w = Matrix::randn(n, n, 0.1, &mut Rng::new(2));
        let mut y = Matrix::zeros(batch, n);
        let flops = 2.0 * (batch * n) as f64 * n as f64;
        suite.bench_with_flops("dense", "expected=100% actual=100%", flops, || {
            pixelfly::sparse::dense::matmul_blocked_into(&x, &w, &mut y);
            std::hint::black_box(&y);
        });
    }

    // random masks at paper-style (group, expected-density) pairs
    for (g, dens) in [(1usize, 0.0125), (2, 0.025), (4, 0.05), (8, 0.20),
                      (16, 0.40), (32, 0.80)] {
        let m = random_grouped_mask(n, g, dens, &mut Rng::new(3));
        run(&mut suite, format!("random_{g}x{g}"), &m);
    }

    // vanilla (non-flat) butterfly: element-level factor masks, 1x1 blocks
    {
        let mut acc = pixelfly::patterns::BlockMask::zeros(n, n);
        let mut s = 2;
        while s <= n.min(64) {
            acc = acc.union(&butterfly_factor_mask(n, s));
            s *= 2;
        }
        run(&mut suite, "butterfly_1x1".into(), &acc);
    }

    // reformer-style bucketed mask (block-aligned but irregular)
    {
        let m = reformer_bucket_mask(n / hw, 4, &mut Rng::new(4)).expand(hw);
        run(&mut suite, "reformer_bucketed".into(), &m);
    }

    // pixelfly at multiple strides (block-aligned by construction)
    for ms in [2usize, 4, 8] {
        let m = flat_butterfly_mask(n / hw, ms).expand(hw);
        run(&mut suite, format!("pixelfly_stride{ms}"), &m);
    }

    // --- parallel engine scaling: serial reference vs tiled engine ------
    // The acceptance configuration: 4k×4k, hardware block 32, 10% block
    // density. One plan per thread count, reused across iterations (the
    // intended steady-state usage).
    let scale_n = args.usize_or("scale-n", 4096);
    let scale_batch = args.usize_or("scale-batch", if suite.quick { 64 } else { 256 });
    // name of the SIMD-tier bench (when one ran), for the summary print
    let mut simd_tier_bench: Option<String> = None;
    {
        let nb = scale_n / hw;
        let mask = random_mask(nb, nb, 0.10, &mut Rng::new(5));
        let w = BsrMatrix::random(&mask, hw, 0.05, &mut Rng::new(6));
        let xs = Matrix::randn(scale_batch, scale_n, 1.0, &mut Rng::new(7));
        let mut y = Matrix::zeros(scale_batch, w.cols_elems());
        let flops = 2.0 * (scale_batch * w.nnz_blocks()) as f64 * (hw * hw) as f64;
        let note = format!("{scale_n}x{scale_n} b=32 10% batch={scale_batch}");
        let serial_name = "bsr4k_serial";
        suite.bench_with_flops(serial_name, &note, flops, || {
            w.matmul_serial_into(&xs, &mut y);
            std::hint::black_box(&y);
        });
        for threads in [1usize, 2, 4, 8] {
            let plan = w.plan(threads);
            suite.bench_with_flops(&format!("bsr4k_par{threads}"), &note, flops, || {
                w.matmul_with_plan(&plan, &xs, &mut y);
                std::hint::black_box(&y);
            });
        }

        // --- kernel dispatch tiers on the same headline configuration ---
        // forced-scalar vs the SIMD tier (acceptance target: simd >= 1.5x
        // scalar at 4k/b32/10% wherever AVX2 or NEON exists); the
        // operator's effective choice is snapshotted and restored so a
        // pinned PIXELFLY_KERNEL round-trips
        let prev_choice = exec::kernel_choice();
        let plan = w.plan(exec::threads());
        exec::set_kernel(KernelChoice::Scalar);
        suite.bench_with_flops("bsr4k_tier_scalar", &note, flops, || {
            w.matmul_with_plan(&plan, &xs, &mut y);
            std::hint::black_box(&y);
        });
        if exec::simd_available() {
            exec::set_kernel(KernelChoice::Simd);
            let name = format!("bsr4k_tier_{}", exec::kernel_name());
            suite.bench_with_flops(&name, &note, flops, || {
                w.matmul_with_plan(&plan, &xs, &mut y);
                std::hint::black_box(&y);
            });
            simd_tier_bench = Some(name);
        }
        exec::set_kernel(prev_choice);
    }

    let out = suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }

    let ser = suite.mean_ms_of("bsr4k_serial").unwrap();
    let par8 = suite.mean_ms_of("bsr4k_par8").unwrap();
    println!("\nparallel engine speedup at 8 threads (4k, b=32, 10%): {:.2}x",
             ser / par8);

    if let Some(name) = &simd_tier_bench {
        let sc = suite.mean_ms_of("bsr4k_tier_scalar").unwrap();
        let sm = suite.mean_ms_of(name).unwrap();
        println!("simd tier ({name}) vs scalar tier (4k, b=32, 10%): {:.2}x \
                  (acceptance target >= 1.5x)", sc / sm);
    } else {
        println!("no SIMD tier on this host; scalar tier only");
    }

    // Table-7 sanity: pixelfly must beat the same-expected-density random
    let pix = suite.mean_ms_of("pixelfly_stride2").unwrap();
    let rnd = suite.mean_ms_of("random_1x1").unwrap();
    println!("pixelfly_stride2 vs random_1x1 (same-order expected density): {:.1}x",
             rnd / pix);
    assert!(pix < rnd, "block-aligned pattern must be faster: {out}");
}
