//! Distributed-training scaling bench (PR 8): whole localhost fleets at
//! ranks {1, 2, 4}, measuring per-round step time and end-to-end
//! samples/s through the full PXD1 path — compile, admission, chunked
//! CRC'd gradient exchange, rank-ordered averaging, broadcast.
//!
//! The substrate pool is pinned to one thread per dispatch so rank
//! count IS the parallelism: on a multi-core host the 2-rank fleet must
//! beat the 1-rank fleet on samples/s (hard assert — data parallelism
//! that loses to a single process is a bug, not a tuning issue). The
//! 4-rank row is reported for the scaling curve but not asserted: CI
//! boxes routinely have 2 cores.
//!
//! A second section reruns the 2-rank fleet with the overlap scheduler
//! pinned off and pinned to dw+comm and compares the workers' exposed
//! upload time per round (`comm_exposed_ms`): per-bucket gradient
//! streaming behind the backward pass must hide wire time the serial
//! schedule pays in the open.

use std::time::Instant;

use pixelfly::bench::{BenchResult, BenchSuite};
use pixelfly::coordinator::budget::rule_of_thumb;
use pixelfly::costmodel::Device;
use pixelfly::dist::{self, DistConfig, WorkerConfig};
use pixelfly::models::preset;
use pixelfly::nn::{compile, Model};
use pixelfly::sparse::exec;
use pixelfly::util::stats::Summary;

const BLOCK: usize = 16;
const SEED: u64 = 42;

fn compile_gpt2s() -> Model {
    let schema = preset("gpt2-s", 1).expect("gpt2-s preset");
    let dev = Device::with_block(BLOCK);
    let alloc = rule_of_thumb(&schema, 0.2, &dev);
    compile(&schema, &alloc, BLOCK, SEED).expect("compile gpt2-s")
}

fn main() {
    let mut suite = BenchSuite::new("dist_scaling");
    // one pool thread per dispatch: each worker thread computes serially,
    // so fleet size is the only parallelism being measured
    exec::set_threads(1);
    let rounds: u64 = if suite.quick { 5 } else { 15 };
    let rows = compile_gpt2s().seq;
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());

    let mut samples_per_s: Vec<(u32, f64)> = Vec::new();
    for nranks in [1u32, 2, 4] {
        let dist = DistConfig::new(nranks, rounds);
        let fleet: Vec<(Model, WorkerConfig)> = (0..nranks)
            .map(|i| {
                (compile_gpt2s(),
                 WorkerConfig::new("", &format!("bench-dist-r{nranks}-w{i}")))
            })
            .collect();
        let t0 = Instant::now();
        let (coord, workers) = dist::run_local(dist, fleet).expect("fleet run");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(coord.rounds, rounds, "{nranks} ranks: all rounds complete");
        assert!(coord.excluded.is_empty(), "{nranks} ranks: no exclusions");
        for w in workers {
            let w = w.expect("worker");
            assert_eq!(w.losses.len(), rounds as usize);
            assert!(w.losses.iter().all(|l| l.is_finite()));
        }

        let samples = (rounds * u64::from(nranks) * rows as u64) as f64;
        let sps = samples / wall;
        let step_ms = wall * 1e3 / rounds as f64;
        samples_per_s.push((nranks, sps));
        let mut ns = vec![wall * 1e9 / rounds as f64];
        suite.results.push(BenchResult {
            name: format!("step_time_ranks{nranks}"),
            summary: Summary::from_ns(&mut ns),
            gflops: None,
            scratch_bytes: None,
            phases: None,
            bytes_moved: None,
            note: format!("{rounds} rounds, {rows} rows/rank/round, \
                           {sps:.0} samples/s, pool=1 thread"),
        });
        println!("ranks={nranks}: {step_ms:.2} ms/round, {sps:.0} samples/s \
                  ({rounds} rounds, global batch {} rows)",
                 rows * nranks as usize);
    }

    // --- comm/compute overlap: exposed upload time at 2 ranks ----------
    // The scaling loop above runs under the session default (dw+comm).
    // Here the same 2-rank fleet is rerun with the overlap scheduler
    // pinned off and pinned to dw+comm, and the workers' mean exposed
    // upload time per round is compared: streaming gradient buckets
    // behind the backward pass must hide most of the wire time that the
    // serial schedule pays after bwd_done.
    let mut exposed_ms: Vec<(&str, f64)> = Vec::new();
    for (tag, mode) in [("off", exec::OverlapMode::Off),
                        ("dw+comm", exec::OverlapMode::DwComm)] {
        exec::set_overlap(Some(mode));
        let dist = DistConfig::new(2, rounds);
        let fleet: Vec<(Model, WorkerConfig)> = (0..2)
            .map(|i| {
                (compile_gpt2s(),
                 WorkerConfig::new("", &format!("bench-dist-ov{i}")))
            })
            .collect();
        let t0 = Instant::now();
        let (coord, workers) =
            dist::run_local(dist, fleet).expect("overlap fleet run");
        let wall = t0.elapsed().as_secs_f64();
        assert!(coord.excluded.is_empty(), "overlap={tag}: no exclusions");
        let mut sum = 0.0;
        let mut cnt = 0.0;
        for w in workers {
            let w = w.expect("worker");
            assert!(w.comm_exposed_ms.is_finite() && w.comm_exposed_ms >= 0.0);
            sum += w.comm_exposed_ms;
            cnt += 1.0;
        }
        let ce = sum / cnt;
        exposed_ms.push((tag, ce));
        let mut ns = vec![wall * 1e9 / rounds as f64];
        let safe = if tag == "off" { "off" } else { "dwcomm" };
        suite.results.push(BenchResult {
            name: format!("comm_overlap_{safe}_ranks2"),
            summary: Summary::from_ns(&mut ns),
            gflops: None,
            scratch_bytes: None,
            phases: None,
            bytes_moved: None,
            note: format!("{rounds} rounds, comm_exposed_ms={ce:.3} \
                           (mean per worker per round), overlap={tag}"),
        });
        println!("overlap={tag}: comm_exposed {ce:.3} ms/round at 2 ranks");
    }
    exec::set_overlap(None);
    let off_ms = exposed_ms[0].1;
    let ov_ms = exposed_ms[1].1;
    println!("overlap hides {:.3} ms/round of upload ({off_ms:.3} -> {ov_ms:.3})",
             off_ms - ov_ms);
    if suite.quick {
        // quick rounds are few and loopback timings jittery: only guard
        // against overlap making the exposed time meaningfully WORSE
        assert!(ov_ms <= off_ms * 1.5 + 0.5,
                "dw+comm must not inflate exposed upload time at 2 ranks \
                 ({ov_ms:.3} ms vs {off_ms:.3} ms serial)");
    } else {
        assert!(ov_ms < off_ms,
                "dw+comm must expose less upload time than the serial \
                 schedule at 2 ranks ({ov_ms:.3} ms vs {off_ms:.3} ms)");
    }

    let sps1 = samples_per_s[0].1;
    let sps2 = samples_per_s[1].1;
    println!("scaling: ranks2/ranks1 = {:.2}x (host has {cores} cores)",
             sps2 / sps1);
    if cores >= 2 {
        // the acceptance test for the whole subsystem: adding a worker
        // must add throughput, allreduce overhead included
        assert!(sps2 > sps1,
                "2-rank fleet must out-throughput 1 rank on a {cores}-core \
                 host ({sps2:.0} vs {sps1:.0} samples/s)");
    } else {
        println!("single-core host: skipping the ranks2 > ranks1 assert");
    }

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
