//! End-to-end compiled-model bench: the whole `preset → budget →
//! compile → train_step` pipeline on the substrate, per testbed preset —
//! the fig8-style measurement for models the compiler assembled rather
//! than hand-built layer chains.
//!
//! Per preset (quick mode: vit-s + gpt2-s at one budget) the suite times
//! the fused train step (fwd+bwd+update over one sequence) and the
//! frozen InferenceSession forward, both with GFLOP/s from the Module
//! flop accounting and the peak workspace bytes column. Hard asserts
//! enforce the compiled-model contract: zero workspace allocations in
//! the steady state for BOTH paths (the session's `run` additionally
//! self-asserts), and a decreasing loss across the timed train steps.

use pixelfly::bench::BenchSuite;
use pixelfly::coordinator::budget::rule_of_thumb;
use pixelfly::costmodel::Device;
use pixelfly::models::preset;
use pixelfly::nn::compile;
use pixelfly::sparse::exec;
use pixelfly::sparse::Matrix;
use pixelfly::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("e2e_compiled_models");
    let block = 16usize;
    let dev = Device::with_block(block);
    let threads = exec::threads();
    let kernel = exec::kernel_name();
    let presets: &[&str] = if suite.quick {
        &["vit-s", "gpt2-s"]
    } else {
        &["vit-s", "mixer-s", "gpt2-s"]
    };
    let budgets: &[f64] = if suite.quick { &[0.2] } else { &[0.1, 0.2] };

    for &name in presets {
        for &budget in budgets {
            let schema = preset(name, 1).expect("testbed preset");
            let alloc = rule_of_thumb(&schema, budget, &dev);
            let mut model = compile(&schema, &alloc, block, 42).expect("compile");
            let mut rng = Rng::new(9);
            let x = Matrix::randn(model.seq, model.in_dim(), 1.0, &mut rng);
            let t = Matrix::randn(model.seq, model.out_dim(), 0.5, &mut rng);
            let fl = model.flops();
            let note = format!(
                "seq={} d={} params={} kept={:.1}% threads={threads} {kernel}",
                model.seq,
                schema.d_model,
                model.param_count(),
                100.0 * model.stats.sparsification_ratio(),
            );
            let tag = format!("{name}_d{:02}", (budget * 100.0) as usize);

            // --- fused train step -------------------------------------
            let (first_loss, _) = model.train_step(&x, &t, 1e-3, 0.9); // warmup
            let warm = model.alloc_events();
            let mut last_loss = first_loss;
            suite.bench_with_flops(&format!("{tag}_train"), &note, fl.total(), || {
                let (loss, _) = model.train_step(&x, &t, 1e-3, 0.9);
                last_loss = loss;
                std::hint::black_box(loss);
            });
            assert_eq!(model.alloc_events(), warm,
                       "{tag}: steady-state train_step must not allocate");
            assert!(last_loss.is_finite() && last_loss < first_loss,
                    "{tag}: training must reduce the fixed-batch loss \
                     ({first_loss} -> {last_loss})");
            suite.set_scratch_bytes(model.peak_scratch_bytes());

            // --- frozen inference session -----------------------------
            // strict(): benches keep the old hard-assert contract; serving
            // callers get typed Err instead
            let mut sess = model.into_inference().strict();
            assert_eq!(sess.training_state_bytes(), 0,
                       "{tag}: freeze must shed gradient/momentum buffers");
            sess.run(&x).unwrap(); // warmup pass sets the rows envelope
            let warm = sess.alloc_events();
            suite.bench_with_flops(&format!("{tag}_infer"), &note, fl.fwd, || {
                std::hint::black_box(sess.run(&x).unwrap().data[0]);
            });
            assert_eq!(sess.alloc_events(), warm,
                       "{tag}: steady-state inference must not allocate");
            suite.set_scratch_bytes(sess.peak_scratch_bytes());
        }
    }

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
