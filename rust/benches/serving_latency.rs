//! Serving-latency bench (PR 6): the KV-cache and continuous-batching
//! contracts, measured.
//!
//! Part A — single-request decode on gpt2-s:
//!   * per-token KV decode latency must be FLAT in sequence position
//!     (hard assert: last-quartile step mean ≤ 3.5× first-quartile) —
//!     the O(seq)-per-token story vs O(seq²) re-prefill
//!   * `kv_decode_gen` vs `reprefill_gen`: one full generation through the
//!     incremental path vs re-running the whole-sequence InferenceSession
//!     per token; KV must win (hard assert)
//!
//! Part B — continuous batching at concurrency 1/4/16: client threads
//! hammer the engine directly (no TCP, so the numbers isolate the
//! batching loop); each row reports tokens/s + request-latency
//! p50/p90/p99. Hard assert: throughput at concurrency 4 beats serial
//! one-at-a-time (concurrency 1).

use std::thread;
use std::time::Instant;

use pixelfly::bench::{BenchResult, BenchSuite};
use pixelfly::coordinator::budget::rule_of_thumb;
use pixelfly::costmodel::Device;
use pixelfly::models::preset;
use pixelfly::nn::{compile, DecodeSession, InferenceSession, Model};
use pixelfly::serving::{percentile, EngineConfig, ServeEngine};
use pixelfly::sparse::exec;
use pixelfly::sparse::Matrix;
use pixelfly::util::stats::Summary;
use pixelfly::util::Rng;

const BLOCK: usize = 16;
const SEED: u64 = 42;
const PROMPT_ROWS: usize = 8;

fn compile_gpt2s() -> Model {
    let schema = preset("gpt2-s", 1).expect("gpt2-s preset");
    let dev = Device::with_block(BLOCK);
    let alloc = rule_of_thumb(&schema, 0.2, &dev);
    compile(&schema, &alloc, BLOCK, SEED).expect("compile gpt2-s")
}

/// One greedy generation through the KV decode path; optionally records
/// per-step wall times. Returns a value sink so the work can't be DCE'd.
fn kv_generate(sess: &mut DecodeSession, prompt: &Matrix, gen: usize,
               mut step_ns: Option<&mut Vec<f64>>) -> f32 {
    let d = sess.out_dim();
    let mut x = Matrix::zeros(1, d);
    let mut last = vec![0.0f32; d];
    let mut acc = 0.0f32;
    for pos in 0..prompt.rows + gen - 1 {
        let src: &[f32] = if pos < prompt.rows { prompt.row(pos) } else { &last };
        x.row_mut(0).copy_from_slice(src);
        let t0 = Instant::now();
        let y = sess.step(&x, &[0], &[pos]).expect("decode step");
        let dt = t0.elapsed().as_nanos() as f64;
        if pos + 1 >= prompt.rows {
            last.copy_from_slice(y.row(0));
            acc += last[0];
        }
        if let Some(v) = step_ns.as_deref_mut() {
            v.push(dt);
        }
    }
    acc
}

/// The no-KV-cache baseline: re-run the whole-sequence forward for every
/// generated token and read one row. Causality makes the zero rows past
/// the current position irrelevant to the row we read.
fn reprefill_generate(sess: &mut InferenceSession, seq: usize, prompt: &Matrix,
                      gen: usize) -> f32 {
    let d = prompt.cols;
    let mut buf = Matrix::zeros(seq, d);
    for r in 0..prompt.rows {
        buf.row_mut(r).copy_from_slice(prompt.row(r));
    }
    let mut acc = 0.0f32;
    for t in 0..gen {
        let pos = prompt.rows - 1 + t;
        let next = sess.run(&buf).expect("prefill run").row(pos).to_vec();
        if pos + 1 < seq {
            buf.row_mut(pos + 1).copy_from_slice(&next);
        }
        acc += next[0];
    }
    acc
}

fn main() {
    let mut suite = BenchSuite::new("serving_latency");
    let threads = exec::threads();
    let kernel = exec::kernel_name();
    let gen = if suite.quick { 48 } else { 96 };
    let mut rng = Rng::new(SEED ^ 0xBE);

    // ---- Part A: single-request decode ---------------------------------
    let model = compile_gpt2s();
    let stats = model.stats;
    let mut sess = model.into_decode(1).expect("gpt2-s decodes").strict();
    let (d, seq) = (sess.in_dim(), sess.max_seq());
    let prompt = Matrix::randn(PROMPT_ROWS, d, 1.0, &mut rng);
    let note = format!("seq={seq} d={d} prompt={PROMPT_ROWS} gen={gen} \
                        threads={threads} {kernel}");

    std::hint::black_box(kv_generate(&mut sess, &prompt, gen, None)); // warm
    let mut step_ns: Vec<f64> = Vec::new();
    std::hint::black_box(kv_generate(&mut sess, &prompt, gen, Some(&mut step_ns)));
    let q = (step_ns.len() / 4).max(1);
    let head = step_ns[..q].iter().sum::<f64>() / q as f64;
    let tail = step_ns[step_ns.len() - q..].iter().sum::<f64>() / q as f64;
    println!("decode step latency: first-quartile {:.1}us, last-quartile {:.1}us \
              ({} steps)", head / 1e3, tail / 1e3, step_ns.len());
    assert!(tail <= 3.5 * head,
            "per-token KV decode latency must stay flat in position \
             (first-quartile {:.1}us vs last-quartile {:.1}us)",
            head / 1e3, tail / 1e3);

    suite.bench("kv_decode_gen", &note, || {
        std::hint::black_box(kv_generate(&mut sess, &prompt, gen, None));
    });
    suite.set_scratch_bytes(sess.peak_scratch_bytes());
    // weight-traffic model for the GB/s column: every decode step streams
    // the full parameter set once; f32 stores every weight at 4B
    let steps_per_gen = (PROMPT_ROWS + gen - 1) as f64;
    let f32_weight_bytes = 4.0 * stats.total_params() as f64;
    suite.set_bytes_moved(steps_per_gen * f32_weight_bytes);
    let kv_ms = suite.last_mean_ms();

    let mut full = compile_gpt2s().into_inference().strict();
    reprefill_generate(&mut full, seq, &prompt, 2); // warm the rows envelope
    suite.bench("reprefill_gen", &note, || {
        std::hint::black_box(reprefill_generate(&mut full, seq, &prompt, gen));
    });
    suite.set_scratch_bytes(full.peak_scratch_bytes());
    let reprefill_ms = suite.last_mean_ms();
    assert!(kv_ms < reprefill_ms,
            "KV-cached decode must beat re-prefill generation \
             ({kv_ms:.2}ms vs {reprefill_ms:.2}ms for {gen} tokens)");
    drop(sess);

    // ---- Part A2: int8 quantized decode vs the f32 tier ----------------
    // `serve --precision int8` end to end: compile fresh under the int8
    // tier (quantize-at-freeze converts every block-sparse weight to
    // per-block int8 + scale inside into_decode) and run the SAME
    // generation. strict() keeps the zero-alloc steady-state contract a
    // hard assert on this tier too — quantized execution must not
    // introduce allocations. Batch-1 decode is memory-bound, so the 4x
    // smaller sparsified weight stream must not lose throughput.
    exec::set_precision(exec::Precision::Int8);
    let mut q_sess = compile_gpt2s().into_decode(1).expect("int8 decode").strict();
    std::hint::black_box(kv_generate(&mut q_sess, &prompt, gen, None)); // warm
    suite.bench("kv_decode_gen_int8", &format!("{note} precision=int8"), || {
        std::hint::black_box(kv_generate(&mut q_sess, &prompt, gen, None));
    });
    suite.set_scratch_bytes(q_sess.peak_scratch_bytes());
    // int8 streams sparsified weights at 1B (+ one f32 scale per b² block);
    // dense-kept embedding/head/bias weights stay f32
    let int8_weight_bytes = stats.sparsified_weight_params as f64
        * (1.0 + 4.0 / (BLOCK * BLOCK) as f64)
        + 4.0 * (stats.total_params() - stats.sparsified_weight_params) as f64;
    suite.set_bytes_moved(steps_per_gen * int8_weight_bytes);
    let int8_ms = suite.last_mean_ms();
    drop(q_sess);
    exec::set_precision(exec::Precision::F32);
    let tokens = (PROMPT_ROWS + gen - 1) as f64;
    let (f32_tps, int8_tps) = (tokens / (kv_ms / 1e3), tokens / (int8_ms / 1e3));
    println!("decode tokens/s: f32 {f32_tps:.1}, int8 {int8_tps:.1}");
    assert!(int8_tps >= f32_tps,
            "int8 decode tokens/s must be >= f32 decode tokens/s \
             ({int8_tps:.1} vs {f32_tps:.1})");

    // ---- Part B: continuous batching vs concurrency --------------------
    let reqs_per_client = if suite.quick { 2 } else { 4 };
    const BGEN: usize = 16;
    let mut tps: Vec<f64> = Vec::new();
    for &c in &[1usize, 4, 16] {
        let dsess = compile_gpt2s().into_decode(c).expect("decode session");
        let engine = ServeEngine::start(
            dsess,
            EngineConfig { max_batch: c, queue_depth: 64 },
        );
        let h0 = engine.handle();
        let wall0 = Instant::now();
        let workers: Vec<_> = (0..c)
            .map(|ci| {
                let h = h0.clone();
                thread::spawn(move || {
                    let d = h.d();
                    let mut lats = Vec::with_capacity(reqs_per_client);
                    for r in 0..reqs_per_client {
                        let mut rng = Rng::new(7000 + (ci * 100 + r) as u64);
                        let p = Matrix::randn(PROMPT_ROWS, d, 1.0, &mut rng);
                        let t0 = Instant::now();
                        std::hint::black_box(h.generate(p, BGEN).expect("generate"));
                        lats.push(t0.elapsed().as_nanos() as f64);
                    }
                    lats
                })
            })
            .collect();
        let mut lat_ns: Vec<f64> = Vec::new();
        for w in workers {
            lat_ns.extend(w.join().expect("client thread"));
        }
        let wall_s = wall0.elapsed().as_secs_f64();
        engine.shutdown();
        let reqs = c * reqs_per_client;
        let tokens_per_s = (reqs * BGEN) as f64 / wall_s;
        tps.push(tokens_per_s);
        let mut sorted = lat_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        suite.results.push(BenchResult {
            name: format!("continuous_batch_c{c:02}"),
            summary: Summary::from_ns(&mut lat_ns),
            gflops: None,
            scratch_bytes: None,
            phases: None,
            bytes_moved: None,
            note: format!(
                "tokens/s={:.1} p50={:.2}ms p90={:.2}ms p99={:.2}ms reqs={reqs} \
                 gen={BGEN} threads={threads}",
                tokens_per_s,
                percentile(&sorted, 0.50) / 1e6,
                percentile(&sorted, 0.90) / 1e6,
                percentile(&sorted, 0.99) / 1e6,
            ),
        });
    }
    assert!(tps[1] > tps[0],
            "continuous batching at concurrency 4 must out-throughput serial \
             one-at-a-time ({:.1} vs {:.1} tokens/s)", tps[1], tps[0]);

    suite.report();
    match suite.write_json_default() {
        Ok(p) => println!("json -> {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    println!("\nserving contract: per-token decode flat in position \
              ({:.1}us -> {:.1}us), KV beats re-prefill ({kv_ms:.2}ms vs \
              {reprefill_ms:.2}ms), batching c=4 beats serial ({:.1} vs {:.1} \
              tok/s).",
             head / 1e3, tail / 1e3, tps[1], tps[0]);
}
