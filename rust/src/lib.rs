//! Pixelated Butterfly (ICLR 2022) — Layer-3 Rust coordinator.
//!
//! This crate is the runtime half of the three-layer reproduction (see
//! DESIGN.md): JAX/Pallas author the compute at build time and lower it to
//! HLO text; this crate loads those artifacts over the PJRT C API (`xla`
//! crate), owns the training loop, the paper's budget-allocation and
//! mask-selection logic, the hardware cost model, the NTK-guided pattern
//! search, the baselines (RigL, butterfly product), the synthetic data
//! substrates, and the pure-Rust block-sparse compute substrate used for
//! the microbenchmarks.
//!
//! Python never runs on the hot path: after `make artifacts` the binary is
//! self-contained.
//!
//! Module map (one subsystem per module; DESIGN.md "System inventory"):
//! - [`patterns`]   block masks: butterfly, flat butterfly, baselines, covers
//! - [`costmodel`]  Appendix-A hardware cost model (block memory access)
//! - [`sparse`]     pure-Rust BSR GEMM substrate (Table 7 / Fig 11 testbed)
//! - [`models`]     model schemas, presets, parameter/FLOP accounting
//! - [`nn`]         Module API + model compiler: composable blocks,
//!   `Sequential`, `compile(schema, alloc, …) -> Model`, inference sessions
//! - [`data`]       synthetic vision / corpus / LRA workloads
//! - [`runtime`]    PJRT engine: manifest, executables, device buffers
//! - [`coordinator`] budget allocation, mask planning, the training loop
//! - [`ntk`]        empirical-NTK distance + Algorithm-2 pattern search
//! - [`rigl`]       RigL dynamic-sparsity baseline (Fig 6)
//! - [`serving`]    continuous-batching serving runtime: KV-cached decode,
//!   admission queue, TCP front end, latency metrics
//! - [`ckpt`]       crash-safe checkpoint layer: PXCK weight format, atomic
//!   background snapshots, corruption-checked load, fault injection
//! - [`dist`]       fault-tolerant data-parallel training: PXD1 TCP
//!   allreduce, crash detection, checkpoint-based elastic recovery
//! - [`util`]       PRNG, timers, stats, CLI & property-test helpers
//! - [`bench`]      in-crate micro-benchmark harness (criterion substitute)

pub mod bench;
pub mod ckpt;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod dist;
pub mod models;
pub mod nn;
pub mod ntk;
pub mod patterns;
pub mod rigl;
pub mod runtime;
pub mod serving;
pub mod sparse;
pub mod util;
