//! In-crate micro-benchmark harness (offline substitute for criterion).
//!
//! Each `rust/benches/*.rs` binary (registered with `harness = false`)
//! builds a `BenchSuite`, registers closures, and calls `run()`, which
//! warms up, samples, and prints a fixed-width table plus TSV lines that
//! EXPERIMENTS.md ingests.  `--quick` (or PIXELFLY_BENCH_QUICK=1) shrinks
//! iteration counts so `cargo bench` stays tractable on CI.
//!
//! For cross-PR perf tracking, [`BenchSuite::write_json_default`] emits a
//! machine-readable `BENCH_<title>.json` (name, mean/p50/p95 ms, GFLOP/s
//! when the bench registered its flop count, note) that CI uploads as an
//! artifact.

use crate::util::stats::{time_it, Summary};
use crate::util::Args;

/// Per-phase columns for train-step benches (forward / backward /
/// optimizer update). Lives in the shared formatter so every suite that
/// measures phases — fig1 today, anything later — renders identically
/// (no per-bench ad-hoc columns).
#[derive(Clone, Copy, Debug)]
pub struct PhaseCols {
    pub fwd_ms: f64,
    pub bwd_ms: f64,
    pub update_ms: f64,
    /// per-phase GFLOP/s when the bench registered per-phase flop counts
    pub fwd_gflops: Option<f64>,
    pub bwd_gflops: Option<f64>,
    pub update_gflops: Option<f64>,
}

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// achieved GFLOP/s (mean), when the bench registered its flop count
    pub gflops: Option<f64>,
    /// peak scratch bytes the benched path touched (workspace-tracked),
    /// when the bench registered it — the fused-attention bench uses this
    /// column to prove the O(block²) scratch bound
    pub scratch_bytes: Option<usize>,
    /// fwd/bwd/update split, when the bench measured one
    pub phases: Option<PhaseCols>,
    /// bytes one invocation reads + writes, when the bench registered it
    /// — the memory-traffic twin of the flop count; the report derives
    /// an achieved-GB/s column from it (what reduced-precision tiers are
    /// supposed to move, so fig1/fig7/serving make the storage win
    /// visible, not just the latency)
    pub bytes_moved: Option<f64>,
    /// optional user metric (e.g. speedup baseline id)
    pub note: String,
}

impl BenchResult {
    /// Achieved GB/s (`bytes_moved` over mean time), when registered.
    /// bytes/ns ≡ GB/s, so no unit factor appears.
    pub fn gbps(&self) -> Option<f64> {
        self.bytes_moved
            .filter(|_| self.summary.mean_ns > 0.0)
            .map(|b| b / self.summary.mean_ns)
    }
}

pub struct BenchSuite {
    pub title: String,
    pub warmup: usize,
    pub iters: usize,
    /// quick/smoke mode (--quick or PIXELFLY_BENCH_QUICK=1): benches may
    /// also shrink their problem sizes, not just the iteration counts
    pub quick: bool,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let args = Args::from_env();
        let quick = args.bool("quick")
            || std::env::var("PIXELFLY_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let (warmup, iters) = if quick { (1, 3) } else { (3, 10) };
        BenchSuite {
            title: title.to_string(),
            warmup: args.usize_or("warmup", warmup),
            iters: args.usize_or("iters", iters),
            quick,
            results: Vec::new(),
        }
    }

    /// Benchmark a closure; `note` is free-form context for the table.
    pub fn bench<F: FnMut()>(&mut self, name: &str, note: &str, f: F) -> &Summary {
        let summary = time_it(self.warmup, self.iters, f);
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            gflops: None,
            scratch_bytes: None,
            phases: None,
            bytes_moved: None,
            note: note.to_string(),
        });
        &self.results.last().unwrap().summary
    }

    /// Attach a peak-scratch-bytes measurement to the most recent result
    /// (rendered as a table/TSV/JSON column).
    pub fn set_scratch_bytes(&mut self, bytes: usize) {
        if let Some(r) = self.results.last_mut() {
            r.scratch_bytes = Some(bytes);
        }
    }

    /// Attach the bytes one invocation reads + writes to the most recent
    /// result; table/TSV/JSON gain an achieved-GB/s column derived from
    /// it. One shared column definition serves every suite that wants a
    /// bandwidth story (fig1, fig7, serving_latency).
    pub fn set_bytes_moved(&mut self, bytes: f64) {
        if let Some(r) = self.results.last_mut() {
            r.bytes_moved = Some(bytes);
        }
    }

    /// Attach a fwd/bwd/update phase split (mean ms per phase) to the
    /// most recent result; `flops` per phase, when given, adds per-phase
    /// GFLOP/s to the JSON. One formatter serves every phase-measuring
    /// bench.
    pub fn set_phase_split(&mut self, ms: [f64; 3], flops: Option<[f64; 3]>) {
        if let Some(r) = self.results.last_mut() {
            let gf = |ms: f64, fl: Option<f64>| {
                fl.filter(|_| ms > 0.0).map(|f| f / (ms * 1e6))
            };
            r.phases = Some(PhaseCols {
                fwd_ms: ms[0],
                bwd_ms: ms[1],
                update_ms: ms[2],
                fwd_gflops: gf(ms[0], flops.map(|f| f[0])),
                bwd_gflops: gf(ms[1], flops.map(|f| f[1])),
                update_gflops: gf(ms[2], flops.map(|f| f[2])),
            });
        }
    }

    /// Benchmark a closure whose one invocation performs `flops` floating
    /// point operations; the report and JSON gain a GFLOP/s column.
    pub fn bench_with_flops<F: FnMut()>(&mut self, name: &str, note: &str,
                                        flops: f64, f: F) -> &Summary {
        self.bench(name, note, f);
        let last = self.results.last_mut().unwrap();
        last.gflops = Some(flops / last.summary.mean_ns);
        &self.results.last().unwrap().summary
    }

    pub fn last_mean_ms(&self) -> f64 {
        self.results.last().map(|r| r.summary.mean_ms()).unwrap_or(f64::NAN)
    }

    /// Mean time of a named result (for speedup columns).
    pub fn mean_ms_of(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.summary.mean_ms())
    }

    /// Print the table; returns it as a string too (for tee-ing). Phase
    /// columns (fwd/bwd/upd) render only when some result measured them,
    /// so phase-free suites keep their existing layout.
    pub fn report(&self) -> String {
        let has_phases = self.results.iter().any(|r| r.phases.is_some());
        let has_bw = self.results.iter().any(|r| r.bytes_moved.is_some());
        let mut out = String::new();
        out.push_str(&format!("\n=== {} (warmup={} iters={}) ===\n",
                              self.title, self.warmup, self.iters));
        let phase_hdr = if has_phases {
            format!(" {:>9} {:>9} {:>9}", "fwd", "bwd", "upd")
        } else {
            String::new()
        };
        let bw_hdr = if has_bw {
            format!(" {:>8}", "GB/s")
        } else {
            String::new()
        };
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>9} {:>11}{phase_hdr}{bw_hdr}  note\n",
            "benchmark", "mean", "p50", "p95", "gflops", "scratch"));
        for r in &self.results {
            let gf = r.gflops.map(|g| format!("{g:>9.2}")).unwrap_or_else(|| " ".repeat(9));
            let sb = r
                .scratch_bytes
                .map(|b| format!("{:>10}B", b))
                .unwrap_or_else(|| " ".repeat(11));
            let ph = if has_phases {
                match &r.phases {
                    Some(p) => format!(" {:>7.2}ms {:>7.2}ms {:>7.2}ms",
                                       p.fwd_ms, p.bwd_ms, p.update_ms),
                    None => " ".repeat(30),
                }
            } else {
                String::new()
            };
            let bw = if has_bw {
                match r.gbps() {
                    Some(g) => format!(" {g:>8.2}"),
                    None => " ".repeat(9),
                }
            } else {
                String::new()
            };
            out.push_str(&format!(
                "{:<44} {:>10.3}ms {:>10.3}ms {:>10.3}ms {gf} {sb}{ph}{bw}  {}\n",
                r.name,
                r.summary.mean_ms(),
                r.summary.p50_ns / 1e6,
                r.summary.p95_ns / 1e6,
                r.note
            ));
        }
        // machine-readable lines (new columns appended last so existing
        // TSV consumers keep their column positions: ..., scratch, fwd,
        // bwd, upd, gbps)
        for r in &self.results {
            let sb = r.scratch_bytes.map(|b| b.to_string()).unwrap_or_default();
            let ph = r
                .phases
                .map(|p| format!("\t{:.6}\t{:.6}\t{:.6}", p.fwd_ms, p.bwd_ms, p.update_ms))
                .unwrap_or_default();
            let bw = r.gbps().map(|g| format!("\t{g:.4}")).unwrap_or_default();
            out.push_str(&format!("TSV\t{}\t{}\t{:.6}\t{:.6}\t{}\t{}{}{}\n",
                                  self.title, r.name, r.summary.mean_ms(),
                                  r.summary.p50_ns / 1e6, r.note, sb, ph, bw));
        }
        print!("{out}");
        out
    }

    /// Machine-readable JSON for CI perf tracking.
    pub fn json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"title\": \"{}\",\n", escape(&self.title)));
        out.push_str(&format!("  \"warmup\": {},\n  \"iters\": {},\n",
                              self.warmup, self.iters));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            let gf = r.gflops.map(|g| format!("{g:.4}")).unwrap_or_else(|| "null".into());
            let sb = r
                .scratch_bytes
                .map(|b| b.to_string())
                .unwrap_or_else(|| "null".into());
            let opt = |v: Option<f64>| v.map(|g| format!("{g:.4}")).unwrap_or_else(|| "null".into());
            let ph = match &r.phases {
                Some(p) => format!(
                    ", \"fwd_ms\": {:.6}, \"bwd_ms\": {:.6}, \"update_ms\": {:.6}, \
                     \"fwd_gflops\": {}, \"bwd_gflops\": {}, \"update_gflops\": {}",
                    p.fwd_ms,
                    p.bwd_ms,
                    p.update_ms,
                    opt(p.fwd_gflops),
                    opt(p.bwd_gflops),
                    opt(p.update_gflops)
                ),
                None => String::new(),
            };
            let bw = r
                .bytes_moved
                .map(|b| format!("{b:.0}"))
                .unwrap_or_else(|| "null".into());
            let gbps = r.gbps().map(|g| format!("{g:.4}")).unwrap_or_else(|| "null".into());
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ms\": {:.6}, \"p50_ms\": {:.6}, \
                 \"p95_ms\": {:.6}, \"gflops\": {}, \"scratch_bytes\": {}, \
                 \"bytes_moved\": {}, \"gbps\": {}{ph}, \
                 \"note\": \"{}\"}}{}\n",
                escape(&r.name),
                r.summary.mean_ms(),
                r.summary.p50_ns / 1e6,
                r.summary.p95_ns / 1e6,
                gf,
                sb,
                bw,
                gbps,
                escape(&r.note),
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`Self::json`] to `BENCH_<title>.json` in the working
    /// directory (CI uploads it as an artifact); returns the path.
    pub fn write_json_default(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::PathBuf::from(format!("BENCH_{}.json", self.title));
        std::fs::write(&path, self.json())?;
        Ok(path)
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn suite() -> BenchSuite {
        BenchSuite { title: "t".into(), warmup: 0, iters: 3, quick: true, results: vec![] }
    }

    #[test]
    fn suite_collects_results() {
        let mut s = suite();
        s.bench("noop", "", || {});
        s.bench("spin", "", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(s.results.len(), 2);
        assert!(s.mean_ms_of("noop").is_some());
        let rep = s.report();
        assert!(rep.contains("TSV\tt\tnoop"));
    }

    #[test]
    fn json_carries_gflops() {
        let mut s = suite();
        s.bench("plain", "n=1", || {});
        s.bench_with_flops("kernel", "n=2", 1e6, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let j = s.json();
        assert!(j.contains("\"title\": \"t\""));
        assert!(j.contains("\"name\": \"kernel\""));
        assert!(j.contains("\"gflops\": null"), "plain bench has no flops: {j}");
        assert!(j.contains("\"scratch_bytes\": null"), "no scratch registered: {j}");
        assert!(s.results[1].gflops.unwrap() > 0.0);
        // crude structural sanity: one object per result, balanced braces
        assert_eq!(j.matches("\"name\"").count(), 2);
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn json_escapes_quotes() {
        let mut s = suite();
        s.bench("q", "say \"hi\"", || {});
        assert!(s.json().contains("say \\\"hi\\\""));
    }

    #[test]
    fn phase_split_flows_to_table_json_and_tsv() {
        let mut s = suite();
        s.bench("plain", "", || {});
        s.bench("train_step", "sparse", || {});
        s.set_phase_split([1.5, 3.0, 0.25], Some([1.5e9, 3.0e9, 0.5e9]));
        // table gains the phase header and the phased row renders values
        let rep = s.report();
        assert!(rep.contains("fwd"), "{rep}");
        assert!(rep.contains("1.50ms"), "{rep}");
        // TSV: phase columns appended after scratch
        assert!(rep.contains("TSV\tt\ttrain_step"), "{rep}");
        assert!(rep.contains("\t1.500000\t3.000000\t0.250000"), "{rep}");
        // JSON: per-phase ms + GFLOP/s (1.5e9 flops / 1.5 ms = 1000 GF/s)
        let j = s.json();
        assert!(j.contains("\"fwd_ms\": 1.500000"), "{j}");
        assert!(j.contains("\"fwd_gflops\": 1000.0000"), "{j}");
        assert!(j.contains("\"update_gflops\": 2000.0000"), "{j}");
        // the phase-free result carries no phase keys
        assert_eq!(j.matches("fwd_ms").count(), 1, "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn scratch_bytes_column_flows_to_json_and_tsv() {
        let mut s = suite();
        s.bench("attn", "fused", || {});
        s.set_scratch_bytes(12544);
        assert_eq!(s.results[0].scratch_bytes, Some(12544));
        assert!(s.json().contains("\"scratch_bytes\": 12544"));
        let rep = s.report();
        assert!(rep.contains("12544"));
    }

    #[test]
    fn bytes_moved_column_flows_to_table_json_and_tsv() {
        let mut s = suite();
        s.bench("plain", "", || {});
        s.bench("sweep", "bf16", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        s.set_bytes_moved(1e6);
        let r = &s.results[1];
        assert_eq!(r.bytes_moved, Some(1e6));
        let g = r.gbps().unwrap();
        assert!((g - 1e6 / r.summary.mean_ns).abs() < 1e-9);
        let rep = s.report();
        assert!(rep.contains("GB/s"), "{rep}");
        // TSV: gbps appended after scratch (and phases, when present)
        assert!(rep.contains(&format!("\t{g:.4}\n")), "{rep}");
        let j = s.json();
        assert!(j.contains("\"bytes_moved\": 1000000"), "{j}");
        assert!(j.contains(&format!("\"gbps\": {g:.4}")), "{j}");
        // the unregistered result stays null
        assert!(j.contains("\"bytes_moved\": null"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}
