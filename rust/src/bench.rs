//! In-crate micro-benchmark harness (offline substitute for criterion).
//!
//! Each `rust/benches/*.rs` binary (registered with `harness = false`)
//! builds a `BenchSuite`, registers closures, and calls `run()`, which
//! warms up, samples, and prints a fixed-width table plus TSV lines that
//! EXPERIMENTS.md ingests.  `--quick` (or PIXELFLY_BENCH_QUICK=1) shrinks
//! iteration counts so `cargo bench` stays tractable on CI.

use crate::util::stats::{time_it, Summary};
use crate::util::Args;

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// optional user metric (e.g. GFLOP/s or speedup baseline id)
    pub note: String,
}

pub struct BenchSuite {
    pub title: String,
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let args = Args::from_env();
        let quick = args.bool("quick")
            || std::env::var("PIXELFLY_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let (warmup, iters) = if quick { (1, 3) } else { (3, 10) };
        BenchSuite {
            title: title.to_string(),
            warmup: args.usize_or("warmup", warmup),
            iters: args.usize_or("iters", iters),
            results: Vec::new(),
        }
    }

    /// Benchmark a closure; `note` is free-form context for the table.
    pub fn bench<F: FnMut()>(&mut self, name: &str, note: &str, f: F) -> &Summary {
        let summary = time_it(self.warmup, self.iters, f);
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            note: note.to_string(),
        });
        &self.results.last().unwrap().summary
    }

    pub fn last_mean_ms(&self) -> f64 {
        self.results.last().map(|r| r.summary.mean_ms()).unwrap_or(f64::NAN)
    }

    /// Mean time of a named result (for speedup columns).
    pub fn mean_ms_of(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.summary.mean_ms())
    }

    /// Print the table; returns it as a string too (for tee-ing).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} (warmup={} iters={}) ===\n",
                              self.title, self.warmup, self.iters));
        out.push_str(&format!("{:<44} {:>12} {:>12} {:>12}  note\n",
                              "benchmark", "mean", "p50", "p95"));
        for r in &self.results {
            out.push_str(&format!(
                "{:<44} {:>10.3}ms {:>10.3}ms {:>10.3}ms  {}\n",
                r.name,
                r.summary.mean_ms(),
                r.summary.p50_ns / 1e6,
                r.summary.p95_ns / 1e6,
                r.note
            ));
        }
        // machine-readable lines
        for r in &self.results {
            out.push_str(&format!("TSV\t{}\t{}\t{:.6}\t{:.6}\t{}\n",
                                  self.title, r.name, r.summary.mean_ms(),
                                  r.summary.p50_ns / 1e6, r.note));
        }
        print!("{out}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_collects_results() {
        let mut s = BenchSuite { title: "t".into(), warmup: 0, iters: 3, results: vec![] };
        s.bench("noop", "", || {});
        s.bench("spin", "", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(s.results.len(), 2);
        assert!(s.mean_ms_of("noop").is_some());
        let rep = s.report();
        assert!(rep.contains("TSV\tt\tnoop"));
    }
}
