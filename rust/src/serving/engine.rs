//! Continuous-batching engine: one thread owns a [`DecodeSession`] and a
//! slot table; concurrent requests coalesce into padded micro-batches and
//! new requests join BETWEEN decode steps, never waiting for the current
//! batch to finish (continuous batching, not static batching).
//!
//! Shape of the loop:
//!
//! ```text
//! handles ── generate() ──► bounded queue ──► admit into free slots ─┐
//!                                                                    ▼
//!            deliver ◄── finished requests ◄── one decode step over every
//!                                              active slot (1 row each)
//! ```
//!
//! Prompts are fed through the same decode path one row per step
//! (incremental prefill), so a freshly admitted request's prefill rows
//! ride along with other requests' decode rows in the same micro-batch.
//! Per-row numerics are batch-composition-independent (see
//! `AttnPlan::decode_query`), so a request's output is bit-identical
//! whatever it was batched with — the concurrency test exploits this.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use crate::nn::DecodeSession;
use crate::sparse::dense::Matrix;

use super::metrics::{MetricsSnapshot, Recorder};

/// Engine sizing. `max_batch` is clamped to the session's slot count.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// concurrent requests decoded per step (KV slots used)
    pub max_batch: usize,
    /// admission queue bound; producers block (backpressure) when full
    pub queue_depth: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_batch: 8, queue_depth: 64 }
    }
}

/// Why a request was rejected or abandoned. Validation errors are
/// returned before the request ever queues; `EngineDown` reaches
/// everything in flight when the engine stops.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestError {
    /// prompt + generation would overflow the KV cache
    TooLong { prompt: usize, gen: usize, max_seq: usize },
    /// wrong width / empty prompt / zero generation
    BadShape { what: &'static str, expected: usize, got: usize },
    EngineDown(String),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::TooLong { prompt, gen, max_seq } => write!(
                f,
                "request needs {prompt} prompt + {gen} generated rows but the \
                 KV cache holds max_seq={max_seq}"
            ),
            RequestError::BadShape { what, expected, got } => {
                write!(f, "bad request shape: {what} expected {expected}, got {got}")
            }
            RequestError::EngineDown(m) => write!(f, "engine down: {m}"),
        }
    }
}

impl std::error::Error for RequestError {}

/// One-shot rendezvous between a blocked client thread and the engine.
#[derive(Default)]
struct ResponseCell {
    slot: Mutex<Option<Result<Matrix, RequestError>>>,
    cv: Condvar,
}

impl ResponseCell {
    // poison-tolerant on both sides: delivery runs on the engine thread
    // (possibly during an unwind — the panic-containment path delivers
    // EngineDown to every parked client) and a poisoned cell must hand
    // the client its typed result, not a second panic
    fn deliver(&self, r: Result<Matrix, RequestError>) {
        *self.slot.lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<Matrix, RequestError> {
        let mut g = self.slot.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

struct Pending {
    prompt: Matrix,
    gen: usize,
    cell: Arc<ResponseCell>,
    enqueued: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    /// producers park here when the queue is at depth
    space: Condvar,
    /// the engine thread parks here when fully idle
    work: Condvar,
    shutdown: AtomicBool,
    metrics: Mutex<Recorder>,
}

/// Lock that shrugs off poisoning: if the engine thread panicked while
/// holding a lock, clients must still get their typed `EngineDown`, not
/// a cascading poison panic.
fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Test hook proving panic containment: the engine thread panics after
/// this many more decode steps (0 = on the next one; negative =
/// disarmed, the resting state).
static PANIC_AFTER: AtomicI64 = AtomicI64::new(-1);

/// Arm [`PANIC_AFTER`]: the engine thread will panic just before decode
/// step `steps` from now. The fault is one-shot; clients of the downed
/// engine must observe [`RequestError::EngineDown`], never a hang.
pub fn arm_engine_panic(steps: u64) {
    PANIC_AFTER.store(steps as i64, Ordering::SeqCst);
}

fn take_injected_panic() -> bool {
    let armed = PANIC_AFTER.load(Ordering::SeqCst);
    if armed < 0 {
        return false;
    }
    PANIC_AFTER.store(armed - 1, Ordering::SeqCst);
    armed == 0
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&'static str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Cloneable client endpoint; `generate` blocks until the engine delivers.
#[derive(Clone)]
pub struct EngineHandle {
    shared: Arc<Shared>,
    d: usize,
    max_seq: usize,
    queue_depth: usize,
}

impl EngineHandle {
    pub fn d(&self) -> usize {
        self.d
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Submit one request and block until its `gen × d` output is ready.
    /// Row `i` of the result is the model's prediction following the
    /// prompt plus `i` already-generated rows (greedy continuous
    /// autoregression in embedding space). Backpressure: blocks while the
    /// admission queue is full.
    pub fn generate(&self, prompt: Matrix, gen: usize) -> Result<Matrix, RequestError> {
        if prompt.cols != self.d {
            return Err(RequestError::BadShape {
                what: "prompt cols",
                expected: self.d,
                got: prompt.cols,
            });
        }
        if prompt.rows == 0 {
            return Err(RequestError::BadShape { what: "prompt rows", expected: 1, got: 0 });
        }
        if gen == 0 {
            return Err(RequestError::BadShape { what: "gen rows", expected: 1, got: 0 });
        }
        if prompt.rows + gen > self.max_seq {
            return Err(RequestError::TooLong {
                prompt: prompt.rows,
                gen,
                max_seq: self.max_seq,
            });
        }
        let cell = Arc::new(ResponseCell::default());
        {
            let mut q = lock(&self.shared.queue);
            loop {
                if self.shared.shutdown.load(Ordering::SeqCst) {
                    return Err(RequestError::EngineDown("engine is shut down".into()));
                }
                if q.len() < self.queue_depth {
                    break;
                }
                q = self.shared.space.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
            q.push_back(Pending {
                prompt,
                gen,
                cell: cell.clone(),
                enqueued: Instant::now(),
            });
            self.shared.work.notify_one();
        }
        cell.wait()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        lock(&self.shared.metrics).snapshot()
    }
}

/// A request resident in a KV slot.
struct Active {
    cell: Arc<ResponseCell>,
    prompt: Matrix,
    gen: usize,
    /// next cache position to feed = rows already fed
    pos: usize,
    out: Matrix,
    produced: usize,
    /// last generated row, fed back as the next decode input
    last: Vec<f32>,
    enqueued: Instant,
}

impl Active {
    fn new(p: Pending, d_out: usize) -> Self {
        Active {
            cell: p.cell,
            out: Matrix::zeros(p.gen, d_out),
            prompt: p.prompt,
            gen: p.gen,
            pos: 0,
            produced: 0,
            last: vec![0.0; d_out],
            enqueued: p.enqueued,
        }
    }
}

fn fail_all(slots: &mut [Option<Active>], q: &mut VecDeque<Pending>, msg: &str) {
    for s in slots.iter_mut() {
        if let Some(a) = s.take() {
            a.cell.deliver(Err(RequestError::EngineDown(msg.into())));
        }
    }
    for p in q.drain(..) {
        p.cell.deliver(Err(RequestError::EngineDown(msg.into())));
    }
}

/// The engine thread: runs the decode loop under `catch_unwind`, with
/// the slot table owned OUTSIDE the unwind boundary, so a panic
/// anywhere in the loop — a kernel assert, an injected
/// [`arm_engine_panic`], a bug — downs the engine cleanly: shutdown
/// flips, every resident and queued request gets a typed
/// [`RequestError::EngineDown`] naming the panic, and blocked producers
/// are woken. Clients can never hang on a dead engine thread.
fn engine_loop(mut sess: DecodeSession, shared: Arc<Shared>, max_batch: usize) {
    let mut slots: Vec<Option<Active>> = (0..max_batch).map(|_| None).collect();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine_iterations(&mut sess, &shared, &mut slots, max_batch);
    }));
    if let Err(payload) = caught {
        let msg = format!("engine thread panicked: {}", panic_message(&*payload));
        shared.shutdown.store(true, Ordering::SeqCst);
        let mut q = lock(&shared.queue);
        fail_all(&mut slots, &mut q, &msg);
        shared.space.notify_all();
    }
}

fn engine_iterations(sess: &mut DecodeSession, shared: &Shared,
                     slots: &mut Vec<Option<Active>>, max_batch: usize) {
    let d = sess.in_dim();
    let d_out = sess.out_dim();
    let mut x = Matrix::zeros(max_batch, d);
    let mut batch_slots: Vec<usize> = Vec::with_capacity(max_batch);
    let mut batch_pos: Vec<usize> = Vec::with_capacity(max_batch);
    loop {
        // ---- admit: move queued requests into free KV slots ----
        {
            let mut q = lock(&shared.queue);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    fail_all(slots, &mut q, "engine is shut down");
                    shared.space.notify_all();
                    return;
                }
                let mut admitted = false;
                while let Some(free) = slots.iter().position(Option::is_none) {
                    match q.pop_front() {
                        Some(p) => {
                            slots[free] = Some(Active::new(p, d_out));
                            admitted = true;
                        }
                        None => break,
                    }
                }
                if admitted {
                    shared.space.notify_all();
                }
                if slots.iter().any(Option::is_some) {
                    break;
                }
                // fully idle: park until a request lands (timeout so a
                // shutdown flag flip is never missed)
                q = shared
                    .work
                    .wait_timeout(q, Duration::from_millis(20))
                    .unwrap_or_else(PoisonError::into_inner)
                    .0;
            }
        }
        // ---- one decode step: 1 input row per active slot ----
        batch_slots.clear();
        batch_pos.clear();
        for (si, s) in slots.iter().enumerate() {
            if let Some(a) = s {
                batch_slots.push(si);
                batch_pos.push(a.pos);
            }
        }
        let n = batch_slots.len();
        x.rows = n;
        x.data.resize(n * d, 0.0);
        for (i, &si) in batch_slots.iter().enumerate() {
            let a = slots[si].as_ref().unwrap();
            let src: &[f32] =
                if a.pos < a.prompt.rows { a.prompt.row(a.pos) } else { &a.last };
            x.row_mut(i).copy_from_slice(src);
        }
        if take_injected_panic() {
            panic!("injected engine panic (arm_engine_panic)");
        }
        let t0 = Instant::now();
        let y = match sess.step(&x, &batch_slots, &batch_pos) {
            Ok(y) => y,
            Err(e) => {
                let msg = format!("decode step failed: {e}");
                let mut q = lock(&shared.queue);
                shared.shutdown.store(true, Ordering::SeqCst);
                fail_all(slots, &mut q, &msg);
                shared.space.notify_all();
                return;
            }
        };
        let step_ns = t0.elapsed().as_nanos() as u64;
        // ---- absorb outputs; prompts in prefill produce nothing yet ----
        let mut generated = 0usize;
        for (i, &si) in batch_slots.iter().enumerate() {
            let a = slots[si].as_mut().unwrap();
            let fed = a.pos;
            a.pos += 1;
            if fed + 1 >= a.prompt.rows {
                // the output row following input row `fed` is the next
                // generated token
                let row = y.row(i);
                a.out.row_mut(a.produced).copy_from_slice(row);
                a.last.clear();
                a.last.extend_from_slice(row);
                a.produced += 1;
                generated += 1;
            }
        }
        let mut m = lock(&shared.metrics);
        m.record_step(step_ns, n, generated);
        for &si in &batch_slots {
            if slots[si].as_ref().map_or(false, |a| a.produced == a.gen) {
                let a = slots[si].take().unwrap();
                m.record_request(a.enqueued.elapsed().as_nanos() as u64);
                a.cell.deliver(Ok(a.out));
            }
        }
    }
}

/// Owns the engine thread; dropping (or `shutdown()`) stops it and fails
/// everything in flight with [`RequestError::EngineDown`].
pub struct ServeEngine {
    shared: Arc<Shared>,
    thread: Option<thread::JoinHandle<()>>,
    d: usize,
    max_seq: usize,
    queue_depth: usize,
}

impl ServeEngine {
    /// Spawn the engine thread around a frozen decode session.
    pub fn start(sess: DecodeSession, cfg: EngineConfig) -> ServeEngine {
        let max_batch = cfg.max_batch.clamp(1, sess.max_slots());
        let d = sess.in_dim();
        let max_seq = sess.max_seq();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            space: Condvar::new(),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Mutex::new(Recorder::new()),
        });
        let s2 = Arc::clone(&shared);
        let thread = thread::Builder::new()
            .name("pixelfly-serve".into())
            .spawn(move || engine_loop(sess, s2, max_batch))
            .expect("spawn serve engine thread");
        ServeEngine {
            shared,
            thread: Some(thread),
            d,
            max_seq,
            queue_depth: cfg.queue_depth.max(1),
        }
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle {
            shared: Arc::clone(&self.shared),
            d: self.d,
            max_seq: self.max_seq,
            queue_depth: self.queue_depth,
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        // same poison tolerance as the engine loop's `lock` helper: a
        // crashed engine thread must not take the metrics path with it
        lock(&self.shared.metrics).snapshot()
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        // the engine drains on its way out; catch anything enqueued after
        let mut q = lock(&self.shared.queue);
        for p in q.drain(..) {
            p.cell.deliver(Err(RequestError::EngineDown("engine is shut down".into())));
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        if self.thread.is_some() {
            self.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_errors_display() {
        let e = RequestError::TooLong { prompt: 100, gen: 64, max_seq: 128 };
        assert!(e.to_string().contains("max_seq=128"));
        let e = RequestError::BadShape { what: "prompt cols", expected: 128, got: 64 };
        assert!(e.to_string().contains("prompt cols"));
        assert!(RequestError::EngineDown("x".into()).to_string().contains("x"));
    }

    #[test]
    fn config_defaults_are_sane() {
        let c = EngineConfig::default();
        assert!(c.max_batch >= 1 && c.queue_depth >= c.max_batch);
    }

    #[test]
    fn response_cell_survives_poisoned_slot() {
        // an engine thread dying while holding the cell lock poisons it;
        // deliver/wait must still hand the client its result, not a
        // cascading poison panic
        let cell = Arc::new(ResponseCell {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        });
        let c2 = Arc::clone(&cell);
        let _ = thread::spawn(move || {
            let _g = c2.slot.lock().unwrap();
            panic!("poison the slot");
        })
        .join();
        assert!(cell.slot.is_poisoned(), "setup must poison the lock");
        cell.deliver(Err(RequestError::EngineDown("crashed".into())));
        match cell.wait() {
            Err(RequestError::EngineDown(msg)) => assert!(msg.contains("crashed")),
            Err(other) => panic!("expected EngineDown, got {other:?}"),
            Ok(_) => panic!("expected EngineDown, got a matrix"),
        }
    }

    #[test]
    fn metrics_lock_helper_survives_poison() {
        let m = Mutex::new(Recorder::default());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison the metrics");
        }));
        assert!(r.is_err() && m.is_poisoned(), "setup must poison the lock");
        // the exact accessor `ServeEngine::metrics` routes through
        let snap = lock(&m).snapshot();
        assert_eq!(snap.requests, 0);
    }
}
