//! Thread-per-connection TCP front end over an [`EngineHandle`].
//!
//! Wire protocol `PXF1` (little-endian, f32 payloads — the models are
//! continuous-embedding autoregressors, so a "token" is a d-dim row):
//!
//! ```text
//! request:  "PXF1" | u32 prompt_rows | u32 d | u32 gen | prompt_rows·d f32
//! response: u8 status
//!           status 0: u32 rows | u32 d | rows·d f32   (generated rows)
//!           status 1: u32 len  | len utf-8 bytes      (error message)
//! ```
//!
//! Connections are keep-alive: a client may pipeline any number of
//! requests and the handler answers in order, one engine call each.
//! Every connection gets its own OS thread (requests block on the engine
//! anyway), and the engine interleaves all of them into micro-batches.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::sparse::dense::Matrix;

use super::engine::EngineHandle;

const MAGIC: &[u8; 4] = b"PXF1";
/// Per-dimension sanity bound: rejects garbage headers before they turn
/// into multi-GiB allocations.
const MAX_DIM: u32 = 1 << 20;

/// Front-end knobs. `io_timeout` bounds every socket read/write so a
/// stalled client can't pin a connection thread forever: a timeout while
/// idle between requests closes the connection quietly; a timeout
/// mid-frame sends the client a typed `timeout:` error first. `None`
/// disables timeouts (blocking reads, the pre-timeout behaviour).
#[derive(Clone, Copy, Debug)]
pub struct TcpConfig {
    pub io_timeout: Option<Duration>,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig { io_timeout: Some(Duration::from_secs(30)) }
    }
}

/// A socket timeout surfaces as `WouldBlock` (unix) or `TimedOut`
/// (windows); the handler treats both as "the client stalled".
fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Listening front end; `stop()` (or drop) halts the accept loop.
/// In-flight connection handlers finish their current request and exit
/// when their client hangs up, stalls past the i/o timeout, or the
/// engine goes down.
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port —
    /// read it back from [`TcpServer::addr`]) and start accepting with
    /// the default config (30s i/o timeout).
    pub fn start(addr: &str, handle: EngineHandle) -> io::Result<TcpServer> {
        Self::start_with(addr, handle, TcpConfig::default())
    }

    /// [`TcpServer::start`] with explicit [`TcpConfig`].
    pub fn start_with(addr: &str, handle: EngineHandle, cfg: TcpConfig)
                      -> io::Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = thread::Builder::new()
            .name("pixelfly-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let h = handle.clone();
                    let _ = thread::Builder::new()
                        .name("pixelfly-conn".into())
                        .spawn(move || {
                            let _ = handle_connection(stream, &h, cfg);
                        });
                }
            })?;
        Ok(TcpServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.halt();
        }
    }
}

fn handle_connection(mut stream: TcpStream, handle: &EngineHandle, cfg: TcpConfig)
                     -> io::Result<()> {
    stream.set_read_timeout(cfg.io_timeout)?;
    stream.set_write_timeout(cfg.io_timeout)?;
    loop {
        let mut magic = [0u8; 4];
        match stream.read_exact(&mut magic) {
            Ok(()) => {}
            // clean EOF between requests = client done
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            // idle timeout BETWEEN requests: nothing owed, close quietly
            Err(e) if is_timeout(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        if &magic != MAGIC {
            write_err(&mut stream, "bad magic (want PXF1)")?;
            return Ok(()); // framing is lost; drop the connection
        }
        // mid-frame from here on: the client owes header + payload bytes,
        // so a stall gets a typed error back before the drop
        let parsed: io::Result<(Matrix, u32)> = (|| {
            let rows = read_u32(&mut stream)?;
            let d = read_u32(&mut stream)?;
            let gen = read_u32(&mut stream)?;
            if rows == 0 || rows > MAX_DIM || d == 0 || d > MAX_DIM || gen > MAX_DIM {
                return Err(io::Error::new(io::ErrorKind::InvalidData,
                                          "header out of range"));
            }
            let mut prompt = Matrix::zeros(rows as usize, d as usize);
            read_f32s(&mut stream, &mut prompt.data)?;
            Ok((prompt, gen))
        })();
        let (prompt, gen) = match parsed {
            Ok(v) => v,
            Err(e) if is_timeout(&e) => {
                // best effort: the write has its own timeout and the
                // connection is being dropped either way
                let _ = write_err(&mut stream, "timeout: client stalled mid-request");
                return Ok(());
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                write_err(&mut stream, &e.to_string())?;
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        match handle.generate(prompt, gen as usize) {
            Ok(out) => {
                let mut buf = Vec::with_capacity(9 + out.data.len() * 4);
                buf.push(0u8);
                buf.extend_from_slice(&(out.rows as u32).to_le_bytes());
                buf.extend_from_slice(&(out.cols as u32).to_le_bytes());
                for v in &out.data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                stream.write_all(&buf)?;
            }
            Err(e) => write_err(&mut stream, &e.to_string())?,
        }
    }
}

fn write_err(stream: &mut TcpStream, msg: &str) -> io::Result<()> {
    let bytes = msg.as_bytes();
    let mut buf = Vec::with_capacity(5 + bytes.len());
    buf.push(1u8);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    stream.write_all(&buf)
}

fn read_u32(stream: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    stream.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(stream: &mut impl Read, out: &mut [f32]) -> io::Result<()> {
    // bounded chunks so one request never holds a payload-sized byte
    // buffer alongside the float buffer
    let mut bytes = [0u8; 4096];
    let mut i = 0;
    while i < out.len() {
        let take = (out.len() - i).min(bytes.len() / 4) * 4;
        stream.read_exact(&mut bytes[..take])?;
        for c in bytes[..take].chunks_exact(4) {
            out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            i += 1;
        }
    }
    Ok(())
}

/// Client side of one `PXF1` round trip on an open connection. Returns
/// `Ok(Ok(matrix))` for generated rows, `Ok(Err(msg))` for a server-side
/// rejection, `Err(_)` for transport failures.
pub fn client_request(
    stream: &mut TcpStream,
    prompt: &Matrix,
    gen: usize,
) -> io::Result<Result<Matrix, String>> {
    let mut buf = Vec::with_capacity(16 + prompt.data.len() * 4);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(prompt.rows as u32).to_le_bytes());
    buf.extend_from_slice(&(prompt.cols as u32).to_le_bytes());
    buf.extend_from_slice(&(gen as u32).to_le_bytes());
    for v in &prompt.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&buf)?;
    let mut status = [0u8; 1];
    stream.read_exact(&mut status)?;
    match status[0] {
        0 => {
            let rows = read_u32(stream)? as usize;
            let d = read_u32(stream)? as usize;
            let mut out = Matrix::zeros(rows, d);
            read_f32s(stream, &mut out.data)?;
            Ok(Ok(out))
        }
        1 => {
            let len = read_u32(stream)? as usize;
            let mut msg = vec![0u8; len.min(1 << 16)];
            stream.read_exact(&mut msg)?;
            Ok(Err(String::from_utf8_lossy(&msg).into_owned()))
        }
        s => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad response status {s}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip_through_byte_chunks() -> io::Result<()> {
        // encode → decode through the same helpers the wire path uses
        let vals: Vec<f32> = (0..1500).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let mut out = vec![0.0f32; vals.len()];
        read_f32s(&mut &bytes[..], &mut out)?;
        assert_eq!(vals, out);
        Ok(())
    }

    #[test]
    fn short_frame_is_a_typed_error_not_a_panic() {
        // a frame that ends mid-payload must surface as UnexpectedEof
        // through the io::Result path, never a panic
        let bytes = 1.5f32.to_le_bytes();
        let mut out = vec![0.0f32; 3];
        match read_f32s(&mut &bytes[..], &mut out) {
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof),
            Ok(()) => panic!("short frame must error"),
        }
    }
}
