//! Serving metrics: a bounded latency recorder and the percentile math
//! the front end reports (p50/p90/p99).
//!
//! [`util::stats::Summary`](crate::util::stats::Summary) stops at p95 and
//! keeps every sample; serving wants tail percentiles over an unbounded
//! request stream, so the [`Recorder`] keeps a fixed-size ring of the most
//! recent request latencies (old requests age out, counters never do) and
//! snapshots compute nearest-rank percentiles over that window.

use std::time::Instant;

/// Most recent request latencies retained for percentile estimation.
const MAX_SAMPLES: usize = 4096;

/// Nearest-rank percentile over an ascending-sorted slice, matching the
/// convention in `util::stats`. `p` is a fraction in `[0, 1]`; an empty
/// slice reports 0.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p) as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Accumulates engine-side counters plus a latency ring. Owned by the
/// engine's shared state behind a mutex; the engine thread records, any
/// handle snapshots.
pub struct Recorder {
    started: Instant,
    /// ring buffer of the most recent completed-request latencies (ns)
    latencies_ns: Vec<f64>,
    next: usize,
    requests: u64,
    generated_tokens: u64,
    steps: u64,
    step_ns: u64,
    step_rows: u64,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder {
            started: Instant::now(),
            latencies_ns: Vec::new(),
            next: 0,
            requests: 0,
            generated_tokens: 0,
            steps: 0,
            step_ns: 0,
            step_rows: 0,
        }
    }

    /// One completed request: end-to-end latency from enqueue to delivery.
    pub fn record_request(&mut self, latency_ns: u64) {
        self.requests += 1;
        let v = latency_ns as f64;
        if self.latencies_ns.len() < MAX_SAMPLES {
            self.latencies_ns.push(v);
        } else {
            self.latencies_ns[self.next] = v;
            self.next = (self.next + 1) % MAX_SAMPLES;
        }
    }

    /// One decode step: wall time, batch occupancy, tokens emitted.
    pub fn record_step(&mut self, ns: u64, rows: usize, generated: usize) {
        self.steps += 1;
        self.step_ns += ns;
        self.step_rows += rows as u64;
        self.generated_tokens += generated as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut sorted = self.latencies_ns.clone();
        // total_cmp: a NaN sample (impossible today, but this is a panic
        // path inside the engine's metrics lock) must never abort the
        // snapshot
        sorted.sort_by(f64::total_cmp);
        let elapsed_s = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            requests: self.requests,
            generated_tokens: self.generated_tokens,
            steps: self.steps,
            elapsed_s,
            tokens_per_s: self.generated_tokens as f64 / elapsed_s,
            mean_batch_rows: self.step_rows as f64 / (self.steps.max(1)) as f64,
            mean_step_ms: self.step_ns as f64 / (self.steps.max(1)) as f64 / 1e6,
            p50_ms: percentile(&sorted, 0.50) / 1e6,
            p90_ms: percentile(&sorted, 0.90) / 1e6,
            p99_ms: percentile(&sorted, 0.99) / 1e6,
        }
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time view of the engine's counters, cheap to copy around.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub generated_tokens: u64,
    pub steps: u64,
    pub elapsed_s: f64,
    pub tokens_per_s: f64,
    /// mean rows per decode step — continuous-batching occupancy
    pub mean_batch_rows: f64,
    pub mean_step_ms: f64,
    /// request-latency percentiles over the recent window
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "reqs={} tokens={} tok/s={:.1} batch={:.2} step={:.3}ms \
             p50={:.2}ms p90={:.2}ms p99={:.2}ms",
            self.requests, self.generated_tokens, self.tokens_per_s,
            self.mean_batch_rows, self.mean_step_ms, self.p50_ms, self.p90_ms,
            self.p99_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn recorder_ring_ages_out_old_samples() {
        let mut r = Recorder::new();
        for _ in 0..MAX_SAMPLES {
            r.record_request(1_000_000); // 1ms
        }
        assert_eq!(r.snapshot().p99_ms, 1.0);
        for _ in 0..MAX_SAMPLES {
            r.record_request(2_000_000); // 2ms pushes the 1ms era out
        }
        let s = r.snapshot();
        assert_eq!(s.p50_ms, 2.0);
        assert_eq!(s.requests, 2 * MAX_SAMPLES as u64);
    }

    #[test]
    fn recorder_counts_steps_and_tokens() {
        let mut r = Recorder::new();
        r.record_step(2_000_000, 4, 3);
        r.record_step(4_000_000, 2, 2);
        let s = r.snapshot();
        assert_eq!(s.steps, 2);
        assert_eq!(s.generated_tokens, 5);
        assert!((s.mean_batch_rows - 3.0).abs() < 1e-12);
        assert!((s.mean_step_ms - 3.0).abs() < 1e-12);
    }
}
