//! Serving runtime: continuous batching + KV-cached decode behind a TCP
//! front end (DESIGN.md "Serving runtime").
//!
//! Three small layers, each usable on its own:
//! - [`engine`]  the continuous-batching loop around a
//!   [`DecodeSession`](crate::nn::DecodeSession): bounded admission queue,
//!   per-slot KV cache, one token per active request per step
//! - [`tcp`]     thread-per-connection front end speaking the `PXF1` frame
//! - [`metrics`] tokens/s + p50/p90/p99 request-latency accounting
//!
//! Everything is std-only: threads, mutexes, condvars, `TcpListener` —
//! no async runtime, matching the crate's zero-dependency substrate.

pub mod engine;
pub mod metrics;
pub mod tcp;

pub use engine::{arm_engine_panic, EngineConfig, EngineHandle, RequestError,
                 ServeEngine};
pub use metrics::{percentile, MetricsSnapshot, Recorder};
pub use tcp::{client_request, TcpConfig, TcpServer};
