//! `pixelfly` — the Layer-3 coordinator CLI.
//!
//! Subcommands (see README for a tour):
//!   train        train one preset end-to-end on the PJRT engine
//!   compare      train dense + pixelfly (+ more) presets and tabulate
//!   ntk-compare  Fig 4: NTK distance of each pattern vs dense (artifacts)
//!   ntk-search   Appendix K / Algorithm 2 over the analytic NTK
//!   plan         budget allocation + mask plan for a model schema
//!   serve        continuous-batching TCP inference on a compiled preset
//!   microbench   Table 7: expected vs actual density & latency
//!   flatbench    Fig 11: flat vs product butterfly multiply
//!   list         list artifacts in the manifest

use std::path::{Path, PathBuf};

use anyhow::Result;

use pixelfly::ckpt::{writer, Snapshotter};
use pixelfly::coordinator::{budget, planner, TrainConfig, Trainer};
use pixelfly::costmodel::Device;
use pixelfly::data::lra::LraTask;
use pixelfly::models;
use pixelfly::nn::Model;
use pixelfly::ntk;
use pixelfly::patterns::{baselines, flat_butterfly_mask, BlockMask};
use pixelfly::runtime::engine::Literal;
use pixelfly::runtime::{artifacts_dir, Engine};
use pixelfly::serving::{EngineConfig, ServeEngine, TcpConfig, TcpServer};
use pixelfly::sparse::{butterfly_mm::ButterflyProduct, exec, BsrMatrix, Matrix};
use pixelfly::util::{stats::time_it, Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env();
    // Substrate worker count: --threads beats PIXELFLY_THREADS beats auto.
    if let Some(n) = args.get("threads") {
        let n: usize = n.parse().map_err(|_| anyhow::anyhow!("--threads expects an integer"))?;
        exec::set_threads(n);
    }
    // Microkernel tier: --kernel beats PIXELFLY_KERNEL beats auto-detect.
    if let Some(k) = args.get("kernel") {
        let choice = exec::KernelChoice::parse(k)
            .ok_or_else(|| anyhow::anyhow!("--kernel expects auto|scalar|simd, got {k:?}"))?;
        exec::set_kernel(choice);
    }
    // Pool runtime: --pool beats PIXELFLY_POOL beats resident default.
    if let Some(p) = args.get("pool") {
        let mode = exec::PoolMode::parse(p)
            .ok_or_else(|| anyhow::anyhow!("--pool expects resident|scoped, got {p:?}"))?;
        exec::set_pool_mode(Some(mode));
    }
    // Precision tier: --precision beats PIXELFLY_PREC beats f32 default.
    if let Some(p) = args.get("precision") {
        let prec = exec::Precision::parse(p).ok_or_else(|| {
            anyhow::anyhow!("--precision expects f32|bf16|int8, got {p:?}")
        })?;
        exec::set_precision(prec);
    }
    // Overlap scheduler: --overlap beats PIXELFLY_OVERLAP beats dw+comm.
    if let Some(o) = args.get("overlap") {
        let mode = exec::OverlapMode::parse(o).ok_or_else(|| {
            anyhow::anyhow!("--overlap expects off|dw|dw+comm, got {o:?}")
        })?;
        exec::set_overlap(Some(mode));
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "compare" => cmd_compare(&args),
        "ntk-compare" => cmd_ntk_compare(&args),
        "ntk-search" => cmd_ntk_search(&args),
        "plan" => cmd_plan(&args),
        "microbench" => cmd_microbench(&args),
        "flatbench" => cmd_flatbench(&args),
        "experiments" => cmd_experiments(&args),
        "list" => cmd_list(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "pixelfly — Pixelated Butterfly (ICLR 2022) coordinator\n\n\
         USAGE: pixelfly <cmd> [--flags]\n\n\
         train        --preset gpt2_s_pixelfly --steps 100 --lr 1e-3 [--lra-task text]\n\
         train        --model vit-s --budget 0.1 [--block 16 --steps 20]\n\
                      [--snapshot-every K --out DIR --retain N --resume CKPT]\n\
                      (compiled substrate path: preset -> budget -> compile -> train;\n\
                      --snapshot-every K checkpoints every K steps from a background\n\
                      thread into --out, keeping the newest --retain; --resume\n\
                      restores params+momentum+step from a .pxck checkpoint)\n\
         train        --dist coordinator --model vit-s --ranks 2 --rounds 40\n\
                      [--addr 0.0.0.0:7979 --dist-mode grad|fedavg --sync-every 4\n\
                      --round-timeout-ms 5000 --data-seed S]\n\
         train        --dist worker --model vit-s --addr HOST:7979 [--tag w0\n\
                      --warm-start CKPT|DIR --out DIR --snapshot-every K --retain N]\n\
                      (fault-tolerant data-parallel training over PXD1 TCP:\n\
                      the coordinator owns the round barrier and averages\n\
                      contributions; workers shard the synthetic stream by rank;\n\
                      dead or stalled ranks are excluded and replacements are\n\
                      admitted mid-run, warm-started from the newest snapshot)\n\
         serve        --model gpt2-s --budget 0.2 [--port 7878 --max-batch 8\n\
                      --queue-depth 64 --steps 0 --weights CKPT --io-timeout-ms N]\n\
                      (continuous-batching TCP inference, KV-cached decode;\n\
                      --steps N trains before freezing; --weights warm-starts from\n\
                      a .pxck file or snapshot dir instead of training from seed;\n\
                      --io-timeout-ms bounds stalled clients, 0 disables;\n\
                      --smoke sends itself one request and exits, the CI\n\
                      end-to-end gate for `--precision int8`; protocol: PXF1)\n\
         compare      --presets mixer_s_dense,mixer_s_pixelfly --steps 50\n\
         ntk-compare  [--batches 2]           (Fig 4, uses ntk_* artifacts)\n\
         ntk-search   [--nb 16 --budget 96]   (Appendix K, analytic NTK)\n\
         plan         --model vit-s16 --budget 0.1 [--block 32]\n\
         experiments  [--out results --scale 1.0]  (run the whole matrix)\n\
         microbench   [--n 1024 --batch 256]  (Table 7)\n\
         flatbench    [--n 1024 --batch 512]  (Fig 11)\n\
         list\n\n\
         Global: --threads N (substrate workers; also PIXELFLY_THREADS),\n\
                 --kernel auto|scalar|simd (microkernel tier; also\n\
                 PIXELFLY_KERNEL; auto picks AVX2/NEON when available),\n\
                 --pool resident|scoped (worker runtime; also PIXELFLY_POOL;\n\
                 resident = parked long-lived workers, the default),\n\
                 --precision f32|bf16|int8 (storage tier; also PIXELFLY_PREC;\n\
                 bf16 = reduced-storage training with f32 accumulate,\n\
                 int8 = per-block quantize-at-freeze for serve/inference),\n\
                 --overlap off|dw|dw+comm (backward overlap scheduler; also\n\
                 PIXELFLY_OVERLAP; dw = deferred dW + eager fused updates,\n\
                 dw+comm adds per-bucket gradient streaming in dist workers;\n\
                 default dw+comm, bit-identical to off by construction).\n\
                 PIXELFLY_PAR_FLOPS pins the calibrated serial-vs-parallel\n\
                 cutover (otherwise measured once at startup).\n\
         Commands that execute artifacts need a build with --features pjrt."
    );
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.str_or("out", "results"));
    let scale = args.f64_or("scale", 1.0);
    let seed = args.u64_or("seed", 0);
    pixelfly::coordinator::experiments::run_all(&artifacts_dir(), &out, scale, seed)?;
    println!("results -> {}", out.display());
    Ok(())
}

fn cmd_list() -> Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    let mut keys: Vec<_> = engine.manifest.artifacts.keys().cloned().collect();
    keys.sort();
    for k in keys {
        let a = &engine.manifest.artifacts[&k];
        println!("{k:<36} batch={:<4} params={:<9} file={}", a.batch, a.param_count, a.file);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // `--dist coordinator|worker` routes to the distributed data-parallel
    // path (compiled substrate over PXD1 TCP allreduce).
    if args.get("dist").is_some() {
        return cmd_train_dist(args);
    }
    // `--model <preset>` routes to the pure-Rust compiled path:
    // preset → budget → compile → train, no artifacts needed.
    if args.get("model").is_some() {
        return cmd_train_compiled(args);
    }
    let mut engine = Engine::new(&artifacts_dir())?;
    let cfg = TrainConfig {
        preset: args.str_or("preset", "mixer_s_pixelfly"),
        steps: args.usize_or("steps", 50),
        lr: args.f32_or("lr", 1e-3),
        warmup: args.usize_or("warmup", 10),
        seed: args.u64_or("seed", 0),
        log_every: args.usize_or("log-every", 10),
        eval_batches: args.usize_or("eval-batches", 4),
        lra_task: args.get("lra-task").map(parse_lra_task).transpose()?,
    };
    let mut trainer = Trainer::new(&mut engine, cfg)?;
    let report = trainer.train()?;
    println!("{}", report.summary_line());
    if args.bool("curve") {
        println!("{}", report.curve_tsv());
    }
    if let Some(dir) = args.get("checkpoint") {
        trainer.checkpoint(std::path::Path::new(dir))?;
        println!("checkpoint -> {dir}");
    }
    Ok(())
}

/// Flags shared by the compiled-substrate subcommands (`train --model`,
/// `serve`): preset, §3.3 budget fraction, hardware block, seed — one
/// parsing convention for both, per the CLI contract in README.
struct CompiledOpts {
    model: String,
    budget: f64,
    block: usize,
    seed: u64,
}

impl CompiledOpts {
    fn from_args(args: &Args, default_model: &str) -> Self {
        CompiledOpts {
            model: args.str_or("model", default_model),
            budget: args.f64_or("budget", 0.1),
            block: args.usize_or("block", 16),
            seed: args.u64_or("seed", 0),
        }
    }

    /// Checkpoint meta line: the compile inputs that must match for a
    /// checkpoint to be loadable (human-readable provenance; the binary
    /// gate is the schema fingerprint).
    fn ckpt_meta(&self) -> String {
        format!("model={};budget={};block={};seed={}",
                self.model, self.budget, self.block, self.seed)
    }

    /// `models::preset` → §3.3 budget rule → `nn::compile`, with the
    /// one-line compile summary both subcommands print.
    fn compile(&self) -> Result<Model> {
        let dev = Device::with_block(self.block);
        let schema = models::preset(&self.model, 1)
            .ok_or_else(|| anyhow::anyhow!("unknown model {:?}", self.model))?;
        let alloc = budget::rule_of_thumb(&schema, self.budget, &dev);
        let model = pixelfly::nn::compile(&schema, &alloc, self.block, self.seed)?;
        println!(
            "compiled {}: params={} (sparsified {} / dense-kept {} / bias {}) \
             plan density={:.3} kept {:.1}% of dense GEMM weights",
            self.model,
            model.param_count(),
            model.stats.sparsified_weight_params,
            model.stats.dense_weight_params,
            model.stats.bias_params,
            model.plan.total_density,
            100.0 * model.stats.sparsification_ratio(),
        );
        Ok(model)
    }
}

/// The end-to-end pipeline of the paper, entirely on the substrate:
/// `models::preset` → §3.3 budget rule → `planner::plan_model` →
/// `nn::compile` → fused train steps → frozen inference session.
fn cmd_train_compiled(args: &Args) -> Result<()> {
    let opts = CompiledOpts::from_args(args, "vit-s");
    let steps = args.usize_or("steps", 20);
    let lr = args.f32_or("lr", 1e-2);
    let momentum = args.f32_or("momentum", 0.9);
    let mut model = opts.compile()?;
    let mut start_step = 0u64;
    if let Some(path) = args.get("resume") {
        let info = model.load_checkpoint(Path::new(path))?;
        start_step = info.step;
        println!("resumed {path} at step {} ({})", info.step, info.meta);
    }
    let out = args.get("out").map(PathBuf::from);
    let snapshot_every = args.usize_or("snapshot-every", 0);
    let retain = args.usize_or("retain", 3);
    let meta = opts.ckpt_meta();
    let snapper = match &out {
        Some(dir) if snapshot_every > 0 => Some(Snapshotter::start(dir, retain)?),
        _ => {
            if snapshot_every > 0 {
                anyhow::bail!("--snapshot-every needs --out <dir>");
            }
            None
        }
    };
    let report = model.train_resumable(
        steps, lr, momentum, opts.seed, start_step,
        snapper.as_ref().map(|s| (s, snapshot_every, meta.as_str())),
    );
    println!("{}", report.summary_line());
    if let Some(s) = snapper {
        let rep = s.finish();
        println!("snapshots: {} written, {} superseded{}", rep.written, rep.dropped,
                 rep.last_path
                     .as_ref()
                     .map(|p| format!(", latest {}", p.display()))
                     .unwrap_or_default());
        for e in &rep.errors {
            eprintln!("snapshot error: {e}");
        }
    }
    if let Some(dir) = &out {
        // final synchronous checkpoint so `train --out` always leaves a
        // complete latest state for `serve --weights` / `--resume`
        std::fs::create_dir_all(dir)?;
        let final_step = start_step + steps as u64;
        let path = dir.join(writer::step_filename(final_step));
        model.save_checkpoint(&path, final_step, &meta)?;
        println!("checkpoint -> {}", path.display());
    }
    if args.bool("curve") {
        println!("{}", report.curve_tsv());
    }
    // freeze into a serving session; strict() keeps the zero-alloc steady
    // state a hard assert, so two passes here double as a serving smoke test
    let seq = model.seq;
    let in_dim = model.in_dim();
    let mut rng = Rng::new(opts.seed ^ 0x1D1E);
    let x = Matrix::randn(seq, in_dim, 1.0, &mut rng);
    let mut sess = model.into_inference().strict();
    sess.run(&x)?;
    sess.run(&x)?;
    println!("inference session: steady-state zero-alloc verified, peak scratch {}B, \
              training state shed to {}B",
             sess.peak_scratch_bytes(), sess.training_state_bytes());
    Ok(())
}

/// Fault-tolerant multi-worker data-parallel training over PXD1 TCP:
/// one coordinator process owns the round barrier, N worker processes
/// each train a shard of the synthetic stream and allreduce gradients
/// (or federated-average weights) through it. Workers can die and be
/// replaced mid-run; replacements warm-start from `--warm-start` and
/// are brought bit-exact by a donor-params transfer.
fn cmd_train_dist(args: &Args) -> Result<()> {
    use pixelfly::dist::{self, DistConfig, Mode, SnapshotCfg, WorkerConfig};
    let role = args.str_or("dist", "coordinator");
    let opts = CompiledOpts::from_args(args, "vit-s");
    match role.as_str() {
        "coordinator" => {
            let mut model = opts.compile()?;
            let mut cfg = DistConfig::new(
                args.usize_or("ranks", 2) as u32,
                args.usize_or("rounds", args.usize_or("steps", 20)) as u64,
            );
            cfg.mode = match args.str_or("dist-mode", "grad").as_str() {
                "grad" => Mode::Grad,
                "fedavg" => Mode::Fedavg,
                other => anyhow::bail!("--dist-mode expects grad|fedavg, got {other:?}"),
            };
            cfg.sync_every = args.usize_or("sync-every", 4) as u32;
            cfg.lr = args.f32_or("lr", 1e-2);
            cfg.momentum = args.f32_or("momentum", 0.9);
            cfg.data_seed = args.u64_or("data-seed", cfg.data_seed);
            cfg.round_timeout = std::time::Duration::from_millis(
                args.u64_or("round-timeout-ms", 5000));
            cfg.admit_timeout = std::time::Duration::from_millis(
                args.u64_or("admit-timeout-ms", 30_000));
            let spec = dist::coordinator::FleetSpec::of(&mut model);
            let addr = args.str_or("addr", "0.0.0.0:7979");
            let coord = dist::Coordinator::bind(&addr, cfg.clone(), spec)?;
            println!("coordinator on {} waiting for {} workers \
                      (protocol PXD1, {:?} mode, {} rounds)",
                     coord.local_addr()?, cfg.nranks, cfg.mode, cfg.rounds);
            let report = coord.run()?;
            println!("fleet done: {} rounds, final loss {:.6}, \
                      {} rank(s) excluded {:?}, {} replacement(s) admitted",
                     report.rounds,
                     report.losses.last().copied().unwrap_or(f64::NAN),
                     report.excluded.len(), report.excluded, report.replacements);
        }
        "worker" => {
            let model = opts.compile()?;
            let addr = args.str_or("addr", "127.0.0.1:7979");
            let tag = args.str_or("tag", "worker");
            let mut wc = WorkerConfig::new(&addr, &tag);
            if let Some(w) = args.get("warm-start") {
                wc.warm_start = Some(PathBuf::from(w));
            }
            let every = args.u64_or("snapshot-every", 0);
            match (args.get("out"), every) {
                (Some(out), e) if e > 0 => {
                    wc.snapshot = Some(SnapshotCfg {
                        dir: PathBuf::from(out),
                        every: e,
                        retain: args.usize_or("retain", 3),
                    });
                }
                (None, e) if e > 0 => anyhow::bail!("--snapshot-every needs --out <dir>"),
                _ => {}
            }
            let report = dist::worker::run(model, wc)?;
            println!("rank {} done: {} rounds applied, final loss {:.6}, \
                      {} snapshot(s) offered",
                     report.rank, report.losses.len(),
                     report.losses.last().copied().unwrap_or(f64::NAN),
                     report.snapshots);
        }
        other => anyhow::bail!("--dist expects coordinator|worker, got {other:?}"),
    }
    Ok(())
}

/// Continuous-batching TCP inference: compile (optionally pre-train), shed
/// training state into a KV-cached decode session, serve `PXF1` frames.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = CompiledOpts::from_args(args, "gpt2-s");
    let port = args.usize_or("port", 7878);
    let max_batch = args.usize_or("max-batch", 8);
    let queue_depth = args.usize_or("queue-depth", 64);
    let steps = args.usize_or("steps", 0);
    let io_timeout_ms = args.u64_or("io-timeout-ms", 30_000);
    let mut model = opts.compile()?;
    if let Some(w) = args.get("weights") {
        // warm-start: a .pxck file, or a snapshot dir (newest wins) —
        // straight into the frozen session, no recompile-train. A corrupt
        // or missing checkpoint is a typed error naming the file — never
        // a panic, never a silent fall-through to seed weights.
        let t0 = std::time::Instant::now();
        let info = model.load_weights(Path::new(w))?;
        println!("warm-start {w} (step {}, {}) in {:.1}ms",
                 info.step, info.meta, t0.elapsed().as_secs_f64() * 1e3);
    }
    if steps > 0 {
        let report = model.train(steps, args.f32_or("lr", 1e-2),
                                 args.f32_or("momentum", 0.9), opts.seed);
        println!("{}", report.summary_line());
    }
    let sess = model.into_decode(max_batch)?;
    println!(
        "decode session: {} params, {} KV slots x {} positions ({:.1} KiB cache), \
         training state shed to {}B",
        sess.param_count(), sess.max_slots(), sess.max_seq(),
        sess.cache_bytes() as f64 / 1024.0, sess.training_state_bytes(),
    );
    let engine = ServeEngine::start(sess, EngineConfig { max_batch, queue_depth });
    let tcp_cfg = TcpConfig {
        io_timeout: (io_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(io_timeout_ms)),
    };
    let server = TcpServer::start_with(&format!("0.0.0.0:{port}"), engine.handle(),
                                       tcp_cfg)?;
    println!("serving on {} (protocol PXF1; Ctrl-C to stop)", server.addr());
    if args.bool("smoke") {
        // CI gate: one real request through the full stack (compile →
        // freeze under the active precision tier → engine → TCP →
        // response), then exit 0. `serve --precision int8 --smoke` is
        // the end-to-end quantized-serving check.
        let d = engine.handle().d();
        let mut rng = Rng::new(opts.seed ^ 0x51);
        let prompt = Matrix::randn(8, d, 1.0, &mut rng);
        let mut stream = std::net::TcpStream::connect(server.addr())?;
        let out = pixelfly::serving::client_request(&mut stream, &prompt, 4)?
            .map_err(|e| anyhow::anyhow!("smoke request refused: {e}"))?;
        anyhow::ensure!(out.rows == 4 && out.cols == d, "smoke response shape");
        anyhow::ensure!(out.data.iter().all(|v| v.is_finite()),
                        "smoke response has non-finite values");
        println!("serve smoke ok: {}x{} response, precision={}",
                 out.rows, out.cols, exec::precision_name());
        server.stop();
        engine.shutdown();
        return Ok(());
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let m = engine.metrics();
        if m.requests > 0 {
            println!("{m}");
        }
    }
}

fn parse_lra_task(s: &str) -> Result<LraTask> {
    LraTask::all()
        .into_iter()
        .find(|t| t.name() == s)
        .ok_or_else(|| anyhow::anyhow!("unknown LRA task {s:?}"))
}

fn cmd_compare(args: &Args) -> Result<()> {
    let presets = args.str_or("presets", "mixer_s_dense,mixer_s_pixelfly");
    let steps = args.usize_or("steps", 50);
    let mut rows = Vec::new();
    for preset in presets.split(',') {
        let mut engine = Engine::new(&artifacts_dir())?;
        let cfg = TrainConfig {
            preset: preset.trim().to_string(),
            steps,
            lr: args.f32_or("lr", 1e-3),
            eval_batches: args.usize_or("eval-batches", 4),
            seed: args.u64_or("seed", 0),
            ..Default::default()
        };
        let mut trainer = Trainer::new(&mut engine, cfg)?;
        let r = trainer.train()?;
        println!("{}", r.summary_line());
        rows.push(r);
    }
    // speedup column vs the first (baseline) preset
    if let Some(base) = rows.first().and_then(|r| r.step_time.as_ref()).map(|s| s.mean_ns) {
        println!("\n{:<26} {:>10} {:>10} {:>9} {:>10} {:>9}",
                 "preset", "final", "eval", "acc/ppl", "step(ms)", "speedup");
        for r in &rows {
            let st = r.step_time.as_ref().unwrap();
            let (metric, eval_loss) = r
                .final_eval
                .map(|e| {
                    if r.preset.contains("gpt2") {
                        (format!("{:.2}", e.perplexity()), e.loss)
                    } else {
                        (format!("{:.3}", e.accuracy), e.loss)
                    }
                })
                .unwrap_or(("-".into(), f64::NAN));
            println!("{:<26} {:>10.4} {:>10.4} {:>9} {:>10.1} {:>8.2}x",
                     r.preset, r.final_loss(), eval_loss, metric,
                     st.mean_ms(), base / st.mean_ns);
        }
    }
    Ok(())
}

fn cmd_ntk_compare(args: &Args) -> Result<()> {
    // Fig 4: run each ntk_* artifact on the SAME input batch, compare grams
    let mut engine = Engine::new(&artifacts_dir())?;
    let patterns = ["dense", "pixelfly", "bigbird", "random", "lowrank", "local"];
    let n_batches = args.usize_or("batches", 1);
    let mut grams: Vec<(String, Vec<f32>)> = Vec::new();
    for p in patterns {
        let key = format!("ntk_{p}.ntk_gram");
        if engine.manifest.artifacts.get(&key).is_none() {
            continue;
        }
        let spec = engine.manifest.artifact(&key)?.clone();
        let params = engine.load_initial_state(&format!("ntk_{p}"), &key)?;
        // shared deterministic input batch across patterns — clustered
        // (Process 1 / Theorem B.1): pairs of examples share a center, so
        // the kernel has real structure for patterns to preserve or lose
        let xspec = spec.inputs.last().unwrap().clone();
        let mut acc: Vec<f32> = Vec::new();
        for b in 0..n_batches {
            let mut noise = Rng::new(1234 + b as u64);
            let dims = &xspec.dims; // [N, seq, in_dim]
            let (nex, per_ex) = (dims[0], dims[1] * dims[2]);
            let mut data = Vec::with_capacity(nex * per_ex);
            for i in 0..nex {
                let mut center = Rng::new(9000 + (i / 2) as u64);
                for _ in 0..per_ex {
                    data.push(center.normal_f32() + 0.3 * noise.normal_f32());
                }
            }
            let x = pixelfly::runtime::engine::f32_literal(&xspec.dims, &data)?;
            let mut argv: Vec<&Literal> = params.iter().collect();
            argv.push(&x);
            let art = engine.load(&key)?;
            let outs = art.exe.execute::<&Literal>(&argv)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            let g = outs[0].to_vec::<f32>()?;
            if acc.is_empty() {
                acc = g;
            } else {
                for (a, v) in acc.iter_mut().zip(g) {
                    *a += v;
                }
            }
        }
        grams.push((p.to_string(), acc));
    }
    let dense = grams
        .iter()
        .find(|(p, _)| p == "dense")
        .map(|(_, g)| g.clone())
        .ok_or_else(|| anyhow::anyhow!("ntk_dense artifact missing"))?;
    // scale-normalise each gram (unit Frobenius norm) so the comparison
    // measures kernel *shape* (training-dynamics direction), not the raw
    // parameter-count scale — models at different densities have kernels
    // of different magnitude by construction.
    let normalise = |g: &[f32]| -> Vec<f32> {
        let norm = (g.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()).sqrt() as f32;
        g.iter().map(|v| v / norm.max(1e-30)).collect()
    };
    let dn = normalise(&dense);
    println!("{:<14} {:>14} {:>16}", "pattern", "raw dist", "normalized dist");
    let mut rows: Vec<(String, f64, f64)> = grams
        .iter()
        .filter(|(p, _)| p != "dense")
        .map(|(p, g)| {
            (p.clone(),
             ntk::relative_distance(&dense, g),
             ntk::relative_distance(&dn, &normalise(g)))
        })
        .collect();
    rows.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    for (p, raw, norm) in &rows {
        println!("{p:<14} {raw:>14.4} {norm:>16.4}");
    }
    println!("\n(paper Fig 4: flat block butterfly + low-rank closest to dense)");
    Ok(())
}

fn cmd_ntk_search(args: &Args) -> Result<()> {
    let nb = args.usize_or("nb", 16);
    let block = args.usize_or("block", 4);
    let budget = args.usize_or("budget", nb * nb / 4);
    let n = args.usize_or("examples", 24);
    let mut rng = Rng::new(args.u64_or("seed", 0));
    // clustered data (Process 1)
    let dim = nb * block;
    let data: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut c = Rng::new(500 + (i / 2) as u64);
            (0..dim).map(|_| c.normal_f32() + 0.3 * rng.normal_f32()).collect()
        })
        .collect();
    let ranked = ntk::search(&data, nb, block, budget, args.u64_or("seed", 0));
    println!("Algorithm 2 ranking (budget {budget} blocks, nb={nb}):");
    println!("{:<20} {:>12} {:>10}", "pattern", "NTK dist", "density");
    for (kind, dist, dens) in ranked {
        println!("{:<20} {:>12.4} {:>10.3}", kind.name(), dist, dens);
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = args.str_or("model", "vit-s16");
    let budget_frac = args.f64_or("budget", 0.1);
    let block = args.usize_or("block", 32);
    let batch = args.usize_or("batch", 32);
    let dev = Device::with_block(block);
    let schema = models::preset(&model, batch)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
    println!("schema {model}: params={} flops/step={:.2}G",
             schema.total_params(), schema.total_flops() as f64 / 1e9);
    println!("\ncompute fractions (dense):");
    for (lt, f) in schema.compute_fractions(&dev) {
        println!("  {:<12} {:>6.1}%", lt.name(), f * 100.0);
    }
    let thumb = budget::rule_of_thumb(&schema, budget_frac, &dev);
    let opt = budget::cost_optimal(&schema, budget_frac, &dev);
    println!("\ndensity allocation (budget {:.0}%):", budget_frac * 100.0);
    println!("  {:<12} {:>14} {:>14}", "layer", "rule-of-thumb", "closed-form");
    for (lt, d) in &thumb.densities {
        println!("  {:<12} {:>14.3} {:>14.3}", lt.name(), d, opt.density_of(*lt));
    }
    println!("\nprojected speedup: thumb {:.2}x, closed-form {:.2}x",
             budget::projected_speedup(&schema, &thumb, &dev),
             budget::projected_speedup(&schema, &opt, &dev));
    let plan = planner::plan_model(&schema, &thumb, block);
    println!("\nlayer plans:");
    for p in &plan.layers {
        println!("  {:<12} {}x{} b={} max_stride={} rank={} density={:.3}",
                 p.layer.name(), p.rows, p.cols, p.block, p.max_stride, p.rank,
                 p.achieved_density);
    }
    if let Some(a) = &plan.attention {
        println!("  attention    nb={} max_stride={} global={} density={:.3}",
                 a.seq_blocks, a.max_stride, a.global_blocks, a.achieved_density);
    }
    println!("\ntotal plan density: {:.3}", plan.total_density);
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    // Table 7 (see also rust/benches/table7_microbench.rs)
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 256);
    let hw_block = 32;
    let threads = exec::threads();
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);
    println!("substrate threads: {threads}  kernel tier: {}", exec::kernel_name());
    println!("{:<12} {:>10} {:>16} {:>14} {:>12} {:>12} {:>9}",
             "pattern", "block", "expected dens", "actual dens",
             "serial(ms)", "engine(ms)", "speedup");
    let mut run = |name: &str, mask: &BlockMask, gblock: usize| {
        let cover = mask.block_cover(hw_block, hw_block);
        let w = BsrMatrix::random(&cover, hw_block, 0.1, &mut Rng::new(1));
        let mut y = Matrix::zeros(batch, w.cols_elems());
        let ser = time_it(1, 5, || w.matmul_serial_into(&x, &mut y));
        let plan = w.plan(threads);
        let par = time_it(1, 5, || w.matmul_with_plan(&plan, &x, &mut y));
        println!("{:<12} {:>7}x{:<3} {:>15.2}% {:>13.2}% {:>12.2} {:>12.2} {:>8.2}x",
                 name, gblock, gblock,
                 100.0 * mask.density(),
                 100.0 * mask.actual_density(hw_block),
                 ser.mean_ms(),
                 par.mean_ms(),
                 ser.mean_ns / par.mean_ns);
    };
    for g in [1usize, 2, 4, 8, 16, 32] {
        let density = 0.0125;
        let m = baselines::random_grouped_mask(n, g, density, &mut Rng::new(2));
        run("random", &m, g);
    }
    let nb = n / hw_block;
    let bf = flat_butterfly_mask(nb, nb.min(8)).expand(hw_block);
    run("pixelfly", &bf, hw_block);
    Ok(())
}

fn cmd_flatbench(args: &Args) -> Result<()> {
    // Fig 11 (see also rust/benches/fig11_flat_vs_product.rs)
    let n = args.usize_or("n", 1024);
    let batch = args.usize_or("batch", 512);
    let block = args.usize_or("block", 32);
    let mut rng = Rng::new(0);
    let x = Matrix::randn(batch, n, 1.0, &mut rng);
    println!("{:<10} {:>14} {:>14} {:>10}", "stride", "product(ms)", "flat(ms)", "speedup");
    let nb = n / block;
    let mut k = 2;
    while k <= nb {
        let bp = ButterflyProduct::random(n, block, k, 0.1, &mut rng);
        let flat = bp.flatten();
        let sp = time_it(1, 5, || {
            std::hint::black_box(bp.matmul(&x));
        });
        let mut y = Matrix::zeros(batch, n);
        let sf = time_it(1, 5, || flat.matmul_into(&x, &mut y));
        println!("{:<10} {:>14.2} {:>14.2} {:>9.2}x", k, sp.mean_ms(), sf.mean_ms(),
                 sp.mean_ns / sf.mean_ns);
        k *= 2;
    }
    Ok(())
}
