//! Hardware cost model (paper Appendix A).
//!
//! ```text
//! Totalcost = Cost_mem * N_blockmem + Cost_flop * N_flop
//! ```
//!
//! The single modelling decision that drives the whole paper: memory is
//! accessed in blocks of `b` contiguous elements, so a sparse matrix's
//! memory cost is the number of nonzero blocks in its `(1, b)` (for the
//! forward pass) — in practice `(b, b)` since both W and Wᵀ are touched —
//! block cover, NOT its nnz.  This module projects latencies for the
//! microbenchmarks (Table 7), the budget allocator (Appendix I), and the
//! end-to-end speedup estimates.

use crate::patterns::BlockMask;

/// Device description. Defaults model a V100-class block device as in the
/// paper (32-wide coalescing, memory-bound sparse GEMMs).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// hardware block size b (elements per coalesced access)
    pub block: usize,
    /// cost of one block memory access (arbitrary time units)
    pub cost_mem: f64,
    /// cost of one floating point op (same units)
    pub cost_flop: f64,
}

impl Default for Device {
    fn default() -> Self {
        // mem:flop ratio ~100:1 per element-block — memory-dominated, as
        // Appendix A argues for block-sparse GEMM on GPUs.
        Device { block: 32, cost_mem: 100.0, cost_flop: 1.0 }
    }
}

impl Device {
    pub fn with_block(block: usize) -> Self {
        Device { block, ..Default::default() }
    }
}

/// Cost of one sparse GEMM  y[m, nc] = x[m, nr] * W  where W has the given
/// element-level mask.  Memory: blocks of W touched (via the (b,b) cover,
/// fwd+bwd symmetric) + streaming x and y; FLOPs: 2 * m * touched
/// elements (the hardware computes whole blocks).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cost {
    pub n_blockmem: u64,
    pub n_flop: u64,
    pub total: f64,
}

pub fn masked_gemm_cost(mask: &BlockMask, m: usize, dev: &Device) -> Cost {
    let b = dev.block;
    let cover = mask.block_cover(b, b);
    let touched_blocks = cover.nnz() as u64;
    let touched_elems = touched_blocks * (b * b) as u64;
    // weight blocks + x stream + y stream (in b-element lines)
    let x_blocks = (m as u64) * (mask.rows.div_ceil(b) as u64);
    let y_blocks = (m as u64) * (mask.cols.div_ceil(b) as u64);
    let n_blockmem = touched_blocks + x_blocks + y_blocks;
    let n_flop = 2 * (m as u64) * touched_elems;
    Cost {
        n_blockmem,
        n_flop,
        total: dev.cost_mem * n_blockmem as f64 + dev.cost_flop * n_flop as f64,
    }
}

pub fn dense_gemm_cost(rows: usize, cols: usize, m: usize, dev: &Device) -> Cost {
    masked_gemm_cost(&BlockMask::ones(rows, cols), m, dev)
}

/// Projected latency ratio dense/sparse for a masked GEMM (the "Speedup"
/// columns of Figs 5–9 at the cost-model level).
pub fn projected_speedup(mask: &BlockMask, m: usize, dev: &Device) -> f64 {
    let dense = dense_gemm_cost(mask.rows, mask.cols, m, dev);
    let sparse = masked_gemm_cost(mask, m, dev);
    dense.total / sparse.total
}

/// Sequential butterfly *product* cost: log2(k) factor GEMMs, each
/// streaming activations fully (Fig 11 baseline).
pub fn butterfly_product_cost(n: usize, max_stride_blocks: usize, m: usize,
                              dev: &Device) -> Cost {
    let b = dev.block;
    let nb = n / b;
    let logk = max_stride_blocks.trailing_zeros() as u64;
    let factor_blocks = (2 * nb) as u64; // 2 nonzero blocks per block row
    let act_blocks = (m as u64) * (nb as u64);
    let n_blockmem = logk * (factor_blocks + 2 * act_blocks);
    let n_flop = logk * 2 * (m as u64) * factor_blocks * (b * b) as u64;
    Cost {
        n_blockmem,
        n_flop,
        total: dev.cost_mem * n_blockmem as f64 + dev.cost_flop * n_flop as f64,
    }
}

/// Flat butterfly cost: ONE sparse GEMM with (log2 k + 1) blocks per row.
pub fn flat_butterfly_cost(n: usize, max_stride_blocks: usize, m: usize,
                           dev: &Device) -> Cost {
    let b = dev.block;
    let nb = n / b;
    let mask = crate::patterns::flat_butterfly_mask(nb, max_stride_blocks.min(nb))
        .expand(b);
    masked_gemm_cost(&mask, m, dev)
}

/// Attention cost for a block mask over sq/b x sk/b blocks, head dim d.
pub fn attention_cost(mask: &BlockMask, b: usize, d: usize, heads: usize,
                      dev: &Device) -> Cost {
    let visible = mask.nnz() as u64;
    // per visible block: QK^T (b*b*d mults), PV (b*b*d)
    let n_flop = (heads as u64) * visible * 4 * (b * b * d) as u64;
    // per visible block: one K tile + one V tile (b*d/b lines each) + Q resident
    let lines_per_tile = (b * d).div_ceil(dev.block) as u64;
    let n_blockmem = (heads as u64) * visible * 2 * lines_per_tile;
    Cost {
        n_blockmem,
        n_flop,
        total: dev.cost_mem * n_blockmem as f64 + dev.cost_flop * n_flop as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::baselines;
    use crate::patterns::flat_butterfly_mask;
    use crate::util::Rng;

    #[test]
    fn dense_cost_scales_with_size() {
        let dev = Device::default();
        let a = dense_gemm_cost(256, 256, 64, &dev);
        let b = dense_gemm_cost(512, 512, 64, &dev);
        assert!(b.total > 3.0 * a.total);
    }

    #[test]
    fn aligned_sparse_beats_dense() {
        let dev = Device::with_block(32);
        let mask = flat_butterfly_mask(32, 4).expand(32); // 1024x1024
        let sp = projected_speedup(&mask, 1024, &dev);
        assert!(sp > 2.0, "speedup {sp}");
    }

    #[test]
    fn unaligned_random_is_no_faster_than_dense() {
        // Appendix A: random elementwise sparsity at 1-2% density touches
        // ~all blocks -> cost ~ dense (Hooker's hardware lottery).
        let dev = Device::with_block(32);
        let mut rng = Rng::new(5);
        let mask = baselines::random_element_mask(512, 0.02, &mut rng);
        let sp = projected_speedup(&mask, 512, &dev);
        assert!(sp < 1.2, "speedup {sp} should be ~1");
    }

    #[test]
    fn flat_beats_product_in_cost_model() {
        // Fig 11 at the cost-model level
        let dev = Device::with_block(32);
        let flat = flat_butterfly_cost(1024, 32, 2048, &dev);
        let prod = butterfly_product_cost(1024, 32, 2048, &dev);
        let ratio = prod.total / flat.total;
        assert!(ratio > 1.5, "flat should win clearly, ratio {ratio}");
        assert!(ratio < 10.0, "but not absurdly, ratio {ratio}");
    }

    #[test]
    fn attention_cost_tracks_visible_fraction() {
        let dev = Device::default();
        let full = attention_cost(&BlockMask::ones(16, 16), 32, 64, 4, &dev);
        let sparse_mask = baselines::pixelfly_attention_mask(16, 2, 1);
        let sparse = attention_cost(&sparse_mask, 32, 64, 4, &dev);
        let expect = sparse_mask.density();
        let got = sparse.total / full.total;
        assert!((got - expect).abs() < 0.02, "got {got} expect {expect}");
    }

    #[test]
    fn cost_components_nonzero() {
        let dev = Device::default();
        let c = dense_gemm_cost(64, 64, 8, &dev);
        assert!(c.n_blockmem > 0 && c.n_flop > 0 && c.total > 0.0);
    }
}
