//! Model schemas (paper Appendix K.2): layer types, repeat counts, GEMM
//! dimensions — the input to the budget allocator and the planner, plus
//! parameter/FLOP accounting mirroring Tables 4–6.

use crate::costmodel::{dense_gemm_cost, Device};

/// Layer types with distinct sparsification behaviour (paper §3.3 step 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerType {
    /// attention projection GEMMs (q/k/v/o)
    AttnProj,
    /// the attention score/value matmuls (seq x seq)
    AttnScore,
    /// MLP / mixer channel GEMMs
    Mlp,
    /// token-mixing GEMMs (mixer only)
    TokenMix,
    /// embeddings / classifier head (kept dense by the paper)
    Dense,
}

impl LayerType {
    pub fn name(&self) -> &'static str {
        match self {
            LayerType::AttnProj => "attn_proj",
            LayerType::AttnScore => "attn_score",
            LayerType::Mlp => "mlp",
            LayerType::TokenMix => "token_mix",
            LayerType::Dense => "dense",
        }
    }

    /// Layers the paper sparsifies (embeddings/heads stay dense).
    pub fn sparsifiable(&self) -> bool {
        !matches!(self, LayerType::Dense)
    }
}

/// One entry of the model schema: `count` GEMMs of shape [m x n] applied
/// to `tokens_per_batch` rows.
#[derive(Clone, Copy, Debug)]
pub struct SchemaEntry {
    pub layer: LayerType,
    pub count: usize,
    pub rows: usize,
    pub cols: usize,
    pub tokens: usize,
}

impl SchemaEntry {
    /// Dense matrix elements of this entry (budget-accounting proxy; for
    /// AttnScore this is the score-matrix size, not trainable weights).
    pub fn params(&self) -> usize {
        self.count * self.rows * self.cols
    }

    /// Trainable weight parameters (0 for attention score matrices).
    pub fn weight_params(&self) -> usize {
        if self.layer == LayerType::AttnScore {
            0
        } else {
            self.params()
        }
    }

    pub fn flops(&self) -> u64 {
        2 * (self.count as u64) * (self.rows as u64) * (self.cols as u64)
            * (self.tokens as u64)
    }

    /// Dense cost under the hardware model.
    pub fn dense_cost(&self, dev: &Device) -> f64 {
        self.count as f64 * dense_gemm_cost(self.rows, self.cols, self.tokens, dev).total
    }
}

/// A full model schema.
#[derive(Clone, Debug)]
pub struct ModelSchema {
    pub name: String,
    pub entries: Vec<SchemaEntry>,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    /// autoregressive attention (LM presets); a schema property so the
    /// model compiler never has to guess from the preset name
    pub causal: bool,
}

/// Coarse architecture family a schema describes — what the model
/// compiler dispatches on when turning entries into blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFamily {
    /// attention + MLP blocks (ViT / GPT-2 shape)
    Transformer,
    /// token-mixing + channel MLP blocks
    Mixer,
}

impl ModelSchema {
    /// Architecture family derived from the entry set: any token-mixing
    /// entry makes it a mixer; any attention projection a transformer.
    pub fn family(&self) -> Option<ModelFamily> {
        if self.entries.iter().any(|e| e.layer == LayerType::TokenMix) {
            Some(ModelFamily::Mixer)
        } else if self.entries.iter().any(|e| e.layer == LayerType::AttnProj) {
            Some(ModelFamily::Transformer)
        } else {
            None
        }
    }

    /// Hidden width of the channel MLP (the `d_model -> hidden` entry).
    pub fn mlp_hidden(&self) -> Option<usize> {
        self.entries
            .iter()
            .find(|e| e.layer == LayerType::Mlp && e.rows == self.d_model)
            .map(|e| e.cols)
    }

    /// Hidden width of the mixer's token-mixing MLP (`seq -> hidden`).
    pub fn token_hidden(&self) -> Option<usize> {
        self.entries
            .iter()
            .find(|e| e.layer == LayerType::TokenMix && e.rows == self.seq_len)
            .map(|e| e.cols)
    }

    pub fn total_params(&self) -> usize {
        self.entries.iter().map(|e| e.weight_params()).sum()
    }

    pub fn total_flops(&self) -> u64 {
        self.entries.iter().map(|e| e.flops()).sum()
    }

    pub fn sparsifiable_params(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.layer.sparsifiable())
            .map(|e| e.params())
            .sum()
    }

    /// Compute-fraction per layer type (the §3.3 rule-of-thumb input).
    pub fn compute_fractions(&self, dev: &Device) -> Vec<(LayerType, f64)> {
        let mut per: Vec<(LayerType, f64)> = Vec::new();
        let total: f64 = self.entries.iter().map(|e| e.dense_cost(dev)).sum();
        for e in &self.entries {
            let cost = e.dense_cost(dev) / total;
            if let Some(p) = per.iter_mut().find(|(l, _)| *l == e.layer) {
                p.1 += cost;
            } else {
                per.push((e.layer, cost));
            }
        }
        per
    }
}

/// Transformer encoder/decoder schema (ViT / GPT-2 shape).
pub fn transformer_schema(name: &str, d: usize, layers: usize, seq: usize,
                          mlp_ratio: usize, batch: usize) -> ModelSchema {
    let tokens = batch * seq;
    ModelSchema {
        name: name.to_string(),
        seq_len: seq,
        d_model: d,
        n_layers: layers,
        causal: false,
        entries: vec![
            SchemaEntry { layer: LayerType::AttnProj, count: 4 * layers, rows: d, cols: d, tokens },
            SchemaEntry { layer: LayerType::AttnScore, count: 2 * layers, rows: seq, cols: seq, tokens: batch * d },
            SchemaEntry { layer: LayerType::Mlp, count: layers, rows: d, cols: mlp_ratio * d, tokens },
            SchemaEntry { layer: LayerType::Mlp, count: layers, rows: mlp_ratio * d, cols: d, tokens },
        ],
    }
}

/// MLP-Mixer schema.
pub fn mixer_schema(name: &str, d: usize, layers: usize, seq: usize,
                    mlp_ratio: usize, batch: usize) -> ModelSchema {
    ModelSchema {
        name: name.to_string(),
        seq_len: seq,
        d_model: d,
        n_layers: layers,
        causal: false,
        entries: vec![
            SchemaEntry { layer: LayerType::TokenMix, count: layers, rows: seq, cols: 2 * seq, tokens: batch * d },
            SchemaEntry { layer: LayerType::TokenMix, count: layers, rows: 2 * seq, cols: seq, tokens: batch * d },
            SchemaEntry { layer: LayerType::Mlp, count: layers, rows: d, cols: mlp_ratio * d, tokens: batch * seq },
            SchemaEntry { layer: LayerType::Mlp, count: layers, rows: mlp_ratio * d, cols: d, tokens: batch * seq },
        ],
    }
}

/// Named presets mirroring the paper's model zoo (scaled; Tables 4–6).
/// LM presets (`gpt2-*`) are marked causal; everything else attends
/// bidirectionally.
pub fn preset(name: &str, batch: usize) -> Option<ModelSchema> {
    let mut schema = match name {
        // paper-scale schemas (for budget/cost projections; not trained here)
        "mixer-s16" => mixer_schema(name, 512, 8, 196, 4, batch),
        "mixer-b16" => mixer_schema(name, 768, 12, 196, 4, batch),
        "vit-s16" => transformer_schema(name, 384, 12, 196, 4, batch),
        "vit-b16" => transformer_schema(name, 768, 12, 196, 4, batch),
        "gpt2-small" => transformer_schema(name, 768, 12, 512, 4, batch),
        "gpt2-medium" => transformer_schema(name, 1024, 24, 512, 4, batch),
        // scaled-down testbed schemas matching the AOT presets
        "mixer-s" => mixer_schema(name, 128, 2, 64, 2, batch),
        "vit-s" => transformer_schema(name, 128, 2, 64, 2, batch),
        "gpt2-s" => transformer_schema(name, 128, 2, 128, 2, batch),
        "lra" => transformer_schema(name, 64, 1, 512, 2, batch),
        _ => return None,
    };
    schema.causal = name.starts_with("gpt2");
    Some(schema)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_match_hand_count() {
        let s = transformer_schema("t", 128, 2, 64, 2, 1);
        // 4*2 projections of 128x128 + 2 layers * (128*256 + 256*128)
        let expect = 8 * 128 * 128 + 2 * 2 * 128 * 256;
        assert_eq!(s.total_params(), expect);
    }

    #[test]
    fn fractions_sum_to_one() {
        let dev = Device::default();
        for name in ["mixer-s", "vit-s", "gpt2-s", "gpt2-medium"] {
            let s = preset(name, 8).unwrap();
            let total: f64 = s.compute_fractions(&dev).iter().map(|(_, f)| f).sum();
            assert!((total - 1.0).abs() < 1e-9, "{name}: {total}");
        }
    }

    #[test]
    fn attention_dominates_long_sequences() {
        let dev = Device::default();
        let short = transformer_schema("s", 256, 4, 128, 4, 8);
        let long = transformer_schema("l", 256, 4, 2048, 4, 8);
        let frac = |s: &ModelSchema| {
            s.compute_fractions(&dev)
                .iter()
                .find(|(l, _)| *l == LayerType::AttnScore)
                .map(|(_, f)| *f)
                .unwrap_or(0.0)
        };
        assert!(frac(&long) > frac(&short));
        assert!(frac(&long) > 0.5, "LRA regime: attention is the bottleneck");
    }

    #[test]
    fn vit_mlp_vs_attn_ratio_about_two() {
        // paper §5.3: ViT-small MLP:attention compute ~ 2:1 at seq 196
        let dev = Device::default();
        let s = preset("vit-s16", 64).unwrap();
        let fr = s.compute_fractions(&dev);
        let get = |lt: LayerType| fr.iter().find(|(l, _)| *l == lt).map(|(_, f)| *f).unwrap();
        let mlp = get(LayerType::Mlp);
        let attn = get(LayerType::AttnProj) + get(LayerType::AttnScore);
        let ratio = mlp / attn;
        assert!(ratio > 0.8 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn lm_presets_are_causal() {
        for name in ["gpt2-s", "gpt2-small", "gpt2-medium"] {
            assert!(preset(name, 4).unwrap().causal, "{name}");
        }
        for name in ["vit-s", "mixer-s", "lra"] {
            assert!(!preset(name, 4).unwrap().causal, "{name}");
        }
    }

    #[test]
    fn family_and_hidden_dims_derive_from_entries() {
        let vit = preset("vit-s", 4).unwrap();
        assert_eq!(vit.family(), Some(ModelFamily::Transformer));
        assert_eq!(vit.mlp_hidden(), Some(2 * vit.d_model));
        assert_eq!(vit.token_hidden(), None);
        let mixer = preset("mixer-s", 4).unwrap();
        assert_eq!(mixer.family(), Some(ModelFamily::Mixer));
        assert_eq!(mixer.mlp_hidden(), Some(2 * mixer.d_model));
        assert_eq!(mixer.token_hidden(), Some(2 * mixer.seq_len));
    }

    #[test]
    fn presets_exist() {
        for n in ["mixer-s16", "mixer-b16", "vit-s16", "vit-b16", "gpt2-small",
                  "gpt2-medium", "mixer-s", "vit-s", "gpt2-s", "lra"] {
            assert!(preset(n, 4).is_some(), "{n}");
        }
        assert!(preset("nope", 4).is_none());
    }
}
