//! Crash-safe checkpoint & weight-format layer (DESIGN.md "Checkpoint &
//! weight format").
//!
//! Three durability layers over one versioned binary format (`PXCK`):
//!
//! - **Atomic snapshots** ([`writer`]): serialize into `<path>.tmp`,
//!   fsync, rename, fsync the parent directory. A background
//!   [`Snapshotter`] thread (fed through the pool's `Doorbell` primitive,
//!   latest-wins, double-buffered) takes the file I/O off the training
//!   step entirely.
//! - **Corruption-checked fast load** ([`loader`]): one read, then
//!   magic/version/fingerprint/CRC validation with typed [`CkptError`]s —
//!   a damaged file is rejected loudly, never loaded silently wrong.
//! - **Fault injection** ([`faults`]): env-gated write-kill / short-read /
//!   bit-flip hooks on the loader/writer chokepoints, so tests prove the
//!   recover-or-reject story instead of asserting it.
//!
//! The paper's fixed flat-block-butterfly + low-rank pattern makes the
//! format simple: masks never change during training, so a block-sparse
//! weight is its CSR block index (written once, verified on load) plus
//! the raw block payload. Modules expose their state through the
//! [`crate::nn::Module`] visitor methods (`state_tensors` / `load_state`);
//! this module never reaches into layer internals.

pub mod faults;
pub mod format;
pub mod loader;
pub mod writer;

pub use format::{crc32, CkptError};
pub use loader::{load, Ckpt};
pub use writer::{write_atomic, SnapReport, Snapshot, Snapshotter};

use crate::sparse::bsr::BsrMatrix;

/// One owned state tensor inside a [`Snapshot`] — f32 payloads (weights,
/// biases, momentum), u32 structure tensors (CSR block indices), or i8
/// quantized payloads (per-block int8 weights from quantize-at-freeze;
/// their f32 scales travel as a separate F32 tensor). The presence of any
/// I8 tensor bumps the file to format version 2 — older binaries reject
/// such files up front instead of misreading 1-byte payloads as f32.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U32(Vec<u32>),
    I8(Vec<i8>),
}

impl TensorData {
    /// Entry-table kind tag (0 = f32, 1 = u32, 2 = i8).
    pub fn kind(&self) -> u8 {
        match self {
            TensorData::F32(_) => 0,
            TensorData::U32(_) => 1,
            TensorData::I8(_) => 2,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::U32(v) => v.len(),
            TensorData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        format::kind_byte_width(self.kind()) * self.len()
    }

    /// Append the little-endian payload bytes to `out`.
    pub fn extend_bytes(&self, out: &mut Vec<u8>) {
        match self {
            TensorData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::U32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I8(v) => {
                out.extend(v.iter().map(|&x| x as u8));
            }
        }
    }
}

/// A borrowed view of one state tensor during save — what
/// `Module::state_tensors` hands its visitor. f32 tensors are borrowed
/// straight out of the module; u32 structure tensors (CSR indices) are
/// materialised on the fly, so they arrive owned.
pub enum StateItem<'a> {
    F32(&'a [f32]),
    U32(Vec<u32>),
}

impl StateItem<'_> {
    pub fn kind(&self) -> u8 {
        match self {
            StateItem::F32(_) => 0,
            StateItem::U32(_) => 1,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            StateItem::F32(s) => s.len(),
            StateItem::U32(v) => v.len(),
        }
    }
}

/// What `Module::load_state` restores from: f32 tensors are copied into
/// the module's buffers, u32 structure tensors are VERIFIED against the
/// freshly-compiled skeleton (a checkpoint never mutates a model's
/// sparsity structure — a pattern difference is a schema mismatch).
pub trait StateSource {
    /// Copy tensor `name` into `dst`; typed error if absent, the wrong
    /// kind, or the wrong length.
    fn load_f32(&mut self, name: &str, dst: &mut [f32]) -> Result<(), CkptError>;

    /// Check that the stored u32 tensor `name` equals `want` exactly;
    /// a difference is a [`CkptError::SchemaMismatch`].
    fn expect_u32(&mut self, name: &str, want: &[u32]) -> Result<(), CkptError>;
}

/// Flatten a BSR weight's structure into its checkpoint index tensor:
/// `[nbr, nbc, block, row_ptr.., cols..]`. Written once per weight and
/// byte-compared on load, so a checkpoint can never be applied across a
/// different mask plan.
pub fn csr_index_tensor(w: &BsrMatrix) -> Vec<u32> {
    let mut out = Vec::with_capacity(3 + w.row_ptr.len() + w.cols.len());
    out.push(w.nbr as u32);
    out.push(w.nbc as u32);
    out.push(w.block as u32);
    out.extend(w.row_ptr.iter().map(|&v| v as u32));
    out.extend(w.cols.iter().map(|&v| v as u32));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::BlockMask;
    use crate::util::Rng;

    #[test]
    fn csr_tensor_round_trips_structure() {
        let mask = BlockMask::ones(3, 2);
        let w = BsrMatrix::random(&mask, 4, 0.1, &mut Rng::new(1));
        let t = csr_index_tensor(&w);
        assert_eq!(&t[..3], &[3, 2, 4]);
        assert_eq!(t.len(), 3 + w.row_ptr.len() + w.cols.len());
        // same structure → same tensor; different structure → different
        let w2 = BsrMatrix::random(&mask, 4, 0.9, &mut Rng::new(7));
        assert_eq!(t, csr_index_tensor(&w2), "values must not affect structure");
    }
}
