//! Fault injection for the checkpoint I/O chokepoints.
//!
//! Every byte the checkpoint layer moves goes through [`write_file`] /
//! [`read_file`], so a single armed fault can simulate the three failure
//! classes the durability story must survive:
//!
//! - `kill-write@K` — the process "crashes" after K bytes of a write: the
//!   truncated file stays on disk (fsynced, like a real power cut mid
//!   `write(2)`) and the call errors, so the atomic-rename protocol is
//!   exercised exactly where it matters (the `.tmp` never gets renamed).
//! - `short-read@K` — a read returns only the first K bytes (torn page,
//!   truncated copy).
//! - `bit-flip@K` — bit `K mod total_bits` of the read buffer flips
//!   (silent media corruption) — the CRC layer must catch it.
//!
//! Arming is test-first (`arm(spec, tag)`) with a PATH TAG: the fault
//! fires only on paths containing `tag` and disarms after firing, so
//! parallel tests with distinct temp dirs never contaminate each other.
//! The `PIXELFLY_CKPT_FAULT` env var (same shape, tag-free, e.g.
//! `PIXELFLY_CKPT_FAULT=bit-flip@100`) arms one fault at process start
//! for CLI-level experiments, mirroring the `PIXELFLY_POOL` convention.

use std::io::{self, Write};
use std::path::Path;
use std::sync::{Mutex, Once};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    KillWrite,
    ShortRead,
    BitFlip,
}

#[derive(Debug)]
struct Armed {
    kind: Kind,
    at: usize,
    /// fault fires only on paths containing this substring ("" = any)
    tag: String,
}

static ARMED: Mutex<Vec<Armed>> = Mutex::new(Vec::new());
static ENV_ONCE: Once = Once::new();

fn parse(spec: &str) -> Option<(Kind, usize)> {
    let (name, at) = spec.split_once('@')?;
    let at: usize = at.trim().parse().ok()?;
    let kind = match name.trim() {
        "kill-write" => Kind::KillWrite,
        "short-read" => Kind::ShortRead,
        "bit-flip" => Kind::BitFlip,
        _ => return None,
    };
    Some((kind, at))
}

/// Arm one fault (`"kill-write@123"`, `"short-read@64"`, `"bit-flip@7"`)
/// scoped to paths containing `tag`. One-shot: the fault disarms when it
/// fires. Returns false on an unparseable spec.
pub fn arm(spec: &str, tag: &str) -> bool {
    match parse(spec) {
        Some((kind, at)) => {
            ARMED.lock().unwrap().push(Armed { kind, at, tag: tag.to_string() });
            true
        }
        None => false,
    }
}

/// Drop every armed fault scoped to `tag` (test cleanup).
pub fn disarm(tag: &str) {
    ARMED.lock().unwrap().retain(|a| a.tag != tag);
}

fn fire(path: &Path, kind: Kind) -> Option<usize> {
    ENV_ONCE.call_once(|| {
        if let Ok(spec) = std::env::var("PIXELFLY_CKPT_FAULT") {
            if !spec.is_empty() && !arm(&spec, "") {
                eprintln!("PIXELFLY_CKPT_FAULT: ignoring unparseable spec {spec:?} \
                           (want kill-write@K | short-read@K | bit-flip@K)");
            }
        }
    });
    let p = path.to_string_lossy();
    let mut g = ARMED.lock().unwrap();
    let i = g.iter().position(|a| a.kind == kind && p.contains(a.tag.as_str()))?;
    Some(g.remove(i).at)
}

/// Create `path` and durably write `bytes` (the writer's one file-write
/// chokepoint). An armed `kill-write` persists only the first K bytes
/// and errors — simulating a crash mid-write.
pub fn write_file(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let kill = fire(path, Kind::KillWrite);
    let mut f = std::fs::File::create(path)?;
    match kill {
        Some(k) => {
            let k = k.min(bytes.len());
            f.write_all(&bytes[..k])?;
            f.sync_all()?;
            Err(io::Error::new(
                io::ErrorKind::Other,
                format!("injected write kill after {k} bytes"),
            ))
        }
        None => {
            f.write_all(bytes)?;
            f.sync_all()
        }
    }
}

/// Read the whole file (the loader's one read chokepoint), with armed
/// short-read / bit-flip faults applied to the returned buffer.
pub fn read_file(path: &Path) -> io::Result<Vec<u8>> {
    let mut buf = std::fs::read(path)?;
    if let Some(k) = fire(path, Kind::ShortRead) {
        buf.truncate(k.min(buf.len()));
    }
    if let Some(k) = fire(path, Kind::BitFlip) {
        if !buf.is_empty() {
            let bit = k % (buf.len() * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
        }
    }
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_bad_specs_do_not() {
        assert!(parse("kill-write@10").is_some());
        assert!(parse("short-read@0").is_some());
        assert!(parse("bit-flip@ 7").is_some());
        assert!(parse("explode@3").is_none());
        assert!(parse("bit-flip").is_none());
        assert!(parse("bit-flip@x").is_none());
    }

    #[test]
    fn faults_are_tag_scoped_and_one_shot() {
        let dir = std::env::temp_dir().join("pxck-faults-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let tagged = dir.join("fault-unit-tagged.bin");
        let other = dir.join("fault-unit-other.bin");
        write_file(&other, b"hello").unwrap();
        write_file(&tagged, b"hello").unwrap();

        assert!(arm("short-read@2", "fault-unit-tagged"));
        // wrong path: untouched
        assert_eq!(read_file(&other).unwrap(), b"hello");
        // tagged path: truncated once…
        assert_eq!(read_file(&tagged).unwrap(), b"he");
        // …and the fault is consumed
        assert_eq!(read_file(&tagged).unwrap(), b"hello");

        assert!(arm("kill-write@3", "fault-unit-tagged"));
        assert!(write_file(&tagged, b"world!").is_err());
        assert_eq!(std::fs::read(&tagged).unwrap(), b"wor", "partial write persisted");
        disarm("fault-unit-tagged");
    }
}
