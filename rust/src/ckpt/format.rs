//! The `PXCK` on-disk weight format: header/entry layout, CRC32, schema
//! fingerprint, typed load errors.
//!
//! Layout (little-endian throughout; see DESIGN.md "Checkpoint & weight
//! format" for the byte diagram):
//!
//! ```text
//! "PXCK" | u32 version | u64 fingerprint | u64 step
//! u32 meta_len | meta (utf-8)
//! u32 n_entries
//! per entry: u16 name_len | name | u8 kind | u64 offset | u64 len | u32 crc
//! u32 header_crc          (over every byte above)
//! payload                 (entries' raw bytes, offsets relative to here)
//! ```
//!
//! Every byte of the file is covered by a checksum: the header (magic
//! through the entry table) by `header_crc`, each payload section by its
//! entry's `crc`. A flipped bit anywhere surfaces as [`CkptError::BadCrc`]
//! — never as silently wrong weights.

use std::fmt;

use super::TensorData;

pub const MAGIC: &[u8; 4] = b"PXCK";
/// Baseline format revision: tensor kinds 0 (f32) and 1 (u32).
pub const VERSION: u32 = 1;
/// Revision that introduced the quantized tensor kind 2 (i8, 1 byte per
/// element). The encoder stays at [`VERSION`] unless a kind-2 tensor is
/// present, so checkpoints that don't use quantization remain readable
/// by older binaries; the loader accepts kind 2 only from version-2
/// files (a kind-2 entry in a v1 file is [`CkptError::WrongKind`]).
pub const VERSION_QUANT: u32 = 2;
/// Newest revision this binary reads and writes.
pub const MAX_VERSION: u32 = VERSION_QUANT;

/// Payload bytes per element for an entry-table kind tag. Unknown kinds
/// are the loader's problem (typed [`CkptError::WrongKind`]) — this maps
/// only the kinds the format defines.
pub fn kind_byte_width(kind: u8) -> usize {
    match kind {
        2 => 1,
        _ => 4,
    }
}

/// Sanity bound on the entry count so a corrupt header can't drive a
/// multi-GiB table allocation before the CRC check rejects it.
pub const MAX_ENTRIES: u32 = 1 << 20;

/// Typed checkpoint error surface: every failure mode of save/load is a
/// variant, so callers (and the fault-injection suite) can assert the
/// loader REJECTS corruption instead of panicking or silently loading
/// wrong weights.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// the file does not start with `PXCK`
    BadMagic,
    /// written by a newer format revision than this binary understands
    FutureVersion { found: u32 },
    /// the file ends before a section it promises
    Truncated { what: &'static str, needed: usize, have: usize },
    /// a checksum mismatch in the named section (header or a tensor)
    BadCrc { section: String },
    /// the checkpoint does not describe this model (architecture, budget,
    /// block size or sparsity pattern differ)
    SchemaMismatch { detail: String },
    /// a tensor the model expects is absent
    MissingTensor { name: String },
    /// a tensor exists but with the wrong element count
    WrongLen { name: String, want: usize, got: usize },
    /// a tensor exists but with the wrong element type
    WrongKind { name: String },
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint i/o: {e}"),
            CkptError::BadMagic => write!(f, "not a PXCK checkpoint (bad magic)"),
            CkptError::FutureVersion { found } => {
                write!(f, "checkpoint format v{found} is newer than this binary \
                           (supports up to v{MAX_VERSION})")
            }
            CkptError::Truncated { what, needed, have } => {
                write!(f, "checkpoint truncated in {what}: need {needed} bytes, \
                           have {have}")
            }
            CkptError::BadCrc { section } => {
                write!(f, "checkpoint corrupt: CRC mismatch in {section}")
            }
            CkptError::SchemaMismatch { detail } => {
                write!(f, "checkpoint schema mismatch: {detail}")
            }
            CkptError::MissingTensor { name } => {
                write!(f, "checkpoint is missing tensor {name:?}")
            }
            CkptError::WrongLen { name, want, got } => {
                write!(f, "tensor {name:?} has {got} elements, model wants {want}")
            }
            CkptError::WrongKind { name } => {
                write!(f, "tensor {name:?} has the wrong element type")
            }
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3 polynomial, table-driven) — std has no checksum and
// the crate policy is no external deps, so the 8-line classic lives here.
// ---------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

static CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// FNV-1a schema fingerprint
// ---------------------------------------------------------------------

/// Incremental FNV-1a (64-bit) — the header fingerprint hashes the state
/// schema (every tensor's name, kind and length, in enumeration order),
/// so a checkpoint of a differently-planned model is rejected up front.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xCBF2_9CE4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Fold one tensor's schema (name, kind tag, element count) into `h` —
/// the ONE definition both the writer (over a snapshot's tensors) and
/// the loader (over a live model's enumeration) share, so the two
/// fingerprints can never drift.
pub fn fp_tensor(h: &mut Fnv, name: &str, kind: u8, len: usize) {
    h.write(name.as_bytes());
    h.write(&[0, kind]);
    h.write(&(len as u64).to_le_bytes());
}

/// Schema fingerprint of an owned tensor list (the writer side).
pub fn fingerprint_of(tensors: &[(String, TensorData)]) -> u64 {
    let mut h = Fnv::new();
    for (name, t) in tensors {
        fp_tensor(&mut h, name, t.kind(), t.len());
    }
    h.finish()
}

// ---------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------

/// Serialize a snapshot into one `PXCK` byte buffer (the writer then
/// lands it atomically). Payload sections follow enumeration order;
/// offsets are relative to the payload region so the header encodes
/// first.
pub fn encode(step: u64, meta: &str, tensors: &[(String, TensorData)]) -> Vec<u8> {
    let payload_len: usize = tensors.iter().map(|(_, t)| t.byte_len()).sum();
    // versioned forward compat: bump to v2 ONLY when a quantized tensor
    // is present, so non-quantized checkpoints stay readable everywhere
    let version = if tensors.iter().any(|(_, t)| t.kind() >= 2) {
        VERSION_QUANT
    } else {
        VERSION
    };
    let mut head = Vec::with_capacity(64 + tensors.len() * 48 + meta.len());
    head.extend_from_slice(MAGIC);
    head.extend_from_slice(&version.to_le_bytes());
    head.extend_from_slice(&fingerprint_of(tensors).to_le_bytes());
    head.extend_from_slice(&step.to_le_bytes());
    head.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    head.extend_from_slice(meta.as_bytes());
    head.extend_from_slice(&(tensors.len() as u32).to_le_bytes());

    let mut payload = Vec::with_capacity(payload_len);
    for (name, t) in tensors {
        let offset = payload.len() as u64;
        let start = payload.len();
        t.extend_bytes(&mut payload);
        let crc = crc32(&payload[start..]);
        head.extend_from_slice(&(name.len() as u16).to_le_bytes());
        head.extend_from_slice(name.as_bytes());
        head.push(t.kind());
        head.extend_from_slice(&offset.to_le_bytes());
        head.extend_from_slice(&(t.len() as u64).to_le_bytes());
        head.extend_from_slice(&crc.to_le_bytes());
    }
    let hcrc = crc32(&head);
    head.extend_from_slice(&hcrc.to_le_bytes());
    head.extend_from_slice(&payload);
    head
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC32 check values (the classic "123456789" vector)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fingerprint_is_order_and_shape_sensitive() {
        let a = vec![("w".to_string(), TensorData::F32(vec![0.0; 4])),
                     ("b".to_string(), TensorData::F32(vec![0.0; 2]))];
        let mut b = a.clone();
        b.swap(0, 1);
        assert_ne!(fingerprint_of(&a), fingerprint_of(&b), "order matters");
        let c = vec![("w".to_string(), TensorData::F32(vec![0.0; 5])),
                     ("b".to_string(), TensorData::F32(vec![0.0; 2]))];
        assert_ne!(fingerprint_of(&a), fingerprint_of(&c), "length matters");
        let d = vec![("w".to_string(), TensorData::U32(vec![0; 4])),
                     ("b".to_string(), TensorData::F32(vec![0.0; 2]))];
        assert_ne!(fingerprint_of(&a), fingerprint_of(&d), "kind matters");
        // values do NOT matter: the fingerprint pins the schema, not data
        let e = vec![("w".to_string(), TensorData::F32(vec![9.0; 4])),
                     ("b".to_string(), TensorData::F32(vec![7.0; 2]))];
        assert_eq!(fingerprint_of(&a), fingerprint_of(&e));
    }

    #[test]
    fn encode_covers_every_byte_with_a_checksum() {
        let tensors = vec![("w".to_string(), TensorData::F32(vec![1.5, -2.0])),
                           ("idx".to_string(), TensorData::U32(vec![3, 4, 5]))];
        let bytes = encode(7, "m", &tensors);
        // header CRC sits right before the payload; recompute both halves
        let payload_len = 2 * 4 + 3 * 4;
        let hcrc_at = bytes.len() - payload_len - 4;
        let hcrc = u32::from_le_bytes(bytes[hcrc_at..hcrc_at + 4].try_into().unwrap());
        assert_eq!(hcrc, crc32(&bytes[..hcrc_at]));
        assert_eq!(&bytes[..4], MAGIC);
    }
}
