//! Corruption-checked checkpoint loader: one read, then validate
//! everything before a single weight reaches the model.
//!
//! The loader is deliberately paranoid — every failure mode is a typed
//! [`CkptError`], never a panic and never silently wrong weights:
//!
//! - wrong magic → `BadMagic`; newer format → `FutureVersion`
//! - file ends early anywhere → `Truncated` naming the section
//! - any flipped bit → `BadCrc` (header CRC covers the entry table,
//!   per-entry CRCs cover the payload; there are no unchecked bytes)
//! - entry table lies about the payload (offset/len out of bounds,
//!   duplicate names, absurd counts) → `Truncated` / `SchemaMismatch`
//!
//! Tensor payloads stay as one contiguous byte buffer after parse; f32
//! values are decoded straight into the model's own buffers via the
//! [`StateSource`] impl, so load cost is the single `read` plus one
//! pass over the weights.

use std::collections::HashMap;
use std::path::Path;

use super::faults;
use super::format::{self, CkptError, MAGIC, MAX_ENTRIES, MAX_VERSION, VERSION_QUANT};
use super::StateSource;

struct Entry {
    kind: u8,
    offset: usize,
    len: usize,
}

/// A parsed, fully CRC-verified checkpoint, ready to feed a model via
/// [`StateSource`].
pub struct Ckpt {
    pub step: u64,
    pub meta: String,
    /// schema fingerprint from the header — compare against the live
    /// model's before loading anything
    pub fingerprint: u64,
    entries: HashMap<String, Entry>,
    payload: Vec<u8>,
}

/// Read and validate the checkpoint at `path` (single read through the
/// fault-injection chokepoint).
pub fn load(path: &Path) -> Result<Ckpt, CkptError> {
    Ckpt::parse(faults::read_file(path)?)
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CkptError> {
        if self.buf.len() - self.pos < n {
            return Err(CkptError::Truncated {
                what,
                needed: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, CkptError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

impl Ckpt {
    /// Parse and validate one `PXCK` buffer. Order matters: cheap
    /// structural checks bound every allocation BEFORE the header CRC
    /// proves the entry table honest, and the entry table is proven
    /// honest before any payload CRC work.
    pub fn parse(bytes: Vec<u8>) -> Result<Ckpt, CkptError> {
        let mut c = Cursor { buf: &bytes, pos: 0 };
        if c.take(4, "magic")? != MAGIC {
            return Err(CkptError::BadMagic);
        }
        let version = c.u32("version")?;
        if version > MAX_VERSION {
            return Err(CkptError::FutureVersion { found: version });
        }
        let fingerprint = c.u64("fingerprint")?;
        let step = c.u64("step")?;
        let meta_len = c.u32("meta length")? as usize;
        let meta = String::from_utf8_lossy(c.take(meta_len, "meta")?).into_owned();
        let n_entries = c.u32("entry count")?;
        if n_entries > MAX_ENTRIES {
            return Err(CkptError::SchemaMismatch {
                detail: format!("entry count {n_entries} exceeds limit {MAX_ENTRIES}"),
            });
        }

        let mut raw = Vec::with_capacity(n_entries as usize);
        for _ in 0..n_entries {
            let name_len = c.u16("entry name length")? as usize;
            let name = String::from_utf8_lossy(c.take(name_len, "entry name")?)
                .into_owned();
            let kind = c.take(1, "entry kind")?[0];
            let offset = c.u64("entry offset")? as usize;
            let len = c.u64("entry length")? as usize;
            let crc = c.u32("entry crc")?;
            raw.push((name, kind, offset, len, crc));
        }

        // header CRC covers magic through the entry table — verify it
        // before trusting any offset/len the table claims
        let header_end = c.pos;
        let stored_hcrc = c.u32("header crc")?;
        if format::crc32(&bytes[..header_end]) != stored_hcrc {
            return Err(CkptError::BadCrc { section: "header".into() });
        }

        let payload = bytes[c.pos..].to_vec();
        // per-version kind ceiling: v1 defined kinds 0 (f32) / 1 (u32),
        // v2 added kind 2 (i8). A kind the writing version could not have
        // produced is a typed WrongKind — the forward-compat pin that
        // keeps an old binary from misreading a newer payload width.
        let max_kind = if version >= VERSION_QUANT { 2 } else { 1 };
        let mut entries = HashMap::with_capacity(raw.len());
        for (name, kind, offset, len, crc) in raw {
            if kind > max_kind {
                return Err(CkptError::WrongKind { name });
            }
            let width = format::kind_byte_width(kind);
            let byte_len = len
                .checked_mul(width)
                .filter(|&b| offset.checked_add(b).is_some_and(|end| end <= payload.len()))
                .ok_or(CkptError::Truncated {
                    what: "tensor payload",
                    needed: offset.saturating_add(len.saturating_mul(width)),
                    have: payload.len(),
                })?;
            if format::crc32(&payload[offset..offset + byte_len]) != crc {
                return Err(CkptError::BadCrc { section: format!("tensor {name:?}") });
            }
            if entries.insert(name.clone(), Entry { kind, offset, len }).is_some() {
                return Err(CkptError::SchemaMismatch {
                    detail: format!("duplicate tensor name {name:?}"),
                });
            }
        }

        Ok(Ckpt { step, meta, fingerprint, entries, payload })
    }

    /// Recompute the schema fingerprint from the live model's tensor
    /// enumeration and compare with the header's. `walk` must call the
    /// visitor exactly as `Module::state_tensors` does.
    pub fn matches_schema(&self, live_fingerprint: u64) -> Result<(), CkptError> {
        if self.fingerprint != live_fingerprint {
            return Err(CkptError::SchemaMismatch {
                detail: format!(
                    "checkpoint schema {:#018x} != model schema {:#018x} \
                     (meta: {})",
                    self.fingerprint, live_fingerprint, self.meta
                ),
            });
        }
        Ok(())
    }

    fn entry(&self, name: &str, kind: u8) -> Result<&Entry, CkptError> {
        let e = self.entries.get(name).ok_or_else(|| CkptError::MissingTensor {
            name: name.to_string(),
        })?;
        if e.kind != kind {
            return Err(CkptError::WrongKind { name: name.to_string() });
        }
        Ok(e)
    }

    /// Copy the quantized (kind 2) tensor `name` out of the payload —
    /// per-block int8 weights written by quantize-at-freeze. Typed error
    /// if absent or a different kind; the element count is the caller's
    /// to check (scales travel as a separate f32 tensor of known shape).
    pub fn load_i8(&self, name: &str) -> Result<Vec<i8>, CkptError> {
        let e = self.entry(name, 2)?;
        let bytes = &self.payload[e.offset..e.offset + e.len];
        Ok(bytes.iter().map(|&b| b as i8).collect())
    }
}

impl StateSource for Ckpt {
    fn load_f32(&mut self, name: &str, dst: &mut [f32]) -> Result<(), CkptError> {
        let e = self.entry(name, 0)?;
        if e.len != dst.len() {
            return Err(CkptError::WrongLen {
                name: name.to_string(),
                want: dst.len(),
                got: e.len,
            });
        }
        let bytes = &self.payload[e.offset..e.offset + 4 * e.len];
        for (d, ch) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes(ch.try_into().unwrap());
        }
        Ok(())
    }

    fn expect_u32(&mut self, name: &str, want: &[u32]) -> Result<(), CkptError> {
        let e = self.entry(name, 1)?;
        if e.len != want.len() {
            return Err(CkptError::WrongLen {
                name: name.to_string(),
                want: want.len(),
                got: e.len,
            });
        }
        let bytes = &self.payload[e.offset..e.offset + 4 * e.len];
        for (i, (w, ch)) in want.iter().zip(bytes.chunks_exact(4)).enumerate() {
            if *w != u32::from_le_bytes(ch.try_into().unwrap()) {
                return Err(CkptError::SchemaMismatch {
                    detail: format!(
                        "structure tensor {name:?} differs at element {i} — \
                         checkpoint was written for a different sparsity plan"
                    ),
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::TensorData;

    fn sample() -> Vec<u8> {
        format::encode(
            42,
            "model=test",
            &[
                ("w".to_string(), TensorData::F32(vec![1.0, -0.5, 3.25])),
                ("idx".to_string(), TensorData::U32(vec![7, 8])),
            ],
        )
    }

    #[test]
    fn parse_round_trips_header_and_tensors() {
        let mut ck = Ckpt::parse(sample()).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.meta, "model=test");
        let mut w = [0.0f32; 3];
        ck.load_f32("w", &mut w).unwrap();
        assert_eq!(w, [1.0, -0.5, 3.25]);
        ck.expect_u32("idx", &[7, 8]).unwrap();
        assert!(matches!(ck.expect_u32("idx", &[7, 9]),
                         Err(CkptError::SchemaMismatch { .. })));
        assert!(matches!(ck.load_f32("nope", &mut w),
                         Err(CkptError::MissingTensor { .. })));
        assert!(matches!(ck.load_f32("idx", &mut [0.0; 2]),
                         Err(CkptError::WrongKind { .. })));
        assert!(matches!(ck.load_f32("w", &mut [0.0; 2]),
                         Err(CkptError::WrongLen { .. })));
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let good = sample();
        // flip one bit at a spread of positions across header and payload;
        // every one must surface as a typed error, never a silent load
        for pos in (0..good.len()).step_by(3) {
            let mut bad = good.clone();
            bad[pos] ^= 0x10;
            match Ckpt::parse(bad) {
                Ok(mut ck) => {
                    // every byte sits under the header CRC or a payload
                    // CRC, so parse should always reject; if a future
                    // format change ever leaves a gap, the flip must
                    // still fail loudly at tensor access time
                    let mut w = [0.0f32; 3];
                    assert!(
                        ck.load_f32("w", &mut w).is_err(),
                        "bit flip at byte {pos} loaded silently"
                    );
                }
                Err(e) => {
                    // typed rejection is the expected path
                    let _ = format!("{e}");
                }
            }
        }
    }

    #[test]
    fn truncations_are_rejected_at_every_length() {
        let good = sample();
        for keep in 0..good.len() {
            let mut bad = good.clone();
            bad.truncate(keep);
            assert!(Ckpt::parse(bad).is_err(), "truncation to {keep} bytes passed");
        }
    }

    #[test]
    fn future_version_and_bad_magic_are_typed() {
        let mut v2 = sample();
        v2[4] = 99; // version byte
        // header CRC now mismatches too; accept either typed error but
        // prefer checking FutureVersion fires when the CRC is fixed up
        let hcrc_at = {
            let payload_len = 3 * 4 + 2 * 4;
            v2.len() - payload_len - 4
        };
        let crc = format::crc32(&v2[..hcrc_at]).to_le_bytes();
        v2[hcrc_at..hcrc_at + 4].copy_from_slice(&crc);
        assert!(matches!(Ckpt::parse(v2), Err(CkptError::FutureVersion { found: 99 })));

        let mut junk = sample();
        junk[0] = b'X';
        assert!(matches!(Ckpt::parse(junk), Err(CkptError::BadMagic)));
    }

    #[test]
    fn quantized_kind_round_trips_under_version_2() {
        let bytes = format::encode(
            1,
            "q",
            &[
                ("q".to_string(), TensorData::I8(vec![-128, -1, 0, 1, 127])),
                ("scale".to_string(), TensorData::F32(vec![0.5])),
            ],
        );
        // the presence of a kind-2 tensor bumps the file to v2
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
                   format::VERSION_QUANT);
        let mut ck = Ckpt::parse(bytes).unwrap();
        assert_eq!(ck.load_i8("q").unwrap(), vec![-128, -1, 0, 1, 127]);
        assert!(matches!(ck.load_i8("scale"), Err(CkptError::WrongKind { .. })));
        assert!(matches!(ck.load_i8("nope"), Err(CkptError::MissingTensor { .. })));
        // mixed-kind file still serves its f32 entries normally
        let mut s = [0.0f32; 1];
        ck.load_f32("scale", &mut s).unwrap();
        assert_eq!(s, [0.5]);
    }

    #[test]
    fn v1_files_reject_the_quantized_kind() {
        // a v1 header claiming a kind-2 entry is a forward-compat
        // violation: v1 writers never produced it, so the loader must
        // answer WrongKind — never misread 1-byte elements as f32
        let mut bytes = format::encode(
            1, "", &[("q".to_string(), TensorData::I8(vec![1, 2, 3, 4]))]);
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        let hcrc_at = bytes.len() - 4 /* payload: 4 i8 */ - 4 /* crc */;
        let crc = format::crc32(&bytes[..hcrc_at]).to_le_bytes();
        bytes[hcrc_at..hcrc_at + 4].copy_from_slice(&crc);
        assert!(matches!(Ckpt::parse(bytes), Err(CkptError::WrongKind { .. })));
    }

    #[test]
    fn unknown_kind_byte_is_rejected_typed() {
        // kind 7 exists in no revision — pinned BEFORE any kind 3 ships,
        // so a new kind must be threaded through the version gate
        // deliberately rather than slipping past an open-ended check
        let mut bytes = sample();
        // "w"'s kind byte: magic(4)+ver(4)+fp(8)+step(8)+meta_len(4)
        // +meta("model=test")+n_entries(4)+name_len(2)+name("w")
        let kind_at = 4 + 4 + 8 + 8 + 4 + "model=test".len() + 4 + 2 + 1;
        assert_eq!(bytes[kind_at], 0, "kind byte location drifted");
        bytes[kind_at] = 7;
        let payload_len = 3 * 4 + 2 * 4;
        let hcrc_at = bytes.len() - payload_len - 4;
        let crc = format::crc32(&bytes[..hcrc_at]).to_le_bytes();
        bytes[hcrc_at..hcrc_at + 4].copy_from_slice(&crc);
        assert!(matches!(Ckpt::parse(bytes),
                         Err(CkptError::WrongKind { name }) if name == "w"));
    }

    #[test]
    fn schema_fingerprint_gates_loading() {
        let ck = Ckpt::parse(sample()).unwrap();
        ck.matches_schema(ck.fingerprint).unwrap();
        assert!(matches!(ck.matches_schema(ck.fingerprint ^ 1),
                         Err(CkptError::SchemaMismatch { .. })));
    }
}
