//! Atomic snapshot writer: crash-safe single-file writes plus the
//! background [`Snapshotter`] thread that takes checkpoint I/O off the
//! training step.
//!
//! Atomicity protocol (the Strata-style write-then-rename):
//!
//! 1. serialize into `<path>.tmp`
//! 2. fsync the tmp file (bytes durable)
//! 3. `rename(tmp, path)` (POSIX rename is atomic: readers see the old
//!    file or the new one, never a half-written hybrid)
//! 4. fsync the parent directory (the rename itself durable)
//!
//! A crash at any step leaves either the previous checkpoint intact or a
//! stray `.tmp` the loader never looks at.
//!
//! The [`Snapshotter`] is fed through the same `Doorbell` primitive the
//! worker pool and prefetcher park on: the training thread fills a
//! recycled [`Snapshot`] buffer (a memcpy of the params — no file I/O)
//! and rings the bell; the writer thread encodes, writes atomically and
//! rotates retained files. The pending slot is latest-wins, so a slow
//! disk can never make snapshots back up behind the training loop.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;

use crate::sparse::exec::pool::Doorbell;

use super::faults;
use super::format::{self, CkptError};
use super::TensorData;

/// An owned copy of one model's full training state, detached from the
/// live module tree — what crosses from the training thread to the
/// writer thread. Buffers are recycled between snapshots (double
/// buffering), so the steady-state cost of a snapshot on the training
/// thread is one memcpy of the parameters.
#[derive(Default)]
pub struct Snapshot {
    pub step: u64,
    pub meta: String,
    pub tensors: Vec<(String, TensorData)>,
}

impl Snapshot {
    pub fn new() -> Self {
        Self::default()
    }

    /// Serialize to `PXCK` bytes (see [`format::encode`]).
    pub fn encode(&self) -> Vec<u8> {
        format::encode(self.step, &self.meta, &self.tensors)
    }

    /// Schema fingerprint of this snapshot's tensor layout.
    pub fn fingerprint(&self) -> u64 {
        format::fingerprint_of(&self.tensors)
    }
}

/// Write `bytes` to `path` through the full atomicity protocol
/// (tmp → fsync → rename → fsync dir). On error the destination is
/// untouched: either the old file survives or nothing was there.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), CkptError> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    faults::write_file(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    sync_parent_dir(path)?;
    Ok(())
}

/// fsync the directory holding `path` so the rename itself is durable.
/// Directory handles can't be fsynced off unix; the rename is still
/// atomic there, just not power-cut durable.
fn sync_parent_dir(path: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::File::open(dir)?.sync_all()?;
            }
        }
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Checkpoint filename for a training step — zero-padded so lexical
/// order is step order (rotation and "latest" both ride on it).
pub fn step_filename(step: u64) -> String {
    format!("ckpt-{step:010}.pxck")
}

/// Newest `ckpt-*.pxck` in `dir` (what `serve --weights <dir>` resolves).
pub fn latest_in(dir: &Path) -> Option<PathBuf> {
    let mut names: Vec<String> = list_checkpoints(dir).ok()?;
    names.sort();
    names.pop().map(|n| dir.join(n))
}

fn list_checkpoints(dir: &Path) -> std::io::Result<Vec<String>> {
    let mut out = Vec::new();
    for e in std::fs::read_dir(dir)? {
        let name = e?.file_name().to_string_lossy().into_owned();
        if name.starts_with("ckpt-") && name.ends_with(".pxck") {
            out.push(name);
        }
    }
    Ok(out)
}

/// Delete all but the newest `retain` checkpoints in `dir`. Stray `.tmp`
/// files (from a killed write) are swept too — they are garbage by
/// definition, the loader never reads them.
fn rotate(dir: &Path, retain: usize, errors: &mut Vec<String>) {
    let Ok(mut names) = list_checkpoints(dir) else { return };
    names.sort();
    let cut = names.len().saturating_sub(retain.max(1));
    for n in &names[..cut] {
        if let Err(e) = std::fs::remove_file(dir.join(n)) {
            errors.push(format!("rotate {n}: {e}"));
        }
    }
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if name.ends_with(".pxck.tmp") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
}

/// What one [`Snapshotter`] run did — surfaced at `finish()` so snapshot
/// failures are loud even though they never block training.
#[derive(Debug, Default)]
pub struct SnapReport {
    /// checkpoints durably written
    pub written: u64,
    /// snapshots superseded in the pending slot before the writer got to
    /// them (latest-wins backpressure)
    pub dropped: u64,
    pub last_path: Option<PathBuf>,
    pub errors: Vec<String>,
}

struct SnapShared {
    pending: Option<Snapshot>,
    free: Vec<Snapshot>,
    shutdown: bool,
    report: SnapReport,
}

/// Background snapshot thread over a checkpoint directory. `offer()` is
/// the training-loop entry point: it never does file I/O and never
/// blocks on the disk.
pub struct Snapshotter {
    bell: Arc<Doorbell<SnapShared>>,
    worker: Option<thread::JoinHandle<()>>,
}

impl Snapshotter {
    /// Create `dir` and start the writer thread; keep the newest
    /// `retain` checkpoints (minimum 1).
    pub fn start(dir: &Path, retain: usize) -> Result<Snapshotter, CkptError> {
        std::fs::create_dir_all(dir)?;
        let dir = dir.to_path_buf();
        let bell = Arc::new(Doorbell::new(SnapShared {
            pending: None,
            free: Vec::new(),
            shutdown: false,
            report: SnapReport::default(),
        }));
        let bell2 = Arc::clone(&bell);
        let worker = thread::Builder::new()
            .name("pixelfly-ckpt".into())
            .spawn(move || {
                loop {
                    // drain pending BEFORE honouring shutdown, so the
                    // final offered snapshot always lands
                    let job = bell2.wait_until(|s| match s.pending.take() {
                        Some(p) => Some(Some(p)),
                        None if s.shutdown => Some(None),
                        None => None,
                    });
                    let Some(snap) = job else { break };
                    let path = dir.join(step_filename(snap.step));
                    let bytes = snap.encode();
                    let outcome = write_atomic(&path, &bytes);
                    bell2.update(|s| {
                        match outcome {
                            Ok(()) => {
                                s.report.written += 1;
                                s.report.last_path = Some(path.clone());
                                rotate(&dir, retain, &mut s.report.errors);
                            }
                            Err(e) => s.report.errors.push(format!(
                                "snapshot step {}: {e}", snap.step)),
                        }
                        s.free.push(snap);
                    });
                }
            })?;
        Ok(Snapshotter { bell, worker: Some(worker) })
    }

    /// Offer a snapshot without blocking on the disk: `fill` runs on the
    /// calling thread into a recycled buffer (one param memcpy), then the
    /// buffer replaces any still-unwritten pending snapshot
    /// (latest-wins — the superseded one is recycled and counted).
    pub fn offer(&self, fill: impl FnOnce(&mut Snapshot)) {
        let mut snap = self.bell.update(|s| s.free.pop()).unwrap_or_default();
        fill(&mut snap);
        self.bell.update(|s| {
            if let Some(prev) = s.pending.replace(snap) {
                s.report.dropped += 1;
                s.free.push(prev);
            }
        });
    }

    /// Drain the pending snapshot, stop the writer thread, and surface
    /// what happened (writes, latest-wins drops, errors).
    pub fn finish(mut self) -> SnapReport {
        self.bell.update(|s| s.shutdown = true);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.bell.update(|s| std::mem::take(&mut s.report))
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.bell.update(|s| s.shutdown = true);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pxck-writer-{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn snap(step: u64) -> Snapshot {
        Snapshot {
            step,
            meta: "test".into(),
            tensors: vec![("w".into(), TensorData::F32(vec![step as f32; 8]))],
        }
    }

    #[test]
    fn atomic_write_leaves_no_tmp() {
        let dir = tdir("atomic");
        let p = dir.join(step_filename(3));
        write_atomic(&p, &snap(3).encode()).unwrap();
        assert!(p.exists());
        let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(entries.len(), 1, "no .tmp residue after a clean write");
    }

    #[test]
    fn snapshotter_writes_rotates_and_reports() {
        let dir = tdir("rotate");
        let s = Snapshotter::start(&dir, 2).unwrap();
        for step in 1..=5u64 {
            s.offer(|b| *b = snap(step));
            // serialize offers so none are dropped (latest-wins is
            // exercised separately); the writer is faster than this loop
            while !dir.join(step_filename(step)).exists() {
                thread::yield_now();
            }
        }
        let rep = s.finish();
        assert_eq!(rep.written, 5);
        assert!(rep.errors.is_empty(), "{:?}", rep.errors);
        assert_eq!(rep.last_path, Some(dir.join(step_filename(5))));
        let mut names = list_checkpoints(&dir).unwrap();
        names.sort();
        assert_eq!(names, vec![step_filename(4), step_filename(5)],
                   "retain-last-2 rotation");
        assert_eq!(latest_in(&dir), Some(dir.join(step_filename(5))));
    }

    #[test]
    fn finish_drains_the_pending_snapshot() {
        let dir = tdir("drain");
        let s = Snapshotter::start(&dir, 3).unwrap();
        s.offer(|b| *b = snap(9));
        let rep = s.finish();
        assert_eq!(rep.written, 1);
        assert!(dir.join(step_filename(9)).exists());
    }
}
