//! Empirical NTK comparison + the Appendix-K / Algorithm-2 pattern search.
//!
//! The NTK *grams* are computed by AOT artifacts (`ntk_*.ntk_gram`) on the
//! PJRT engine; this module owns the distance metric (relative Frobenius
//! difference, as in Fig 4), the candidate enumeration of Algorithm 2, and
//! a closed-form NTK for two-layer ReLU nets (Definition G.2) used as a
//! fast self-contained check (and in unit tests, where no artifacts are
//! required).

use crate::patterns::{baselines, flat_butterfly_mask, BlockMask, PatternKind};
use crate::util::Rng;

/// Relative Frobenius distance ||A - B||_F / ||A||_F (Fig 4's metric).
pub fn relative_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*x as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

/// Analytic infinite-width NTK entry for a 2-layer ReLU net with masked
/// first-layer weights (Definition G.2 adapted to a row mask): the kernel
/// of example pair (x, y) restricted to the coordinates each hidden unit
/// sees.  For unit r with support S_r:
/// `K(x,y) = E_r [ <x_S, y_S> * P(w·x_S >= 0, w·y_S >= 0) ]`
/// where the arc-cosine formula gives the probability.
pub fn two_layer_relu_ntk(x: &[f32], y: &[f32], supports: &[Vec<usize>]) -> f64 {
    let mut acc = 0.0f64;
    for s in supports {
        let (mut xx, mut yy, mut xy) = (0.0f64, 0.0f64, 0.0f64);
        for &i in s {
            xx += (x[i] as f64).powi(2);
            yy += (y[i] as f64).powi(2);
            xy += x[i] as f64 * y[i] as f64;
        }
        if xx <= 0.0 || yy <= 0.0 {
            continue;
        }
        let cos = (xy / (xx.sqrt() * yy.sqrt())).clamp(-1.0, 1.0);
        let theta = cos.acos();
        // arc-cosine kernel of order 1 (ReLU): contribution
        acc += xy * (std::f64::consts::PI - theta) / std::f64::consts::PI;
    }
    acc / supports.len() as f64
}

/// Build hidden-unit supports from a weight block mask: unit group j sees
/// input blocks with `mask[i][j]` set.
pub fn supports_from_mask(mask: &BlockMask, block: usize) -> Vec<Vec<usize>> {
    let t = mask.transpose();
    (0..t.rows)
        .map(|j| {
            let mut s = Vec::new();
            for i in t.row_cols(j) {
                for e in 0..block {
                    s.push(i * block + e);
                }
            }
            s
        })
        .collect()
}

/// Gram matrix of the analytic sparse NTK over a dataset.
pub fn ntk_gram(data: &[Vec<f32>], supports: &[Vec<usize>]) -> Vec<f32> {
    let n = data.len();
    let mut g = vec![0.0f32; n * n];
    for i in 0..n {
        for j in i..n {
            let v = two_layer_relu_ntk(&data[i], &data[j], supports) as f32;
            g[i * n + j] = v;
            g[j * n + i] = v;
        }
    }
    g
}

/// One Algorithm-2 candidate: a named mask generator at a given budget.
pub struct Candidate {
    pub kind: PatternKind,
    pub mask: BlockMask,
}

/// Enumerate the candidate set of Appendix K (Fig 12 components and the
/// pixelfly combination) at roughly equal block budget.
pub fn candidate_set(nb: usize, budget_blocks: usize, rng: &mut Rng) -> Vec<Candidate> {
    let density = budget_blocks as f64 / (nb * nb) as f64;
    let mut out = Vec::new();
    out.push(Candidate { kind: PatternKind::Dense, mask: BlockMask::ones(nb, nb) });
    // every sparse candidate is built AT (as close as its family allows to)
    // the same block budget, so distances are comparable (Algorithm 2
    // compares under the TotalCompute(s) <= B constraint)
    let ms = crate::patterns::butterfly::max_stride_for_budget(
        nb, budget_blocks.saturating_sub(2 * nb).max(nb));
    out.push(Candidate {
        kind: PatternKind::Pixelfly,
        mask: baselines::pixelfly_attention_mask(nb, ms, 1),
    });
    out.push(Candidate {
        kind: PatternKind::FlatButterfly,
        mask: flat_butterfly_mask(nb, crate::patterns::butterfly::max_stride_for_budget(nb, budget_blocks)),
    });
    out.push(Candidate {
        kind: PatternKind::Local,
        mask: baselines::local_mask(nb, (budget_blocks / (2 * nb)).max(1)),
    });
    out.push(Candidate {
        kind: PatternKind::Global,
        mask: baselines::global_mask(nb, (budget_blocks.div_ceil(2 * nb)).max(1)),
    });
    out.push(Candidate {
        kind: PatternKind::Random,
        mask: baselines::random_mask(nb, nb, density, rng),
    });
    // bigbird: window 1 + global 1 costs ~5*nb blocks; spend the rest on
    // random links
    let base_cost = 5 * nb;
    let n_random = budget_blocks.saturating_sub(base_cost) / nb;
    out.push(Candidate {
        kind: PatternKind::BigBird,
        mask: baselines::bigbird_mask(nb, 1, 1, n_random, rng),
    });
    out
}

/// Algorithm 2 over the analytic NTK: rank candidates by distance to the
/// dense NTK at (approximately) the same budget; returns
/// (kind, distance, density) sorted best-first.
pub fn search(data: &[Vec<f32>], nb: usize, block: usize, budget_blocks: usize,
              seed: u64) -> Vec<(PatternKind, f64, f64)> {
    let mut rng = Rng::new(seed);
    let dense_supports = supports_from_mask(&BlockMask::ones(nb, nb), block);
    let dense_gram = ntk_gram(data, &dense_supports);
    let mut out = Vec::new();
    for cand in candidate_set(nb, budget_blocks, &mut rng) {
        if cand.kind == PatternKind::Dense {
            continue;
        }
        let supports = supports_from_mask(&cand.mask, block);
        let gram = ntk_gram(data, &supports);
        out.push((cand.kind, relative_distance(&dense_gram, &gram), cand.mask.density()));
    }
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_data(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        // clustered inputs (Process 1 flavour): pairs share a center
        (0..n)
            .map(|i| {
                let mut c = Rng::new(100 + (i / 2) as u64);
                (0..dim)
                    .map(|_| c.normal_f32() + 0.2 * rng.normal_f32())
                    .collect()
            })
            .collect()
    }

    #[test]
    fn relative_distance_zero_on_equal() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert!(relative_distance(&a, &a) < 1e-12);
    }

    #[test]
    fn ntk_gram_is_psd_diagonal_dominantish() {
        let data = toy_data(8, 32, 1);
        let supports = supports_from_mask(&BlockMask::ones(8, 8), 4);
        let g = ntk_gram(&data, &supports);
        for i in 0..8 {
            assert!(g[i * 8 + i] > 0.0);
        }
        // symmetry
        for i in 0..8 {
            for j in 0..8 {
                assert!((g[i * 8 + j] - g[j * 8 + i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn denser_masks_are_closer_to_dense_ntk() {
        let data = toy_data(12, 64, 2);
        let block = 4;
        let nb = 16;
        let dense = ntk_gram(&data, &supports_from_mask(&BlockMask::ones(nb, nb), block));
        let near = flat_butterfly_mask(nb, 16);
        let far = flat_butterfly_mask(nb, 1);
        let d_near = relative_distance(
            &dense, &ntk_gram(&data, &supports_from_mask(&near, block)));
        let d_far = relative_distance(
            &dense, &ntk_gram(&data, &supports_from_mask(&far, block)));
        assert!(d_near < d_far, "near {d_near} far {d_far}");
    }

    #[test]
    fn search_distance_tracks_budget_monotonically() {
        // The analytic proxy's robust invariant: at matched structure,
        // more budget => closer to the dense NTK.  (The paper's empirical
        // pattern *ranking* — Fig 4 — is reproduced with the artifact-based
        // grams via `pixelfly ntk-compare`, where the patterns change the
        // actual model; the closed-form proxy here is density-monotone.)
        let data = toy_data(16, 64, 3);
        let small = search(&data, 16, 4, 48, 7);
        let large = search(&data, 16, 4, 160, 7);
        let dist = |r: &Vec<(PatternKind, f64, f64)>, k: PatternKind| {
            r.iter().find(|(kk, _, _)| *kk == k).unwrap().1
        };
        for k in [PatternKind::Pixelfly, PatternKind::FlatButterfly, PatternKind::Random] {
            assert!(dist(&large, k) < dist(&small, k),
                    "{k:?}: {} !< {}", dist(&large, k), dist(&small, k));
        }
        // every candidate's distance is in (0, 1]-ish range and ranking is
        // produced sorted
        for w in small.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn candidate_budgets_comparable() {
        let mut rng = Rng::new(4);
        let budget = 96;
        for c in candidate_set(16, budget, &mut rng) {
            if matches!(c.kind, PatternKind::Dense) {
                continue;
            }
            assert!(c.mask.nnz() <= 3 * budget,
                    "{:?} wildly over budget: {}", c.kind, c.mask.nnz());
        }
    }
}
