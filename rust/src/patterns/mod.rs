//! Sparsity patterns: block masks, butterfly structure, block covers.
//!
//! Everything the paper defines over sparsity structure lives here:
//! - [`mask`]      `BlockMask` + `(b1,b2)`-block covers (Definition A.1)
//! - [`butterfly`] block butterfly factors/products (Defs 3.1–3.3) and the
//!                 flat butterfly pattern (Def 3.4)
//! - [`baselines`] the comparison patterns: random, local, global,
//!                 BigBird, Sparse-Transformer, Longformer, Reformer-like

pub mod baselines;
pub mod butterfly;
pub mod mask;

pub use butterfly::{butterfly_factor_mask, flat_butterfly_mask};
pub use mask::BlockMask;

/// Named pattern kinds used by the planner / NTK search / benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PatternKind {
    Dense,
    Pixelfly,
    FlatButterfly,
    ButterflyProduct,
    LowRank,
    Random,
    Local,
    Global,
    BigBird,
    SparseTransformer,
    Longformer,
}

impl PatternKind {
    pub fn name(&self) -> &'static str {
        match self {
            PatternKind::Dense => "dense",
            PatternKind::Pixelfly => "pixelfly",
            PatternKind::FlatButterfly => "flat_butterfly",
            PatternKind::ButterflyProduct => "butterfly_product",
            PatternKind::LowRank => "lowrank",
            PatternKind::Random => "random",
            PatternKind::Local => "local",
            PatternKind::Global => "global",
            PatternKind::BigBird => "bigbird",
            PatternKind::SparseTransformer => "sparse_transformer",
            PatternKind::Longformer => "longformer",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "dense" => PatternKind::Dense,
            "pixelfly" => PatternKind::Pixelfly,
            "flat_butterfly" => PatternKind::FlatButterfly,
            "butterfly_product" | "butterfly" => PatternKind::ButterflyProduct,
            "lowrank" => PatternKind::LowRank,
            "random" => PatternKind::Random,
            "local" => PatternKind::Local,
            "global" => PatternKind::Global,
            "bigbird" => PatternKind::BigBird,
            "sparse_transformer" => PatternKind::SparseTransformer,
            "longformer" => PatternKind::Longformer,
            _ => return None,
        })
    }
}
