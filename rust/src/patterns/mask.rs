//! Block masks and `(b1, b2)`-block covers (paper Definition A.1).
//!
//! A `BlockMask` is a boolean matrix at *block* granularity.  The same type
//! also represents element-level masks (block size 1), which is how the
//! cost-model experiments express non-aligned patterns and compute their
//! covers — the "expected vs actual density" mechanics behind Table 7.

/// Dense-stored boolean mask over an `rows x cols` grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockMask {
    pub rows: usize,
    pub cols: usize,
    bits: Vec<bool>,
}

impl BlockMask {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        BlockMask { rows, cols, bits: vec![false; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        BlockMask { rows, cols, bits: vec![true; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, true);
        }
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        self.bits[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.bits[r * self.cols + c] = v;
    }

    /// Number of true entries.
    pub fn nnz(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Fraction of true entries.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Element-wise OR.
    pub fn union(&self, other: &BlockMask) -> BlockMask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let bits = self
            .bits
            .iter()
            .zip(&other.bits)
            .map(|(a, b)| *a || *b)
            .collect();
        BlockMask { rows: self.rows, cols: self.cols, bits }
    }

    /// Element-wise AND.
    pub fn intersect(&self, other: &BlockMask) -> BlockMask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let bits = self
            .bits
            .iter()
            .zip(&other.bits)
            .map(|(a, b)| *a && *b)
            .collect();
        BlockMask { rows: self.rows, cols: self.cols, bits }
    }

    /// True if `self <= other` entrywise (support containment).
    pub fn contained_in(&self, other: &BlockMask) -> bool {
        self.bits.iter().zip(&other.bits).all(|(a, b)| !*a || *b)
    }

    pub fn transpose(&self) -> BlockMask {
        let mut t = BlockMask::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.get(r, c) {
                    t.set(c, r, true);
                }
            }
        }
        t
    }

    /// Keep only entries on/below the diagonal (causal attention).
    pub fn lower_triangular(&self) -> BlockMask {
        let mut m = self.clone();
        for r in 0..self.rows {
            for c in 0..self.cols {
                if c > r {
                    m.set(r, c, false);
                }
            }
        }
        m
    }

    /// Expand each entry into a `b x b` all-true/all-false element block.
    pub fn expand(&self, b: usize) -> BlockMask {
        let mut m = BlockMask::zeros(self.rows * b, self.cols * b);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.get(r, c) {
                    for dr in 0..b {
                        for dc in 0..b {
                            m.set(r * b + dr, c * b + dc, true);
                        }
                    }
                }
            }
        }
        m
    }

    /// The `(b1, b2)`-block cover (Definition A.1): the smallest
    /// block-aligned mask containing `self`.  Result is at *block*
    /// granularity: shape (ceil(rows/b1), ceil(cols/b2)).
    pub fn block_cover(&self, b1: usize, b2: usize) -> BlockMask {
        let br = self.rows.div_ceil(b1);
        let bc = self.cols.div_ceil(b2);
        let mut cover = BlockMask::zeros(br, bc);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.get(r, c) {
                    cover.set(r / b1, c / b2, true);
                }
            }
        }
        cover
    }

    /// Is this element mask `(b1, b2)`-block-aligned (Definition A.1)?
    pub fn is_block_aligned(&self, b1: usize, b2: usize) -> bool {
        if self.rows % b1 != 0 || self.cols % b2 != 0 {
            return false;
        }
        let cover = self.block_cover(b1, b2);
        cover.expand_rect(b1, b2) == *self
    }

    /// Expand with rectangular blocks (b1 rows x b2 cols).
    pub fn expand_rect(&self, b1: usize, b2: usize) -> BlockMask {
        let mut m = BlockMask::zeros(self.rows * b1, self.cols * b2);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.get(r, c) {
                    for dr in 0..b1 {
                        for dc in 0..b2 {
                            m.set(r * b1 + dr, c * b2 + dc, true);
                        }
                    }
                }
            }
        }
        m
    }

    /// "Actual density" under hardware block size b (Table 7): the fraction
    /// of *elements* touched once every touched b x b block is fully
    /// accessed.
    pub fn actual_density(&self, b: usize) -> f64 {
        let cover = self.block_cover(b, b);
        let touched = cover.nnz() * b * b;
        touched as f64 / ((self.rows.div_ceil(b) * b) * (self.cols.div_ceil(b) * b)) as f64
    }

    /// Column indices of true entries in row `r`.
    pub fn row_cols(&self, r: usize) -> Vec<usize> {
        (0..self.cols).filter(|&c| self.get(r, c)).collect()
    }

    /// Every row has at least one true entry.
    pub fn rows_nonempty(&self) -> bool {
        (0..self.rows).all(|r| (0..self.cols).any(|c| self.get(r, c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cover_of_single_element_is_one_block() {
        let mut m = BlockMask::zeros(8, 8);
        m.set(5, 2, true);
        let cover = m.block_cover(4, 4);
        assert_eq!(cover.nnz(), 1);
        assert!(cover.get(1, 0));
    }

    #[test]
    fn aligned_mask_roundtrips_through_cover() {
        let blocks = BlockMask::identity(4);
        let elems = blocks.expand(4);
        assert!(elems.is_block_aligned(4, 4));
        assert_eq!(elems.block_cover(4, 4), blocks);
    }

    #[test]
    fn random_scatter_cover_inflates_density() {
        // Table 7 mechanism: scattered nonzeros touch nearly all blocks.
        let mut m = BlockMask::zeros(64, 64);
        // one nonzero per 8x8 block
        for i in 0..8 {
            for j in 0..8 {
                m.set(i * 8 + 3, j * 8 + 5, true);
            }
        }
        assert!((m.density() - 64.0 / 4096.0).abs() < 1e-12);
        assert!((m.actual_density(8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn containment_and_union() {
        let a = BlockMask::identity(4);
        let b = BlockMask::ones(4, 4);
        assert!(a.contained_in(&b));
        assert!(!b.contained_in(&a));
        assert_eq!(a.union(&b), b);
        assert_eq!(a.intersect(&b), a);
    }

    #[test]
    fn lower_triangular_removes_upper() {
        let m = BlockMask::ones(4, 4).lower_triangular();
        assert_eq!(m.nnz(), 10);
        assert!(!m.get(0, 3));
        assert!(m.get(3, 0));
    }

    #[test]
    fn transpose_involution() {
        let mut m = BlockMask::zeros(3, 5);
        m.set(0, 4, true);
        m.set(2, 1, true);
        assert_eq!(m.transpose().transpose(), m);
        assert!(m.transpose().get(4, 0));
    }
}
