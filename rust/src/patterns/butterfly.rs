//! Block butterfly structure (paper Definitions 3.1–3.4).
//!
//! All masks here are at block granularity over `nb` blocks per side.
//! The XOR characterisation: a butterfly factor matrix of stride `s`
//! (in blocks) pairs block index `i` with `i ^ (s/2)`; the flat butterfly
//! of max stride `k` is the union of the diagonal with the XOR partners
//! for every power of two below `k` — exactly the first-order expansion
//! I + λ(B_2 + B_4 + ... + B_k) of Eq. (1).

use super::mask::BlockMask;

/// Mask of one block butterfly factor matrix `B_s^{(nb, b)}` (Def 3.2):
/// entries (i, i) and (i, i ^ s/2). `stride` is in blocks, a power of two,
/// 2 <= stride <= nb.
pub fn butterfly_factor_mask(nb: usize, stride: usize) -> BlockMask {
    assert!(stride >= 2 && stride.is_power_of_two() && stride <= nb);
    assert!(nb.is_power_of_two());
    let mut m = BlockMask::zeros(nb, nb);
    for i in 0..nb {
        m.set(i, i, true);
        m.set(i, i ^ (stride / 2), true);
    }
    m
}

/// Flat butterfly mask of max stride `k` (Def 3.4): diagonal ∪ XOR
/// partners 2^0 .. 2^(log2 k - 1).  `k = 1` gives the diagonal only.
pub fn flat_butterfly_mask(nb: usize, max_stride: usize) -> BlockMask {
    assert!(max_stride >= 1 && max_stride.is_power_of_two() && max_stride <= nb);
    assert!(nb.is_power_of_two());
    let mut m = BlockMask::identity(nb);
    let mut s = 1;
    while s < max_stride {
        for i in 0..nb {
            m.set(i, i ^ s, true);
        }
        s *= 2;
    }
    m
}

/// Rectangular "stretch" of the flat butterfly (paper Appendix I.4): tile
/// the square pattern over min-side blocks along the longer dimension.
pub fn stretched_flat_butterfly(nbr: usize, nbc: usize, max_stride: usize) -> BlockMask {
    let nsq = nbr.min(nbc);
    let p2 = if nsq.is_power_of_two() { nsq } else { nsq.next_power_of_two() / 2 }.max(1);
    let ms = max_stride.min(p2);
    let base = flat_butterfly_mask(p2, ms);
    let mut m = BlockMask::zeros(nbr, nbc);
    for i in 0..nbr {
        for j in 0..nbc {
            if base.get(i % p2, j % p2) {
                m.set(i, j, true);
            }
        }
    }
    m
}

/// Number of nonzero blocks of the flat butterfly with max stride `k`.
pub fn flat_butterfly_nnz_blocks(nb: usize, max_stride: usize) -> usize {
    if max_stride <= 1 {
        nb
    } else {
        nb * ((max_stride.trailing_zeros() as usize) + 1)
    }
}

/// Largest power-of-two max stride whose flat pattern stays within
/// `budget` nonzero blocks (paper §3.3 step 2: fill the budget).
pub fn max_stride_for_budget(nb: usize, budget_blocks: usize) -> usize {
    let mut k = 1;
    while k < nb {
        let next = k * 2;
        if flat_butterfly_nnz_blocks(nb, next) > budget_blocks {
            break;
        }
        k = next;
    }
    k
}

/// Support mask of the *product* of butterfly factor masks with strides
/// 2..=k (the reachability of the sequential form; used to verify that the
/// product connects all pairs at k = nb, i.e. the FFT mixing property).
pub fn butterfly_product_support(nb: usize, max_stride: usize) -> BlockMask {
    let mut acc = BlockMask::identity(nb);
    let mut s = 2;
    while s <= max_stride {
        let f = butterfly_factor_mask(nb, s);
        acc = bool_matmul(&acc, &f);
        s *= 2;
    }
    acc
}

/// Boolean matrix product (support of the product of two masks).
pub fn bool_matmul(a: &BlockMask, b: &BlockMask) -> BlockMask {
    assert_eq!(a.cols, b.rows);
    let mut out = BlockMask::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            if a.get(i, k) {
                for j in 0..b.cols {
                    if b.get(k, j) {
                        out.set(i, j, true);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_mask_has_two_per_row() {
        for nb in [4usize, 8, 16] {
            let mut s = 2;
            while s <= nb {
                let m = butterfly_factor_mask(nb, s);
                for i in 0..nb {
                    assert_eq!(m.row_cols(i).len(), 2, "nb={nb} s={s} row {i}");
                }
                s *= 2;
            }
        }
    }

    #[test]
    fn flat_mask_nnz_formula() {
        for nb in [4usize, 8, 16, 32] {
            let mut k = 1;
            while k <= nb {
                let m = flat_butterfly_mask(nb, k);
                assert_eq!(m.nnz(), flat_butterfly_nnz_blocks(nb, k), "nb={nb} k={k}");
                k *= 2;
            }
        }
    }

    #[test]
    fn flat_mask_is_symmetric() {
        let m = flat_butterfly_mask(16, 8);
        assert_eq!(m, m.transpose());
    }

    #[test]
    fn flat_equals_union_of_factors() {
        // Def 3.4: support(I + ΣB_s) = diag ∪ ∪_s support(B_s)
        let nb = 16;
        let mut acc = BlockMask::identity(nb);
        let mut s = 2;
        while s <= nb {
            acc = acc.union(&butterfly_factor_mask(nb, s));
            s *= 2;
        }
        assert_eq!(acc, flat_butterfly_mask(nb, nb));
    }

    #[test]
    fn product_at_full_stride_is_all_to_all() {
        // the defining property of butterfly networks: with log2(nb)
        // factors every input block reaches every output block
        let nb = 16;
        let support = butterfly_product_support(nb, nb);
        assert_eq!(support.nnz(), nb * nb);
    }

    #[test]
    fn product_at_partial_stride_is_local_groups() {
        let nb = 16;
        let support = butterfly_product_support(nb, 4);
        // reachability limited to 4-block groups
        for i in 0..nb {
            for j in 0..nb {
                assert_eq!(support.get(i, j), i / 4 == j / 4, "({i},{j})");
            }
        }
    }

    #[test]
    fn budget_fill_is_tight() {
        let nb = 64;
        for budget in [64usize, 128, 192, 256, 448] {
            let k = max_stride_for_budget(nb, budget);
            assert!(flat_butterfly_nnz_blocks(nb, k) <= budget);
            if k < nb {
                assert!(flat_butterfly_nnz_blocks(nb, k * 2) > budget);
            }
        }
    }

    #[test]
    fn stretch_covers_all_rows_cols() {
        let m = stretched_flat_butterfly(16, 4, 4);
        assert!(m.rows_nonempty());
        assert!(m.transpose().rows_nonempty());
    }
}
