//! Baseline sparsity patterns the paper compares against (Fig 4/7/9,
//! Table 7, Appendix K's candidate components).

use super::butterfly::flat_butterfly_mask;
use super::mask::BlockMask;
use crate::util::Rng;

/// Local banded window: |i - j| <= window (Fig 12 "Local").
pub fn local_mask(nb: usize, window: usize) -> BlockMask {
    let mut m = BlockMask::zeros(nb, nb);
    for i in 0..nb {
        let lo = i.saturating_sub(window);
        let hi = (i + window).min(nb - 1);
        for j in lo..=hi {
            m.set(i, j, true);
        }
    }
    m
}

/// Global stripe of `width` leading rows + columns (Fig 12 "Global";
/// rank <= 2 * width * b — the block-aligned low-rank form, Appendix I.2).
pub fn global_mask(nb: usize, width: usize) -> BlockMask {
    let mut m = BlockMask::zeros(nb, nb);
    for i in 0..nb {
        for j in 0..nb {
            if i < width || j < width {
                m.set(i, j, true);
            }
        }
    }
    m
}

/// Random block mask at the given density, rows/cols kept nonempty
/// (pruning-at-init baseline; Fig 12 "Random").
pub fn random_mask(nbr: usize, nbc: usize, density: f64, rng: &mut Rng) -> BlockMask {
    let mut m = BlockMask::zeros(nbr, nbc);
    for i in 0..nbr {
        for j in 0..nbc {
            if rng.bool(density) {
                m.set(i, j, true);
            }
        }
    }
    for i in 0..nbr {
        m.set(i, rng.below(nbc), true);
    }
    for j in 0..nbc {
        m.set(rng.below(nbr), j, true);
    }
    m
}

/// Random *element* mask (non-block-aligned; Table 7 "Random, 1x1"): the
/// unstructured-sparsity baseline whose block cover blows up.
pub fn random_element_mask(n: usize, density: f64, rng: &mut Rng) -> BlockMask {
    let mut m = BlockMask::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if rng.bool(density) {
                m.set(i, j, true);
            }
        }
    }
    m
}

/// Random mask grouped into `g x g` pattern blocks (Table 7 sweeps g from
/// 1..32): nonzeros come in g-blocks but need not align to the hardware
/// block grid.
pub fn random_grouped_mask(n: usize, g: usize, density: f64, rng: &mut Rng) -> BlockMask {
    let mut m = BlockMask::zeros(n, n);
    let ng = n / g;
    for bi in 0..ng {
        for bj in 0..ng {
            if rng.bool(density) {
                // place the g x g group at a random (unaligned) offset
                let oi = (bi * g + rng.below(g.max(1))).min(n - g);
                let oj = (bj * g + rng.below(g.max(1))).min(n - g);
                for di in 0..g {
                    for dj in 0..g {
                        m.set(oi + di, oj + dj, true);
                    }
                }
            }
        }
    }
    m
}

/// BigBird (Zaheer et al. 2020): window + global + random blocks.
pub fn bigbird_mask(nb: usize, window: usize, n_global: usize, n_random: usize,
                    rng: &mut Rng) -> BlockMask {
    let mut m = local_mask(nb, window).union(&global_mask(nb, n_global));
    for i in 0..nb {
        for _ in 0..n_random {
            m.set(i, rng.below(nb), true);
        }
    }
    m
}

/// Sparse Transformer (Child et al. 2019) strided pattern at block level.
pub fn sparse_transformer_mask(nb: usize, stride: Option<usize>) -> BlockMask {
    let s = stride.unwrap_or_else(|| (nb as f64).sqrt().max(1.0) as usize);
    let mut m = local_mask(nb, 1);
    for i in 0..nb {
        let mut j = 0;
        while j < nb {
            m.set(i, j, true);
            j += s;
        }
    }
    m
}

/// Longformer: window + global, no random.
pub fn longformer_mask(nb: usize, window: usize, n_global: usize) -> BlockMask {
    local_mask(nb, window).union(&global_mask(nb, n_global))
}

/// Reformer-style LSH bucketing approximation: queries attend within their
/// hash bucket.  We model it as a random balanced block permutation mask —
/// crucially NOT aligned to any fixed pattern across steps, which is why
/// the paper measures it as slow (Fig 9, 0.8x).
pub fn reformer_bucket_mask(nb: usize, bucket_blocks: usize, rng: &mut Rng) -> BlockMask {
    let mut order: Vec<usize> = (0..nb).collect();
    rng.shuffle(&mut order);
    let mut m = BlockMask::zeros(nb, nb);
    for chunk in order.chunks(bucket_blocks.max(1)) {
        for &i in chunk {
            for &j in chunk {
                m.set(i, j, true);
            }
        }
    }
    m
}

/// Pixelfly attention mask: flat butterfly ∪ global stripe.
pub fn pixelfly_attention_mask(nb: usize, max_stride: usize, global_width: usize) -> BlockMask {
    flat_butterfly_mask(nb, max_stride.min(nb)).union(&global_mask(nb, global_width))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_mask_band() {
        let m = local_mask(8, 1);
        assert!(m.get(3, 2) && m.get(3, 3) && m.get(3, 4));
        assert!(!m.get(3, 5));
        assert_eq!(m, m.transpose());
    }

    #[test]
    fn global_mask_rank_structure() {
        let m = global_mask(8, 2);
        assert_eq!(m.nnz(), 8 * 2 + 2 * 8 - 4);
    }

    #[test]
    fn random_mask_nonempty_rows() {
        let mut rng = Rng::new(7);
        let m = random_mask(16, 8, 0.05, &mut rng);
        assert!(m.rows_nonempty());
        assert!(m.transpose().rows_nonempty());
    }

    #[test]
    fn bigbird_contains_window_and_global() {
        let mut rng = Rng::new(1);
        let m = bigbird_mask(16, 1, 1, 2, &mut rng);
        assert!(local_mask(16, 1).contained_in(&m));
        assert!(global_mask(16, 1).contained_in(&m));
    }

    #[test]
    fn sparse_transformer_has_strided_cols() {
        let m = sparse_transformer_mask(16, Some(4));
        for i in 0..16 {
            for j in (0..16).step_by(4) {
                assert!(m.get(i, j));
            }
        }
    }

    #[test]
    fn reformer_buckets_are_blocks() {
        let mut rng = Rng::new(3);
        let m = reformer_bucket_mask(16, 4, &mut rng);
        // every row attends to exactly its bucket (4 blocks)
        for i in 0..16 {
            assert_eq!(m.row_cols(i).len(), 4);
            assert!(m.get(i, i));
        }
        assert_eq!(m, m.transpose());
    }

    #[test]
    fn pixelfly_attention_mask_contains_diag_and_global() {
        let m = pixelfly_attention_mask(16, 4, 1);
        for i in 0..16 {
            assert!(m.get(i, i));
            assert!(m.get(i, 0) && m.get(0, i));
        }
    }

    #[test]
    fn grouped_random_small_groups_inflate_cover() {
        // Table 7: same expected density, smaller group => bigger cover
        let mut rng = Rng::new(9);
        let n = 128;
        let small = random_grouped_mask(n, 2, 0.02, &mut rng);
        let mut rng2 = Rng::new(9);
        let large = random_grouped_mask(n, 32, 0.02, &mut rng2);
        let infl_small = small.actual_density(32) / small.density().max(1e-9);
        let infl_large = large.actual_density(32) / large.density().max(1e-9);
        assert!(infl_small > infl_large,
                "small-group inflation {infl_small} should exceed {infl_large}");
    }
}
