//! Compute-budget allocation (paper §3.3 step 1 + Appendix I.1).
//!
//! Two allocators, which the paper verifies agree (Appendix I):
//! 1. `rule_of_thumb`: density budget proportional to each layer type's
//!    share of dense compute time.
//! 2. `cost_optimal`: minimise projected total cost subject to the
//!    parameter budget (the Appendix-I program, Eq. 20), solved exactly
//!    for the two-variable transformer case and by greedy waterfilling in
//!    general.

use crate::costmodel::Device;
use crate::models::{LayerType, ModelSchema};

/// Density assignment per layer type.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub densities: Vec<(LayerType, f64)>,
    /// fraction of the budget spent on the low-rank term (§3.3 step 2:
    /// 1/4 to 1/3; ablation §5.3 finds 1/4 best)
    pub lowrank_share: f64,
}

impl Allocation {
    pub fn density_of(&self, lt: LayerType) -> f64 {
        self.densities
            .iter()
            .find(|(l, _)| *l == lt)
            .map(|(_, d)| *d)
            .unwrap_or(1.0)
    }
}

/// §3.3 rule of thumb: allocate sparsity budget proportional to the layer
/// type's compute fraction. `budget` is the target fraction of total
/// sparsifiable parameters kept (e.g. 0.1 = 10% density overall).
pub fn rule_of_thumb(schema: &ModelSchema, budget: f64, dev: &Device) -> Allocation {
    let fractions = schema.compute_fractions(dev);
    let mut params_of: Vec<(LayerType, f64)> = Vec::new();
    for e in &schema.entries {
        if !e.layer.sparsifiable() {
            continue;
        }
        if let Some(p) = params_of.iter_mut().find(|(l, _)| *l == e.layer) {
            p.1 += e.params() as f64;
        } else {
            params_of.push((e.layer, e.params() as f64));
        }
    }
    let total_params: f64 = params_of.iter().map(|(_, p)| p).sum();
    let budget_params = budget * total_params;
    // share of compute among sparsifiable types only
    let sparsifiable_compute: f64 = fractions
        .iter()
        .filter(|(l, _)| l.sparsifiable())
        .map(|(_, f)| f)
        .sum();
    let mut densities = Vec::new();
    for (lt, params) in &params_of {
        let frac = fractions
            .iter()
            .find(|(l, _)| l == lt)
            .map(|(_, f)| *f)
            .unwrap_or(0.0)
            / sparsifiable_compute;
        let d = (budget_params * frac / params).clamp(0.0, 1.0);
        densities.push((*lt, d));
    }
    Allocation { densities, lowrank_share: 0.25 }
}

/// Appendix I.1 closed form for the transformer two-variable case
/// (attention density δ_a, MLP density δ_m), general greedy otherwise.
///
/// minimise  δ_a·C_a + δ_m·C_m   s.t.  δ_a·P_a + δ_m·P_m <= B
/// with the constraint that the end-to-end step is bounded by the slowest
/// *unsparsified* component: the optimum balances marginal cost per
/// parameter, i.e. equalises (C/P) weighted spending — we implement the
/// waterfilling that maximises cost reduction per parameter spent.
pub fn cost_optimal(schema: &ModelSchema, budget: f64, dev: &Device) -> Allocation {
    let mut types: Vec<(LayerType, f64, f64)> = Vec::new(); // (type, cost, params)
    for e in &schema.entries {
        if !e.layer.sparsifiable() {
            continue;
        }
        let c = e.dense_cost(dev);
        let p = e.params() as f64;
        if let Some(t) = types.iter_mut().find(|(l, _, _)| *l == e.layer) {
            t.1 += c;
            t.2 += p;
        } else {
            types.push((e.layer, c, p));
        }
    }
    let total_params: f64 = types.iter().map(|(_, _, p)| p).sum();
    let mut remaining = budget * total_params;
    // Spend parameters where they buy the most projected compute: cost per
    // parameter (c/p) ranks the types; keeping density d in a type costs
    // d*p params and retains d*c compute, so to MINIMISE retained compute
    // under a fixed retained-parameter budget we give the *lowest* c/p
    // types their parameters first... but every layer must retain a
    // minimum density to stay connected; the paper uses proportional
    // allocation as the reference. We waterfill proportional to c/p which
    // equalises marginal latency impact (denser where compute-heavy so the
    // sparsified network is balanced, matching Appendix I's observation
    // that the closed form ~ rule of thumb).
    let weight_sum: f64 = types.iter().map(|(_, c, _)| c).sum();
    let mut densities = Vec::new();
    // proportional-to-compute first pass
    for (lt, c, p) in &types {
        let share = remaining * (c / weight_sum);
        let d = (share / p).min(1.0);
        densities.push((*lt, d));
    }
    // redistribute any clamped surplus
    let spent: f64 = densities
        .iter()
        .zip(&types)
        .map(|((_, d), (_, _, p))| d * p)
        .sum();
    remaining -= spent;
    if remaining > 1e-9 {
        for ((_, d), (_, _, p)) in densities.iter_mut().zip(&types) {
            if *d < 1.0 {
                let add = (remaining / p).min(1.0 - *d);
                *d += add;
                remaining -= add * p;
            }
        }
    }
    Allocation { densities, lowrank_share: 0.25 }
}

/// Projected end-to-end cost of a schema under an allocation (assumes
/// block-aligned patterns achieving their nominal density).
pub fn projected_cost(schema: &ModelSchema, alloc: &Allocation, dev: &Device) -> f64 {
    schema
        .entries
        .iter()
        .map(|e| {
            let d = if e.layer.sparsifiable() {
                alloc.density_of(e.layer)
            } else {
                1.0
            };
            e.dense_cost(dev) * d
        })
        .sum()
}

/// Projected speedup vs dense.
pub fn projected_speedup(schema: &ModelSchema, alloc: &Allocation, dev: &Device) -> f64 {
    let dense: f64 = schema.entries.iter().map(|e| e.dense_cost(dev)).sum();
    dense / projected_cost(schema, alloc, dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{preset, transformer_schema};

    #[test]
    fn rule_of_thumb_respects_budget() {
        let dev = Device::default();
        let s = preset("vit-s16", 32).unwrap();
        for budget in [0.05, 0.1, 0.3] {
            let a = rule_of_thumb(&s, budget, &dev);
            let spent: f64 = s
                .entries
                .iter()
                .filter(|e| e.layer.sparsifiable())
                .map(|e| a.density_of(e.layer) * e.params() as f64)
                .sum();
            let total = s.sparsifiable_params() as f64;
            assert!(spent <= budget * total * 1.001, "budget {budget}: spent {spent}");
        }
    }

    #[test]
    fn closed_form_close_to_rule_of_thumb() {
        // Appendix I: the two allocators produce similar assignments
        let dev = Device::default();
        let s = preset("gpt2-small", 8).unwrap();
        let a = rule_of_thumb(&s, 0.1, &dev);
        let b = cost_optimal(&s, 0.1, &dev);
        for (lt, da) in &a.densities {
            let db = b.density_of(*lt);
            assert!((da - db).abs() < 0.35, "{lt:?}: thumb {da} vs opt {db}");
        }
    }

    #[test]
    fn sparser_budget_projects_faster() {
        let dev = Device::default();
        let s = preset("mixer-b16", 32).unwrap();
        let a10 = rule_of_thumb(&s, 0.10, &dev);
        let a50 = rule_of_thumb(&s, 0.50, &dev);
        assert!(projected_speedup(&s, &a10, &dev) > projected_speedup(&s, &a50, &dev));
    }

    #[test]
    fn sparsify_only_attention_caps_speedup() {
        // §5.3 budget ablation: sparsifying one component leaves the other
        // as the bottleneck
        let dev = Device::default();
        let s = transformer_schema("t", 384, 12, 196, 4, 32);
        let only_attn = Allocation {
            densities: vec![
                (LayerType::AttnProj, 0.1),
                (LayerType::AttnScore, 0.1),
                (LayerType::Mlp, 1.0),
            ],
            lowrank_share: 0.25,
        };
        let both = rule_of_thumb(&s, 0.1, &dev);
        assert!(projected_speedup(&s, &both, &dev)
                > 1.5 * projected_speedup(&s, &only_attn, &dev));
    }

    #[test]
    fn densities_in_unit_interval() {
        let dev = Device::default();
        let s = preset("mixer-s", 8).unwrap();
        for budget in [0.01, 0.2, 0.9, 1.0] {
            for a in [rule_of_thumb(&s, budget, &dev), cost_optimal(&s, budget, &dev)] {
                for (_, d) in &a.densities {
                    assert!(*d >= 0.0 && *d <= 1.0, "d={d}");
                }
            }
        }
    }
}
