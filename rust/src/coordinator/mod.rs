//! The Layer-3 coordinator: the paper's system contribution.
//!
//! - [`budget`]  compute-budget allocation across layer types (§3.3 step 1,
//!   Appendix I closed form + rule of thumb)
//! - [`planner`] sparsity-mask selection: rank + max-stride filling a
//!   layer's budget (§3.3 step 2)
//! - [`trainer`] the training loop over PJRT artifacts: batching, LR
//!   schedule, metrics, eval, loss-curve logging — plus the substrate
//!   train-step drivers riding the [`crate::nn::Module`] trait (whole
//!   compiled models live in `crate::nn::compile`)
//! - [`metrics`] run reports (loss curves, step timing, throughput) and
//!   their CSV/TSV serialization for EXPERIMENTS.md

pub mod budget;
pub mod experiments;
pub mod metrics;
pub mod planner;
pub mod trainer;

pub use budget::{cost_optimal, projected_speedup, rule_of_thumb, Allocation};
pub use planner::{plan_attention, plan_layer, plan_model, AttentionPlan, LayerPlan, ModelPlan};
pub use trainer::{
    AttnTrainStep, DenseLinear, Linear, SparseLinear, StepTimings, TrainConfig,
    TrainStep, Trainer,
};
