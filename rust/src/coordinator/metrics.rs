//! Run reports: loss curves, step timing, throughput, eval metrics.

use crate::util::Summary;

/// Evaluation result over a set of batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub n_examples: usize,
}

impl EvalResult {
    /// LM perplexity (e^loss with loss in nats).
    pub fn perplexity(&self) -> f64 {
        self.loss.exp()
    }
}

/// Full record of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub preset: String,
    pub steps: usize,
    /// (step, loss) samples
    pub loss_curve: Vec<(usize, f64)>,
    pub step_time: Option<Summary>,
    /// tokens (LM) or examples (vision) per second, hot steps only
    pub throughput: f64,
    pub final_eval: Option<EvalResult>,
    pub param_count: usize,
    pub compile_ms: f64,
    /// worker count of the substrate execution engine during the run
    /// (`sparse::exec::threads()`); 0 when unrecorded
    pub substrate_threads: usize,
    /// resolved microkernel tier of the substrate during the run
    /// (`sparse::exec::kernel_name()`: "scalar" / "avx2" / "neon");
    /// empty when unrecorded
    pub kernel: String,
    /// resolved precision tier of the substrate during the run
    /// (`sparse::exec::precision_name()`: "f32" / "bf16" / "int8");
    /// empty when unrecorded
    pub precision: String,
    /// per-phase step-time split (forward / backward / optimizer update),
    /// recorded by drivers that run all three on the substrate
    /// (`TrainStep`); `None` for engine-path runs where the phases
    /// execute inside one opaque artifact
    pub fwd_time: Option<Summary>,
    pub bwd_time: Option<Summary>,
    pub update_time: Option<Summary>,
    /// the engine's calibrated serial-vs-parallel cutover in flops
    /// (`sparse::exec::calibration()`; infinity on single-core hosts);
    /// 0 when unrecorded
    pub par_threshold_flops: f64,
    /// overlap scheduler mode during the run
    /// (`sparse::exec::overlap_mode().name()`: "off" / "dw" / "dw+comm");
    /// empty when the run never engaged the scheduler
    pub overlap: String,
    /// dW/update time absorbed into pool idle slots by the overlap
    /// scheduler (already inside `bwd_time`, split out for the
    /// exposed-vs-hidden view); `None` when the scheduler never engaged
    pub ov_hidden_time: Option<Summary>,
    /// overlap-scope drain time the critical path actually waited on
    pub ov_exposed_time: Option<Summary>,
    /// measured pool dispatch overhead feeding that cutover, ns; 0 when
    /// unrecorded or when `PIXELFLY_PAR_FLOPS` pinned the threshold
    pub dispatch_ns: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f64 {
        self.loss_curve.last().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }

    pub fn initial_loss(&self) -> f64 {
        self.loss_curve.first().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }

    /// Serialize the loss curve as TSV (step\tloss).
    pub fn curve_tsv(&self) -> String {
        let mut s = String::from("step\tloss\n");
        for (step, loss) in &self.loss_curve {
            s.push_str(&format!("{step}\t{loss:.6}\n"));
        }
        s
    }

    /// One-line summary for experiment tables.
    pub fn summary_line(&self) -> String {
        let eval = self
            .final_eval
            .map(|e| format!(" eval_loss={:.4} acc={:.3} ppl={:.2}", e.loss, e.accuracy,
                             e.perplexity()))
            .unwrap_or_default();
        let st = self
            .step_time
            .as_ref()
            .map(|s| format!(" step={:.1}ms", s.mean_ms()))
            .unwrap_or_default();
        let st = match (&self.fwd_time, &self.bwd_time, &self.update_time) {
            (Some(f), Some(b), Some(u)) => format!(
                "{st} (fwd={:.1} bwd={:.1} upd={:.1})",
                f.mean_ms(),
                b.mean_ms(),
                u.mean_ms()
            ),
            _ => st,
        };
        let thr = if self.substrate_threads > 0 {
            format!(" threads={}", self.substrate_threads)
        } else {
            String::new()
        };
        let thr = if self.kernel.is_empty() {
            thr
        } else {
            format!("{thr} kernel={}", self.kernel)
        };
        // precision tier: f32 is the default; only non-default tiers are
        // worth a column in experiment tables
        let thr = if self.precision.is_empty() || self.precision == "f32" {
            thr
        } else {
            format!("{thr} prec={}", self.precision)
        };
        // overlap scheduler: only runs that engaged it get the column
        // (off-mode and engine-path runs leave these unset)
        let thr = match (&self.ov_hidden_time, &self.ov_exposed_time) {
            (Some(h), Some(e)) if !self.overlap.is_empty() => format!(
                "{thr} overlap={} (hidden={:.1} exposed={:.1})",
                self.overlap,
                h.mean_ms(),
                e.mean_ms()
            ),
            _ => thr,
        };
        // calibrated cutover (finite ⇔ parallelism is ever worth it)
        let thr = if self.par_threshold_flops > 0.0 && self.par_threshold_flops.is_finite()
        {
            format!("{thr} par_cutover={:.1e}f", self.par_threshold_flops)
        } else {
            thr
        };
        format!(
            "{}: steps={} loss {:.4} -> {:.4}{st} thru={:.1}/s params={}{thr}{eval}",
            self.preset,
            self.steps,
            self.initial_loss(),
            self.final_loss(),
            self.throughput,
            self.param_count,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_zero_loss_is_one() {
        let e = EvalResult { loss: 0.0, accuracy: 1.0, n_examples: 10 };
        assert!((e.perplexity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn curve_tsv_format() {
        let mut r = TrainReport::default();
        r.loss_curve = vec![(0, 2.5), (10, 1.25)];
        let tsv = r.curve_tsv();
        assert!(tsv.starts_with("step\tloss\n"));
        assert!(tsv.contains("10\t1.250000"));
        assert!((r.initial_loss() - 2.5).abs() < 1e-12);
        assert!((r.final_loss() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn summary_line_shows_phase_split_when_recorded() {
        let mut r = TrainReport::default();
        r.preset = "substrate_mlp".into();
        r.loss_curve = vec![(0, 1.0)];
        assert!(!r.summary_line().contains("fwd="));
        let s = Summary { mean_ns: 2e6, p50_ns: 2e6, p95_ns: 2e6, ..Default::default() };
        r.step_time = Some(s.clone());
        r.fwd_time = Some(s.clone());
        r.bwd_time = Some(s.clone());
        r.update_time = Some(s);
        let line = r.summary_line();
        assert!(line.contains("fwd=2.0"), "{line}");
        assert!(line.contains("bwd=2.0"), "{line}");
        assert!(line.contains("upd=2.0"), "{line}");
    }

    #[test]
    fn summary_line_mentions_preset() {
        let mut r = TrainReport::default();
        r.preset = "gpt2_s_pixelfly".into();
        r.loss_curve = vec![(0, 3.0)];
        assert!(r.summary_line().contains("gpt2_s_pixelfly"));
        // unrecorded kernel tier stays out of the line...
        assert!(!r.summary_line().contains("kernel="));
        // ...and shows up once recorded
        r.kernel = "avx2".into();
        assert!(r.summary_line().contains("kernel=avx2"));
    }

    #[test]
    fn summary_line_shows_precision_only_when_reduced() {
        let mut r = TrainReport::default();
        r.preset = "p".into();
        r.loss_curve = vec![(0, 1.0)];
        assert!(!r.summary_line().contains("prec="), "unrecorded stays out");
        r.precision = "f32".into();
        assert!(!r.summary_line().contains("prec="), "default tier stays out");
        r.precision = "bf16".into();
        assert!(r.summary_line().contains("prec=bf16"), "{}", r.summary_line());
    }

    #[test]
    fn summary_line_shows_overlap_only_when_engaged() {
        let mut r = TrainReport::default();
        r.preset = "p".into();
        r.loss_curve = vec![(0, 1.0)];
        assert!(!r.summary_line().contains("overlap="), "unrecorded stays out");
        let s = Summary { mean_ns: 1.5e6, p50_ns: 1.5e6, p95_ns: 1.5e6,
                          ..Default::default() };
        // mode name without the timing split (or vice versa) stays out —
        // both land together or not at all
        r.overlap = "dw".into();
        assert!(!r.summary_line().contains("overlap="), "no split, stays out");
        r.ov_hidden_time = Some(s.clone());
        r.ov_exposed_time = Some(s);
        let line = r.summary_line();
        assert!(line.contains("overlap=dw"), "{line}");
        assert!(line.contains("hidden=1.5"), "{line}");
        assert!(line.contains("exposed=1.5"), "{line}");
    }

    #[test]
    fn summary_line_shows_calibrated_cutover_only_when_finite() {
        let mut r = TrainReport::default();
        r.preset = "p".into();
        r.loss_curve = vec![(0, 1.0)];
        assert!(!r.summary_line().contains("par_cutover="), "unrecorded stays out");
        r.par_threshold_flops = f64::INFINITY; // single-core host
        assert!(!r.summary_line().contains("par_cutover="), "inf stays out");
        r.par_threshold_flops = 3.2e6;
        assert!(r.summary_line().contains("par_cutover=3.2e6f"),
                "{}", r.summary_line());
    }
}
