//! Sparsity-mask selection (paper §3.3 step 2).
//!
//! Given a layer's density budget, split it low-rank : butterfly
//! (default 1/4 : 3/4), pick the rank as a block multiple, and pick the
//! flat-butterfly max stride filling the rest — producing a `LayerPlan`
//! that maps one-to-one onto the Python ModelConfig fields
//! (`max_stride`, `rank`, `attn_max_stride`, `attn_global_blocks`).

use crate::models::{LayerType, ModelSchema};
use crate::patterns::butterfly::{max_stride_for_budget, stretched_flat_butterfly};
use crate::patterns::BlockMask;

use super::budget::Allocation;

/// Concrete sparsity plan for one GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub layer: LayerType,
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// flat butterfly max stride (blocks); 1 = diagonal only
    pub max_stride: usize,
    /// low-rank term rank (elements; multiple of block, 0 = none)
    pub rank: usize,
    /// achieved density (butterfly + low-rank params over dense params)
    pub achieved_density: f64,
}

impl LayerPlan {
    /// The butterfly term's block mask — the SAME stretched mask the
    /// compiler materializes (square plans reduce to the square flat
    /// pattern; rectangular plans get the Appendix-I.4 stretch), so the
    /// mask, [`Self::butterfly_params`] and the realized weights agree.
    pub fn butterfly_mask(&self) -> BlockMask {
        stretched_flat_butterfly(self.rows / self.block, self.cols / self.block,
                                 self.max_stride)
    }

    /// Exact weight elements of the materialized butterfly term: counted
    /// off the same stretched mask the compiler builds, so planner
    /// accounting and `nn::compile`'s realized parameter counts agree on
    /// EVERY shape (integer-ratio shortcuts used to diverge when the
    /// long side was not a block-multiple of the short side).
    pub fn butterfly_params(&self) -> usize {
        stretched_flat_butterfly(self.rows / self.block, self.cols / self.block,
                                 self.max_stride)
            .nnz() * self.block * self.block
    }

    pub fn lowrank_params(&self) -> usize {
        self.rank * (self.rows + self.cols)
    }
}

/// Plan one layer: density -> (rank, max_stride), paper §3.3 step 2.
pub fn plan_layer(layer: LayerType, rows: usize, cols: usize, block: usize,
                  density: f64, lowrank_share: f64) -> LayerPlan {
    assert!(rows % block == 0 && cols % block == 0,
            "dims {rows}x{cols} must be multiples of block {block}");
    let dense_params = rows * cols;
    let budget = (density * dense_params as f64) as usize;

    let (nbr, nbc) = (rows / block, cols / block);
    let nb = nbr.min(nbc);
    // the power-of-two stride domain of the stretched pattern (mirrors
    // stretched_flat_butterfly's internal grid)
    let p2 = if nb.is_power_of_two() {
        nb
    } else {
        (nb.next_power_of_two() / 2).max(1)
    };
    // EXACT materialized cost of the stretched flat butterfly at stride
    // k — the same mask the compiler builds, so rounding can only go
    // down and planner accounting matches realized weights on every
    // shape (including long sides that are not multiples of the short)
    let bf_cost = |k: usize| stretched_flat_butterfly(nbr, nbc, k).nnz() * block * block;
    // the flat term never drops below the block diagonal, so a plan
    // always pays at least one stride level
    let diag_params = bf_cost(1);

    // low-rank share, rank as a block multiple (rounded to the nearest
    // block so a 0.96-block budget still buys the paper's minimum rank)
    let lr_budget = (lowrank_share * budget as f64) as usize;
    let rank_blocks = ((lr_budget as f64 / ((rows + cols) * block) as f64) + 0.5) as usize;
    let mut rank = rank_blocks * block;
    // never let the low-rank term eat more than half the total budget —
    // and always leave room for the mandatory diagonal, so the nearest-
    // block rounding can only round DOWN the realized density, never
    // past the request
    while rank > 0
        && (rank * (rows + cols) > budget / 2
            || rank * (rows + cols) + diag_params > budget)
    {
        rank -= block;
    }
    let lr_params = rank * (rows + cols);

    // remaining budget fills the flat butterfly stride against the real
    // stretched-mask cost; no forced minimum — the stride-1 diagonal is
    // the floor, the only case where the realized density may exceed a
    // request below the diagonal floor itself
    let bf_budget = budget.saturating_sub(lr_params);
    let mut max_stride = 1;
    while max_stride < p2 {
        let next = max_stride * 2;
        if bf_cost(next) > bf_budget {
            break;
        }
        max_stride = next;
    }

    let bf_params = bf_cost(max_stride);
    LayerPlan {
        layer,
        rows,
        cols,
        block,
        max_stride,
        rank,
        achieved_density: (bf_params + lr_params) as f64 / dense_params as f64,
    }
}

/// Plan for the attention score mask: flat butterfly + global stripe with
/// the global width playing the low-rank role (Appendix I.2).
#[derive(Clone, Debug, PartialEq)]
pub struct AttentionPlan {
    pub seq_blocks: usize,
    pub block: usize,
    pub max_stride: usize,
    pub global_blocks: usize,
    pub achieved_density: f64,
}

pub fn plan_attention(seq_len: usize, block: usize, density: f64,
                      lowrank_share: f64) -> AttentionPlan {
    let nb = seq_len / block;
    let budget_blocks = (density * (nb * nb) as f64) as usize;
    let global_budget = (lowrank_share * budget_blocks as f64) as usize;
    // width-w global stripe costs ~ 2*w*nb - w^2 blocks
    let mut global_blocks = 0;
    while global_blocks < nb / 2 {
        let next = global_blocks + 1;
        if 2 * next * nb - next * next > global_budget {
            break;
        }
        global_blocks = next;
    }
    let stripe = 2 * global_blocks * nb - global_blocks * global_blocks;
    let rest = budget_blocks.saturating_sub(stripe);
    // no forced diagonal minimum: for any request at or above the
    // diagonal floor (1/nb density) the realized union mask stays within
    // the block budget (stripe + flat, overlaps counted once)
    let max_stride = max_stride_for_budget(nb, rest);
    let mask = crate::patterns::baselines::pixelfly_attention_mask(nb, max_stride, global_blocks);
    AttentionPlan {
        seq_blocks: nb,
        block,
        max_stride,
        global_blocks,
        achieved_density: mask.density(),
    }
}

/// Full-model plan: one LayerPlan per schema entry + an attention plan.
#[derive(Clone, Debug)]
pub struct ModelPlan {
    pub layers: Vec<LayerPlan>,
    pub attention: Option<AttentionPlan>,
    pub total_density: f64,
}

pub fn plan_model(schema: &ModelSchema, alloc: &Allocation, block: usize) -> ModelPlan {
    let mut layers = Vec::new();
    let mut attention = None;
    let mut kept = 0usize;
    let mut dense = 0usize;
    for e in &schema.entries {
        if !e.layer.sparsifiable() {
            continue;
        }
        let d = alloc.density_of(e.layer);
        if e.layer == LayerType::AttnScore {
            let plan = plan_attention(schema.seq_len, block, d, alloc.lowrank_share);
            kept += (plan.achieved_density * (e.params() as f64)) as usize;
            dense += e.params();
            attention = Some(plan);
        } else {
            let plan = plan_layer(e.layer, e.rows, e.cols, block, d, alloc.lowrank_share);
            kept += (plan.butterfly_params() + plan.lowrank_params()) * e.count;
            dense += e.params();
            layers.push(plan);
        }
    }
    ModelPlan {
        layers,
        attention,
        total_density: kept as f64 / dense.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Device;
    use crate::coordinator::budget::rule_of_thumb;
    use crate::models::preset;

    #[test]
    fn layer_plan_respects_density() {
        for density in [0.05, 0.1, 0.25, 0.5] {
            let p = plan_layer(LayerType::Mlp, 512, 512, 32, density, 0.25);
            assert!(p.achieved_density <= density * 1.30 + 0.02,
                    "density {density}: achieved {}", p.achieved_density);
        }
    }

    #[test]
    fn rank_is_block_multiple() {
        let p = plan_layer(LayerType::Mlp, 1024, 1024, 32, 0.2, 0.3);
        assert_eq!(p.rank % 32, 0);
        assert!(p.rank > 0, "enough budget for a low-rank term");
    }

    #[test]
    fn lowrank_share_quarter_to_third() {
        let p = plan_layer(LayerType::Mlp, 1024, 1024, 32, 0.2, 0.25);
        let lr = p.lowrank_params() as f64;
        let total = lr + p.butterfly_params() as f64;
        assert!(lr / total > 0.10 && lr / total < 0.40, "share {}", lr / total);
    }

    #[test]
    fn attention_plan_has_diag() {
        let p = plan_attention(1024, 32, 0.15, 0.25);
        assert!(p.max_stride >= 1);
        assert!(p.achieved_density <= 0.30);
    }

    #[test]
    fn model_plan_end_to_end() {
        let dev = Device::default();
        let s = preset("vit-s", 32).unwrap();
        let alloc = rule_of_thumb(&s, 0.2, &dev);
        let plan = plan_model(&s, &alloc, 8);
        assert!(!plan.layers.is_empty());
        assert!(plan.attention.is_some());
        assert!(plan.total_density < 0.6, "density {}", plan.total_density);
    }

    #[test]
    fn realized_density_never_exceeds_request() {
        // PR 4 satellite: block-count rounding must round DOWN — above
        // the mandatory-diagonal floor, the realized density can never
        // exceed the requested allocation. Includes shapes whose long
        // side is NOT a block-multiple of the short side (the case where
        // integer-ratio accounting used to overshoot).
        for &(rows, cols, block) in &[(512usize, 512usize, 32usize), (256, 512, 32),
                                      (128, 256, 16), (1024, 1024, 32),
                                      (128, 128, 16), (128, 320, 32),
                                      (320, 128, 32)] {
            let diag = stretched_flat_butterfly(rows / block, cols / block, 1).nnz()
                * block * block;
            let floor = diag as f64 / (rows * cols) as f64;
            for density in [0.08, 0.10, 0.15, 0.25, 0.30, 0.40, 0.60] {
                if density < floor {
                    continue; // the diagonal itself outweighs the request
                }
                for share in [0.0, 0.25, 0.33] {
                    let p = plan_layer(LayerType::Mlp, rows, cols, block, density,
                                       share);
                    assert!(p.achieved_density <= density + 1e-9,
                            "{rows}x{cols} b={block} density {density} share \
                             {share}: achieved {}", p.achieved_density);
                    // the plan's accounting is the realized cost: what
                    // the compiler materializes equals butterfly_params
                    assert_eq!(p.butterfly_params(),
                               stretched_flat_butterfly(rows / block, cols / block,
                                                        p.max_stride)
                                   .nnz() * block * block);
                }
            }
        }
    }

    #[test]
    fn attention_realized_density_never_exceeds_request() {
        for &(seq, block) in &[(1024usize, 32usize), (512, 32), (256, 16),
                               (128, 16)] {
            let nb = seq / block;
            let floor = 1.0 / nb as f64; // the block diagonal
            for density in [0.05, 0.10, 0.20, 0.40] {
                if density < floor {
                    continue;
                }
                for share in [0.0, 0.25] {
                    let p = plan_attention(seq, block, density, share);
                    assert!(p.achieved_density <= density + 1e-9,
                            "seq {seq} b={block} density {density} share {share}: \
                             achieved {}", p.achieved_density);
                }
            }
        }
    }

    #[test]
    fn rectangular_layer_plans() {
        let p = plan_layer(LayerType::Mlp, 256, 512, 32, 0.2, 0.25);
        assert!(p.max_stride >= 1);
        assert!(p.achieved_density > 0.0);
    }
}
