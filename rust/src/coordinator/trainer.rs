//! The training loop: Rust-driven, Python-free.
//!
//! State layout contract with `python/compile/train.py` (pytree flatten
//! order, recorded in the manifest):
//!
//!     train_step inputs : [P params][P m][P v][step s32][lr f32][x][y]
//!     train_step outputs: (loss, [P params], [P m], [P v], step)
//!     forward_eval inputs : [P params][x][y]   outputs: (loss, n_correct)
//!
//! Each step samples a synthetic batch (family-specific substrate),
//! executes the train-step artifact, and swaps the returned state literals
//! in.  Loss is read from the scalar output; everything heavier stays in
//! literal form.  The LR schedule (linear warmup + cosine decay, the
//! paper's recipe) is computed host-side and passed as a scalar so no
//! recompilation is ever needed.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::{corpus::MarkovCorpus, lra::LraDataset, lra::LraTask, vision::VisionDataset};
use crate::nn::{self, mse_loss_grad, Module, StepTimer};
use crate::patterns::BlockMask;
use crate::runtime::engine::{self, Engine, Literal};
use crate::sparse::attention::{self, AttnPlan, AttnStats};
use crate::sparse::dense::Matrix;
use crate::sparse::exec::{self, Workspace};
use crate::util::{Rng, Summary};

use super::metrics::{EvalResult, TrainReport};

// The linear building blocks grew into the Module API and live in
// `crate::nn` now; re-exported here so the established
// `coordinator::{SparseLinear, …}` paths keep working.
pub use crate::nn::{DenseLinear, Linear, SparseLinear, StepTimings};

/// What to train and how long.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// manifest preset, e.g. "gpt2_s_pixelfly"
    pub preset: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_batches: usize,
    /// LRA task override (preset "lra_*" only)
    pub lra_task: Option<LraTask>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "mixer_s_pixelfly".into(),
            steps: 50,
            lr: 1e-3,
            warmup: 10,
            seed: 0,
            log_every: 10,
            eval_batches: 4,
            lra_task: None,
        }
    }
}

/// Batch sampler dispatching on the artifact's model family.
enum Sampler {
    Vision(VisionDataset),
    Corpus(MarkovCorpus, usize /* seq */),
    Lra(LraDataset),
}

/// Reused sampling buffers: the steady-state training loop clears and
/// refills these instead of allocating fresh batch vectors every step, so
/// host-side batch synthesis stops touching the allocator after step one
/// (the literal handed to the engine still copies, which is the engine
/// ABI's cost, not the sampler's).
#[derive(Default)]
struct SampleBufs {
    xf: Vec<f32>,
    xi: Vec<i32>,
    y: Vec<i32>,
}

impl Sampler {
    fn sample(&self, batch: usize, rng: &mut Rng, bufs: &mut SampleBufs)
              -> Result<(Literal, Literal, usize)> {
        match self {
            Sampler::Vision(ds) => {
                ds.sample_into(batch, rng, &mut bufs.xf, &mut bufs.y);
                Ok((
                    engine::f32_literal(&[batch, ds.seq, ds.dim], &bufs.xf)?,
                    engine::i32_literal(&[batch], &bufs.y)?,
                    batch,
                ))
            }
            Sampler::Corpus(c, seq) => {
                c.sample_into(batch, *seq, rng, &mut bufs.xi, &mut bufs.y);
                Ok((
                    engine::i32_literal(&[batch, *seq], &bufs.xi)?,
                    engine::i32_literal(&[batch, *seq], &bufs.y)?,
                    batch * seq,
                ))
            }
            Sampler::Lra(ds) => {
                ds.sample_into(batch, rng, &mut bufs.xf, &mut bufs.y);
                Ok((
                    engine::f32_literal(&[batch, ds.seq, ds.dim], &bufs.xf)?,
                    engine::i32_literal(&[batch], &bufs.y)?,
                    batch,
                ))
            }
        }
    }
}

pub struct Trainer<'e> {
    pub engine: &'e mut Engine,
    pub cfg: TrainConfig,
    sampler: Sampler,
    family: String,
    batch: usize,
    n_leaves: usize,
    /// params ++ m ++ v, in manifest order
    state: Vec<Literal>,
    step_lit: Literal,
    step: usize,
    /// reused batch-synthesis buffers (zero-alloc steady-state sampling)
    bufs: SampleBufs,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e mut Engine, cfg: TrainConfig) -> Result<Self> {
        let key = format!("{}.train_step", cfg.preset);
        engine.load(&key)?;
        let spec = engine.manifest.artifact(&key)?.clone();
        let family = spec.config.get("family").cloned().unwrap_or_default();
        let seq: usize = spec.cfg("seq_len").unwrap_or(64);
        let in_dim: usize = spec.cfg("in_dim").unwrap_or(16);
        let n_classes: usize = spec.cfg("n_classes").unwrap_or(10);

        let sampler = if let Some(task) = cfg.lra_task {
            Sampler::Lra(LraDataset::new(task, seq, in_dim))
        } else {
            match family.as_str() {
                "gpt2" => Sampler::Corpus(MarkovCorpus::new(n_classes, cfg.seed), seq),
                "mixer" | "vit" => Sampler::Vision(VisionDataset::new(
                    n_classes, seq, in_dim, 0.5, cfg.seed,
                )),
                f => bail!("unknown model family {f:?}"),
            }
        };

        // initial state: params from the AOT dump, zeros for m/v
        let params = engine.load_initial_state(&cfg.preset, &key)?;
        let n_leaves = spec.n_param_leaves;
        let mut state = params;
        for i in 0..2 * n_leaves {
            let t = &spec.inputs[n_leaves + i]; // m then v specs
            state.push(engine::zero_literal(t)?);
        }
        Ok(Trainer {
            engine,
            batch: spec.batch,
            n_leaves,
            state,
            step_lit: engine::i32_scalar(0)?,
            step: 0,
            sampler,
            family,
            cfg,
            bufs: SampleBufs::default(),
        })
    }

    /// Linear warmup then cosine decay to 10% (the paper's schedule shape).
    pub fn lr_at(&self, step: usize) -> f32 {
        let base = self.cfg.lr;
        if step < self.cfg.warmup {
            return base * (step + 1) as f32 / self.cfg.warmup as f32;
        }
        let t = (step - self.cfg.warmup) as f32
            / (self.cfg.steps.saturating_sub(self.cfg.warmup)).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
        base * (0.1 + 0.9 * cos)
    }

    /// One optimizer step; returns the loss.
    pub fn step_once(&mut self, rng: &mut Rng) -> Result<f64> {
        let key = format!("{}.train_step", self.cfg.preset);
        let (x, y, _) = self.sampler.sample(self.batch, rng, &mut self.bufs)?;
        let lr = engine::f32_scalar(self.lr_at(self.step))?;
        let mut args: Vec<&Literal> = self.state.iter().collect();
        args.push(&self.step_lit);
        args.push(&lr);
        args.push(&x);
        args.push(&y);
        let art = self.engine.load(&key)?;
        let outs = art
            .exe
            .execute::<&Literal>(&args)
            .context("train_step execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let p = self.n_leaves;
        if outs.len() != 3 * p + 2 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), 3 * p + 2);
        }
        let mut iter = outs.into_iter();
        let loss = iter.next().unwrap().get_first_element::<f32>()? as f64;
        let mut new_state: Vec<Literal> = Vec::with_capacity(3 * p);
        for _ in 0..3 * p {
            new_state.push(iter.next().unwrap());
        }
        self.step_lit = iter.next().unwrap();
        self.state = new_state;
        self.step += 1;
        Ok(loss)
    }

    /// Run the configured number of steps; returns the full report.
    pub fn train(&mut self) -> Result<TrainReport> {
        let key = format!("{}.train_step", self.cfg.preset);
        let (param_count, compile_ms) = {
            let art = self.engine.load(&key)?;
            (art.spec.param_count, art.compile_ms)
        };
        let mut rng = Rng::new(self.cfg.seed ^ 0xDA7A);
        let mut report = TrainReport {
            preset: self.cfg.preset.clone(),
            steps: self.cfg.steps,
            param_count,
            compile_ms,
            // host-side substrate work (batch synthesis, NTK checks, any
            // fallback math) runs on the execution engine's pool; record
            // the effective width and the resolved kernel tier so runs
            // are comparable across machines
            substrate_threads: exec::threads(),
            kernel: exec::kernel_name().to_string(),
            precision: exec::precision_name().to_string(),
            par_threshold_flops: exec::calibration().par_threshold_flops,
            dispatch_ns: exec::calibration().dispatch_ns,
            ..Default::default()
        };
        let mut times = Vec::new();
        let mut units_per_step = 0usize;
        for s in 0..self.cfg.steps {
            let t0 = Instant::now();
            let loss = self.step_once(&mut rng)?;
            times.push(t0.elapsed());
            if units_per_step == 0 {
                units_per_step = match &self.sampler {
                    Sampler::Corpus(_, seq) => self.batch * seq,
                    _ => self.batch,
                };
            }
            if s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps {
                report.loss_curve.push((s, loss));
            }
        }
        // skip the first (compile/warmup-heavy) samples for throughput
        let hot = if times.len() > 3 { &times[2..] } else { &times[..] };
        let summary = Summary::from_durations(hot);
        report.throughput = units_per_step as f64 / (summary.mean_ns / 1e9);
        report.step_time = Some(summary);
        if self.cfg.eval_batches > 0 {
            let eval_key = format!("{}.forward_eval", self.cfg.preset);
            if self.engine.manifest.artifacts.contains_key(&eval_key) {
                report.final_eval = Some(self.evaluate(self.cfg.eval_batches)?);
            }
            // presets lowered train-only (e.g. lra_*_train) simply skip eval
        }
        Ok(report)
    }

    /// Evaluate on fresh batches with the forward_eval artifact.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<EvalResult> {
        let key = format!("{}.forward_eval", self.cfg.preset);
        self.engine.load(&key)?;
        let units_per_batch = match self.family.as_str() {
            "gpt2" => {
                let spec = self.engine.manifest.artifact(&key)?;
                let seq: usize = spec.cfg("seq_len").unwrap_or(1);
                self.batch * seq
            }
            _ => self.batch,
        };
        let mut rng = Rng::new(self.cfg.seed ^ 0xE7A1_5EED);
        let mut total_loss = 0.0;
        let mut total_correct = 0usize;
        let mut total_n = 0usize;
        for _ in 0..n_batches {
            let (x, y, _) = self.sampler.sample(self.batch, &mut rng, &mut self.bufs)?;
            let mut args: Vec<&Literal> = self.state[..self.n_leaves].iter().collect();
            args.push(&x);
            args.push(&y);
            let art = self.engine.load(&key)?;
            let outs = art.exe.execute::<&Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            total_loss += outs[0].get_first_element::<f32>()? as f64;
            total_correct += outs[1].get_first_element::<i32>()? as usize;
            total_n += units_per_batch;
        }
        Ok(EvalResult {
            loss: total_loss / n_batches as f64,
            accuracy: total_correct as f64 / total_n as f64,
            n_examples: total_n,
        })
    }

    pub fn current_step(&self) -> usize {
        self.step
    }

    /// Borrow the current parameter literals (e.g. for checkpointing).
    pub fn params(&self) -> &[Literal] {
        &self.state[..self.n_leaves]
    }

    /// Serialize current params to a directory (one .bin per leaf).
    pub fn checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (i, lit) in self.params().iter().enumerate() {
            // int leaves don't occur in params, but be safe
            let data: Vec<f32> = match lit.to_vec::<f32>() {
                Ok(v) => v,
                Err(_) => lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
            };
            let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
            std::fs::write(dir.join(format!("param_{i:04}.bin")), bytes)?;
        }
        Ok(())
    }
}

// =====================================================================
// Substrate training tier: forward → backward → update without the
// engine. The `Trainer` above drives compiled train_step artifacts (the
// PJRT parity path); `TrainStep` / `AttnTrainStep` below are thin
// drivers over the `crate::nn::Module` trait — the layers own their
// stashes and gradients, the drivers own the inter-layer buffers and the
// phase clock. They remain the gradcheck-oracle-bearing harnesses the
// fig1 bench and the proptests pin the engine against; whole models
// (attention + MLP chains, ViT/Mixer/GPT-2 presets) run through the
// model compiler (`crate::nn::compile`) on the same trait.
// =====================================================================

/// Substrate train-step driver for a chain of [`Linear`] layers: one
/// `step` runs fused forward → transpose-free backward → SIMD optimizer
/// update, timing each phase. The step's allocation-freedom is
/// structural: every activation/gradient buffer is sized once at
/// construction, the layers' stashes size themselves on first forward,
/// and the BSR forward/backward engines need no scratch at all — the
/// workspace threaded through the Module calls is never drawn from on
/// this path. (The attention driver below DOES need scratch and carries
/// real, assertable workspace counters.)
pub struct TrainStep {
    pub layers: Vec<Linear>,
    batch: usize,
    /// `acts[i]` = activated output of layer i
    acts: Vec<Matrix>,
    /// `grads[i]` = dL/d(`acts[i]`), consumed in place by layer i's backward
    grads: Vec<Matrix>,
    ws: Workspace,
}

impl TrainStep {
    pub fn new(layers: Vec<Linear>, batch: usize) -> Self {
        assert!(!layers.is_empty());
        for pair in layers.windows(2) {
            assert_eq!(pair[0].out_dim(), pair[1].in_dim(), "layer dims must chain");
        }
        let acts: Vec<Matrix> =
            layers.iter().map(|l| Matrix::zeros(batch, l.out_dim())).collect();
        let grads: Vec<Matrix> =
            layers.iter().map(|l| Matrix::zeros(batch, l.out_dim())).collect();
        TrainStep { layers, batch, acts, grads, ws: Workspace::new() }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Per-phase flops of one step (forward, backward, update). The
    /// first layer performs no input-gradient GEMM, so its backward
    /// counts only the dW half.
    pub fn phase_flops(&self) -> (f64, f64, f64) {
        let m = self.batch;
        (
            self.layers.iter().map(|l| l.fwd_flops(m)).sum(),
            self.layers.iter().map(|l| l.bwd_flops(m)).sum::<f64>()
                - self.layers[0].fwd_flops(m),
            self.layers.iter().map(|l| l.update_flops()).sum(),
        )
    }

    /// One training step on `(x, target)`; returns (loss, phase split).
    /// Runs as one whole-step dispatch region ([`exec::step_scope`]): the
    /// layer chain's job batches flow through the resident pool
    /// latch-to-latch instead of paying a park/wake per op.
    pub fn step(&mut self, x: &Matrix, target: &Matrix, lr: f32, momentum: f32)
                -> (f64, StepTimings) {
        assert_eq!((x.rows, x.cols), (self.batch, self.layers[0].in_dim()));
        exec::step_scope(|| self.step_inner(x, target, lr, momentum))
    }

    fn step_inner(&mut self, x: &Matrix, target: &Matrix, lr: f32, momentum: f32)
                  -> (f64, StepTimings) {
        let nl = self.layers.len();

        let mut timer = StepTimer::start();
        for i in 0..nl {
            let (done, rest) = self.acts.split_at_mut(i);
            let input: &Matrix = if i == 0 { x } else { &done[i - 1] };
            self.layers[i].forward_into(input, &mut rest[0], &mut self.ws);
        }
        timer.fwd_done();

        let loss = mse_loss_grad(&self.acts[nl - 1], target, &mut self.grads[nl - 1]);
        for i in (0..nl).rev() {
            let (gprev, gcur) = self.grads.split_at_mut(i);
            let dy = &mut gcur[0];
            // the first layer feeds no upstream: skip its dX GEMM entirely
            let (input, dx): (&Matrix, Option<&mut Matrix>) = if i == 0 {
                (x, None)
            } else {
                (&self.acts[i - 1], Some(&mut gprev[i - 1]))
            };
            self.layers[i].backward_into(input, &self.acts[i], dy, dx, &mut self.ws);
        }
        timer.bwd_done();

        for layer in &mut self.layers {
            Module::update(layer, lr, momentum);
        }
        timer.update_done();

        (loss, timer.finish())
    }

    /// Train against a fixed synthetic regression batch (throughput- and
    /// convergence-checkable) through the shared report driver, with the
    /// fwd/bwd/update split.
    pub fn train(&mut self, steps: usize, lr: f32, momentum: f32, seed: u64)
                 -> TrainReport {
        let mut rng = Rng::new(seed ^ 0x5B57_7A7E);
        let x = Matrix::randn(self.batch, self.layers[0].in_dim(), 1.0, &mut rng);
        let target = Matrix::randn(
            self.batch,
            self.layers.last().unwrap().out_dim(),
            0.5,
            &mut rng,
        );
        let params = self.param_count();
        let batch = self.batch;
        nn::drive_substrate_training("substrate_mlp", steps, params, batch, 10,
                                     |_s| self.step(&x, &target, lr, momentum))
    }
}

/// Substrate train-step driver for an attention block: fused
/// streaming attention (with stats) → sparse output projection → MSE
/// loss, then the Flash-style recompute backward and the fused optimizer
/// sweep. Self-attention over one sequence (`q = k = v = x`), so the
/// input gradient is `dq + dk + dv`.
pub struct AttnTrainStep {
    plan: std::sync::Arc<AttnPlan>,
    causal: bool,
    pub wo: Linear,
    stats: AttnStats,
    ws: Workspace,
    o: Matrix,
    y: Matrix,
    gy: Matrix,
    d_o: Matrix,
    dq: Matrix,
    dk: Matrix,
    dv: Matrix,
    pub dx: Matrix,
    seq: usize,
    d: usize,
}

impl AttnTrainStep {
    pub fn new(mask: &BlockMask, causal: bool, seq: usize, d: usize, wo: Linear) -> Self {
        assert_eq!(wo.in_dim(), d, "projection must consume the head output");
        let plan = attention::plan_for(mask, causal, exec::threads());
        AttnTrainStep {
            plan,
            causal,
            stats: AttnStats::new(),
            ws: Workspace::new(),
            o: Matrix::zeros(seq, d),
            y: Matrix::zeros(seq, wo.out_dim()),
            gy: Matrix::zeros(seq, wo.out_dim()),
            d_o: Matrix::zeros(seq, d),
            dq: Matrix::zeros(seq, d),
            dk: Matrix::zeros(seq, d),
            dv: Matrix::zeros(seq, d),
            dx: Matrix::zeros(seq, d),
            wo,
            seq,
            d,
        }
    }

    pub fn causal(&self) -> bool {
        self.causal
    }

    /// Attention flops of one forward (see [`AttnPlan::flops`]).
    pub fn attn_flops(&self) -> f64 {
        self.plan.flops(self.seq / self.plan.grid_blocks(), self.d)
    }

    pub fn alloc_events(&self) -> usize {
        self.ws.alloc_events()
    }

    pub fn peak_scratch_bytes(&self) -> usize {
        self.ws.peak_bytes()
    }

    /// One training step on sequence `x` against `target`; returns
    /// (loss, phase split). One whole-step dispatch region, like
    /// [`TrainStep::step`].
    pub fn step(&mut self, x: &Matrix, target: &Matrix, lr: f32, momentum: f32)
                -> (f64, StepTimings) {
        assert_eq!((x.rows, x.cols), (self.seq, self.d));
        exec::step_scope(|| self.step_inner(x, target, lr, momentum))
    }

    fn step_inner(&mut self, x: &Matrix, target: &Matrix, lr: f32, momentum: f32)
                  -> (f64, StepTimings) {
        let mut timer = StepTimer::start();
        self.plan.execute_stats(x, x, x, &mut self.o, &mut self.stats, &mut self.ws);
        self.wo.forward_into(&self.o, &mut self.y, &mut self.ws);
        timer.fwd_done();

        let loss = mse_loss_grad(&self.y, target, &mut self.gy);
        self.wo.backward_into(&self.o, &self.y, &mut self.gy, Some(&mut self.d_o),
                              &mut self.ws);
        self.plan.backward(x, x, x, &self.o, &self.d_o, &self.stats,
                           &mut self.dq, &mut self.dk, &mut self.dv, &mut self.ws);
        // self-attention: x feeds q, k and v, so the input gradient sums
        for ((dxv, &dqv), (&dkv, &dvv)) in self
            .dx
            .data
            .iter_mut()
            .zip(&self.dq.data)
            .zip(self.dk.data.iter().zip(&self.dv.data))
        {
            *dxv = dqv + dkv + dvv;
        }
        timer.bwd_done();

        Module::update(&mut self.wo, lr, momentum);
        timer.update_done();

        (loss, timer.finish())
    }
}

#[cfg(test)]
mod substrate_tests {
    use super::*;
    use crate::patterns::baselines;
    use crate::sparse::exec::Activation;

    fn mlp(sparse: bool, n: usize, block: usize, batch: usize, seed: u64) -> TrainStep {
        let mut rng = Rng::new(seed);
        let nb = n / block;
        let layer = |act: Activation, rng: &mut Rng| -> Linear {
            if sparse {
                let mask = baselines::random_mask(nb, nb, 0.4, rng);
                Linear::Sparse(SparseLinear::random(&mask, block, act,
                                                    1.0 / (n as f32).sqrt(), rng))
            } else {
                Linear::Dense(DenseLinear::random(n, n, act,
                                                  1.0 / (n as f32).sqrt(), rng))
            }
        };
        let layers = vec![layer(Activation::Gelu, &mut rng),
                          layer(Activation::Identity, &mut rng)];
        TrainStep::new(layers, batch)
    }

    #[test]
    fn sparse_mlp_train_step_decreases_loss() {
        let mut ts = mlp(true, 64, 16, 8, 1);
        let r = ts.train(40, 5e-2, 0.9, 7);
        assert!(r.final_loss().is_finite());
        assert!(r.final_loss() < r.initial_loss(),
                "loss must fall: {} -> {}", r.initial_loss(), r.final_loss());
        assert!(r.fwd_time.is_some() && r.bwd_time.is_some() && r.update_time.is_some());
        assert!(r.summary_line().contains("fwd="));
    }

    #[test]
    fn dense_mlp_train_step_decreases_loss() {
        let mut ts = mlp(false, 32, 16, 8, 2);
        let r = ts.train(40, 5e-2, 0.9, 7);
        assert!(r.final_loss() < r.initial_loss());
    }

    #[test]
    fn sparse_and_dense_steps_agree_on_identical_weights() {
        // a sparse layer over a FULL mask and a dense layer seeded with
        // the same weights must produce the same loss trajectory — the
        // two backward implementations checking each other end-to-end
        let (n, block, batch) = (32usize, 16usize, 6usize);
        let mut rng = Rng::new(3);
        let mask = crate::patterns::BlockMask::ones(n / block, n / block);
        let s1 = SparseLinear::random(&mask, block, Activation::Gelu, 0.3, &mut rng);
        let s2 = SparseLinear::random(&mask, block, Activation::Identity, 0.3, &mut rng);
        let d1 = DenseLinear::from_parts(s1.w.to_dense(), s1.bias.clone(),
                                         Activation::Gelu);
        let d2 = DenseLinear::from_parts(s2.w.to_dense(), s2.bias.clone(),
                                         Activation::Identity);
        let mut sp = TrainStep::new(vec![Linear::Sparse(s1), Linear::Sparse(s2)], batch);
        let mut de = TrainStep::new(vec![Linear::Dense(d1), Linear::Dense(d2)], batch);
        let x = Matrix::randn(batch, n, 1.0, &mut rng);
        let t = Matrix::randn(batch, n, 0.5, &mut rng);
        for step in 0..5 {
            let (ls, _) = sp.step(&x, &t, 1e-2, 0.9);
            let (ld, _) = de.step(&x, &t, 1e-2, 0.9);
            assert!((ls - ld).abs() < 1e-4 * (1.0 + ls.abs()),
                    "step {step}: sparse {ls} vs dense {ld}");
        }
    }

    #[test]
    fn repeated_steps_on_fixed_buffers_stay_finite() {
        // the linear chain reuses its member buffers across steps (the
        // workspace threaded through the Module calls is never drawn from
        // on this path — allocation-freedom is structural); repeated
        // stepping must stay numerically sane
        let mut ts = mlp(true, 64, 16, 8, 4);
        let mut rng = Rng::new(5);
        let x = Matrix::randn(8, 64, 1.0, &mut rng);
        let t = Matrix::randn(8, 64, 0.5, &mut rng);
        let mut last = f64::INFINITY;
        for _ in 0..4 {
            let (loss, _) = ts.step(&x, &t, 1e-2, 0.9);
            assert!(loss.is_finite());
            last = loss;
        }
        assert!(last.is_finite());
        assert_eq!(ts.ws.alloc_events(), 0,
                   "the MLP chain must never draw workspace scratch");
    }

    #[test]
    fn attention_train_step_decreases_loss_and_stays_zero_alloc() {
        let (seq, d, block) = (64usize, 16usize, 16usize);
        let mut rng = Rng::new(6);
        let mask = baselines::pixelfly_attention_mask(seq / block, 2, 1);
        let womask = crate::patterns::BlockMask::ones(1, 1);
        let wo = Linear::Sparse(SparseLinear::random(&womask, d, Activation::Identity,
                                                     0.3, &mut rng));
        let mut ts = AttnTrainStep::new(&mask, true, seq, d, wo);
        let x = Matrix::randn(seq, d, 1.0, &mut rng);
        let t = Matrix::randn(seq, d, 0.5, &mut rng);
        let (first, _) = ts.step(&x, &t, 5e-2, 0.9);
        let warm = ts.alloc_events();
        let mut last = first;
        for _ in 0..20 {
            let (l, timings) = ts.step(&x, &t, 5e-2, 0.9);
            last = l;
            assert!(timings.total() >= timings.fwd);
        }
        assert!(last < first, "loss must fall: {first} -> {last}");
        assert_eq!(ts.alloc_events(), warm, "steady-state step must not allocate");
        // never a seq×seq buffer
        assert!(ts.peak_scratch_bytes() < seq * seq * 4);
    }
}
