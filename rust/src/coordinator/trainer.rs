//! The training loop: Rust-driven, Python-free.
//!
//! State layout contract with `python/compile/train.py` (pytree flatten
//! order, recorded in the manifest):
//!
//!   train_step inputs : [P params][P m][P v][step s32][lr f32][x][y]
//!   train_step outputs: (loss, [P params], [P m], [P v], step)
//!   forward_eval inputs : [P params][x][y]   outputs: (loss, n_correct)
//!
//! Each step samples a synthetic batch (family-specific substrate),
//! executes the train-step artifact, and swaps the returned state literals
//! in.  Loss is read from the scalar output; everything heavier stays in
//! literal form.  The LR schedule (linear warmup + cosine decay, the
//! paper's recipe) is computed host-side and passed as a scalar so no
//! recompilation is ever needed.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::{corpus::MarkovCorpus, lra::LraDataset, lra::LraTask, vision::VisionDataset};
use crate::runtime::engine::{self, Engine, Literal};
use crate::sparse::exec;
use crate::util::{Rng, Summary};

use super::metrics::{EvalResult, TrainReport};

/// What to train and how long.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// manifest preset, e.g. "gpt2_s_pixelfly"
    pub preset: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_batches: usize,
    /// LRA task override (preset "lra_*" only)
    pub lra_task: Option<LraTask>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "mixer_s_pixelfly".into(),
            steps: 50,
            lr: 1e-3,
            warmup: 10,
            seed: 0,
            log_every: 10,
            eval_batches: 4,
            lra_task: None,
        }
    }
}

/// Batch sampler dispatching on the artifact's model family.
enum Sampler {
    Vision(VisionDataset),
    Corpus(MarkovCorpus, usize /* seq */),
    Lra(LraDataset),
}

/// Reused sampling buffers: the steady-state training loop clears and
/// refills these instead of allocating fresh batch vectors every step, so
/// host-side batch synthesis stops touching the allocator after step one
/// (the literal handed to the engine still copies, which is the engine
/// ABI's cost, not the sampler's).
#[derive(Default)]
struct SampleBufs {
    xf: Vec<f32>,
    xi: Vec<i32>,
    y: Vec<i32>,
}

impl Sampler {
    fn sample(&self, batch: usize, rng: &mut Rng, bufs: &mut SampleBufs)
              -> Result<(Literal, Literal, usize)> {
        match self {
            Sampler::Vision(ds) => {
                ds.sample_into(batch, rng, &mut bufs.xf, &mut bufs.y);
                Ok((
                    engine::f32_literal(&[batch, ds.seq, ds.dim], &bufs.xf)?,
                    engine::i32_literal(&[batch], &bufs.y)?,
                    batch,
                ))
            }
            Sampler::Corpus(c, seq) => {
                c.sample_into(batch, *seq, rng, &mut bufs.xi, &mut bufs.y);
                Ok((
                    engine::i32_literal(&[batch, *seq], &bufs.xi)?,
                    engine::i32_literal(&[batch, *seq], &bufs.y)?,
                    batch * seq,
                ))
            }
            Sampler::Lra(ds) => {
                ds.sample_into(batch, rng, &mut bufs.xf, &mut bufs.y);
                Ok((
                    engine::f32_literal(&[batch, ds.seq, ds.dim], &bufs.xf)?,
                    engine::i32_literal(&[batch], &bufs.y)?,
                    batch,
                ))
            }
        }
    }
}

pub struct Trainer<'e> {
    pub engine: &'e mut Engine,
    pub cfg: TrainConfig,
    sampler: Sampler,
    family: String,
    batch: usize,
    n_leaves: usize,
    /// params ++ m ++ v, in manifest order
    state: Vec<Literal>,
    step_lit: Literal,
    step: usize,
    /// reused batch-synthesis buffers (zero-alloc steady-state sampling)
    bufs: SampleBufs,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e mut Engine, cfg: TrainConfig) -> Result<Self> {
        let key = format!("{}.train_step", cfg.preset);
        engine.load(&key)?;
        let spec = engine.manifest.artifact(&key)?.clone();
        let family = spec.config.get("family").cloned().unwrap_or_default();
        let seq: usize = spec.cfg("seq_len").unwrap_or(64);
        let in_dim: usize = spec.cfg("in_dim").unwrap_or(16);
        let n_classes: usize = spec.cfg("n_classes").unwrap_or(10);

        let sampler = if let Some(task) = cfg.lra_task {
            Sampler::Lra(LraDataset::new(task, seq, in_dim))
        } else {
            match family.as_str() {
                "gpt2" => Sampler::Corpus(MarkovCorpus::new(n_classes, cfg.seed), seq),
                "mixer" | "vit" => Sampler::Vision(VisionDataset::new(
                    n_classes, seq, in_dim, 0.5, cfg.seed,
                )),
                f => bail!("unknown model family {f:?}"),
            }
        };

        // initial state: params from the AOT dump, zeros for m/v
        let params = engine.load_initial_state(&cfg.preset, &key)?;
        let n_leaves = spec.n_param_leaves;
        let mut state = params;
        for i in 0..2 * n_leaves {
            let t = &spec.inputs[n_leaves + i]; // m then v specs
            state.push(engine::zero_literal(t)?);
        }
        Ok(Trainer {
            engine,
            batch: spec.batch,
            n_leaves,
            state,
            step_lit: engine::i32_scalar(0)?,
            step: 0,
            sampler,
            family,
            cfg,
            bufs: SampleBufs::default(),
        })
    }

    /// Linear warmup then cosine decay to 10% (the paper's schedule shape).
    pub fn lr_at(&self, step: usize) -> f32 {
        let base = self.cfg.lr;
        if step < self.cfg.warmup {
            return base * (step + 1) as f32 / self.cfg.warmup as f32;
        }
        let t = (step - self.cfg.warmup) as f32
            / (self.cfg.steps.saturating_sub(self.cfg.warmup)).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
        base * (0.1 + 0.9 * cos)
    }

    /// One optimizer step; returns the loss.
    pub fn step_once(&mut self, rng: &mut Rng) -> Result<f64> {
        let key = format!("{}.train_step", self.cfg.preset);
        let (x, y, _) = self.sampler.sample(self.batch, rng, &mut self.bufs)?;
        let lr = engine::f32_scalar(self.lr_at(self.step))?;
        let mut args: Vec<&Literal> = self.state.iter().collect();
        args.push(&self.step_lit);
        args.push(&lr);
        args.push(&x);
        args.push(&y);
        let art = self.engine.load(&key)?;
        let outs = art
            .exe
            .execute::<&Literal>(&args)
            .context("train_step execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let p = self.n_leaves;
        if outs.len() != 3 * p + 2 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), 3 * p + 2);
        }
        let mut iter = outs.into_iter();
        let loss = iter.next().unwrap().get_first_element::<f32>()? as f64;
        let mut new_state: Vec<Literal> = Vec::with_capacity(3 * p);
        for _ in 0..3 * p {
            new_state.push(iter.next().unwrap());
        }
        self.step_lit = iter.next().unwrap();
        self.state = new_state;
        self.step += 1;
        Ok(loss)
    }

    /// Run the configured number of steps; returns the full report.
    pub fn train(&mut self) -> Result<TrainReport> {
        let key = format!("{}.train_step", self.cfg.preset);
        let (param_count, compile_ms) = {
            let art = self.engine.load(&key)?;
            (art.spec.param_count, art.compile_ms)
        };
        let mut rng = Rng::new(self.cfg.seed ^ 0xDA7A);
        let mut report = TrainReport {
            preset: self.cfg.preset.clone(),
            steps: self.cfg.steps,
            param_count,
            compile_ms,
            // host-side substrate work (batch synthesis, NTK checks, any
            // fallback math) runs on the execution engine's pool; record
            // the effective width and the resolved kernel tier so runs
            // are comparable across machines
            substrate_threads: exec::threads(),
            kernel: exec::kernel_name().to_string(),
            ..Default::default()
        };
        let mut times = Vec::new();
        let mut units_per_step = 0usize;
        for s in 0..self.cfg.steps {
            let t0 = Instant::now();
            let loss = self.step_once(&mut rng)?;
            times.push(t0.elapsed());
            if units_per_step == 0 {
                units_per_step = match &self.sampler {
                    Sampler::Corpus(_, seq) => self.batch * seq,
                    _ => self.batch,
                };
            }
            if s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps {
                report.loss_curve.push((s, loss));
            }
        }
        // skip the first (compile/warmup-heavy) samples for throughput
        let hot = if times.len() > 3 { &times[2..] } else { &times[..] };
        let summary = Summary::from_durations(hot);
        report.throughput = units_per_step as f64 / (summary.mean_ns / 1e9);
        report.step_time = Some(summary);
        if self.cfg.eval_batches > 0 {
            let eval_key = format!("{}.forward_eval", self.cfg.preset);
            if self.engine.manifest.artifacts.contains_key(&eval_key) {
                report.final_eval = Some(self.evaluate(self.cfg.eval_batches)?);
            }
            // presets lowered train-only (e.g. lra_*_train) simply skip eval
        }
        Ok(report)
    }

    /// Evaluate on fresh batches with the forward_eval artifact.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<EvalResult> {
        let key = format!("{}.forward_eval", self.cfg.preset);
        self.engine.load(&key)?;
        let units_per_batch = match self.family.as_str() {
            "gpt2" => {
                let spec = self.engine.manifest.artifact(&key)?;
                let seq: usize = spec.cfg("seq_len").unwrap_or(1);
                self.batch * seq
            }
            _ => self.batch,
        };
        let mut rng = Rng::new(self.cfg.seed ^ 0xE7A1_5EED);
        let mut total_loss = 0.0;
        let mut total_correct = 0usize;
        let mut total_n = 0usize;
        for _ in 0..n_batches {
            let (x, y, _) = self.sampler.sample(self.batch, &mut rng, &mut self.bufs)?;
            let mut args: Vec<&Literal> = self.state[..self.n_leaves].iter().collect();
            args.push(&x);
            args.push(&y);
            let art = self.engine.load(&key)?;
            let outs = art.exe.execute::<&Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            total_loss += outs[0].get_first_element::<f32>()? as f64;
            total_correct += outs[1].get_first_element::<i32>()? as usize;
            total_n += units_per_batch;
        }
        Ok(EvalResult {
            loss: total_loss / n_batches as f64,
            accuracy: total_correct as f64 / total_n as f64,
            n_examples: total_n,
        })
    }

    pub fn current_step(&self) -> usize {
        self.step
    }

    /// Borrow the current parameter literals (e.g. for checkpointing).
    pub fn params(&self) -> &[Literal] {
        &self.state[..self.n_leaves]
    }

    /// Serialize current params to a directory (one .bin per leaf).
    pub fn checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (i, lit) in self.params().iter().enumerate() {
            // int leaves don't occur in params, but be safe
            let data: Vec<f32> = match lit.to_vec::<f32>() {
                Ok(v) => v,
                Err(_) => lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
            };
            let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
            std::fs::write(dir.join(format!("param_{i:04}.bin")), bytes)?;
        }
        Ok(())
    }
}
