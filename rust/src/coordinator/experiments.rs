//! Experiment-matrix launcher: drives the full paper reproduction in one
//! command (`pixelfly experiments --out results/`), writing per-experiment
//! TSVs that EXPERIMENTS.md quotes.
//!
//! Each experiment is declared as an `ExperimentSpec` (figure/table id,
//! presets, steps) so the matrix is data, not code — extend by appending
//! to `matrix()`.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::data::lra::LraTask;
use crate::runtime::Engine;
use crate::util::Rng;

use super::metrics::TrainReport;
use super::trainer::{TrainConfig, Trainer};

/// One experiment: a set of presets trained under identical settings.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// experiment id matching DESIGN.md's index, e.g. "fig5_mixer"
    pub id: &'static str,
    pub presets: &'static [&'static str],
    pub steps: usize,
    pub lr: f32,
    pub eval_batches: usize,
    pub lra_task: Option<LraTask>,
}

/// The default reproduction matrix (training-based experiments; the
/// substrate microbenches live in `cargo bench`).
pub fn matrix(steps_scale: f64) -> Vec<ExperimentSpec> {
    let s = |n: usize| ((n as f64 * steps_scale) as usize).max(5);
    vec![
        ExperimentSpec {
            id: "fig5_mixer",
            presets: &["mixer_s_dense", "mixer_s_pixelfly", "mixer_s_random"],
            steps: s(120), lr: 1e-3, eval_batches: 8, lra_task: None,
        },
        ExperimentSpec {
            id: "fig5_vit",
            presets: &["vit_s_dense", "vit_s_pixelfly", "vit_s_bigbird"],
            steps: s(120), lr: 1e-3, eval_batches: 8, lra_task: None,
        },
        ExperimentSpec {
            id: "fig8_gpt2",
            presets: &["gpt2_s_dense", "gpt2_s_pixelfly", "gpt2_s_bigbird"],
            steps: s(200), lr: 3e-3, eval_batches: 8, lra_task: None,
        },
        ExperimentSpec {
            id: "table8_butterfly",
            presets: &["mixer_s_dense", "mixer_s_butterfly", "mixer_s_pixelfly"],
            steps: s(120), lr: 1e-3, eval_batches: 8, lra_task: None,
        },
        ExperimentSpec {
            id: "fig9_lra_text",
            presets: &["lra_dense_train", "lra_pixelfly_train"],
            steps: s(40), lr: 1e-3, eval_batches: 4, lra_task: Some(LraTask::Text),
        },
    ]
}

/// Result row: one preset's report within an experiment.
pub struct ExperimentRow {
    pub experiment: String,
    pub report: TrainReport,
}

/// Run one experiment spec; skips presets missing from the manifest.
pub fn run_experiment(artifacts: &Path, spec: &ExperimentSpec, seed: u64)
                      -> Result<Vec<ExperimentRow>> {
    let mut rows = Vec::new();
    for preset in spec.presets {
        let mut engine = Engine::new(artifacts)?;
        if engine.manifest.artifacts.get(&format!("{preset}.train_step")).is_none() {
            eprintln!("[{}] skip {preset} (artifact missing)", spec.id);
            continue;
        }
        let cfg = TrainConfig {
            preset: preset.to_string(),
            steps: spec.steps,
            lr: spec.lr,
            warmup: spec.steps / 10,
            log_every: (spec.steps / 20).max(1),
            eval_batches: spec.eval_batches,
            seed,
            lra_task: spec.lra_task,
        };
        let mut trainer = Trainer::new(&mut engine, cfg)?;
        let report = trainer.train()?;
        println!("[{}] {}", spec.id, report.summary_line());
        rows.push(ExperimentRow { experiment: spec.id.to_string(), report });
    }
    Ok(rows)
}

/// Serialize experiment rows to `<out>/<experiment>.tsv`.
pub fn write_results(out_dir: &Path, rows: &[ExperimentRow]) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let mut by_exp: Vec<(&str, Vec<&ExperimentRow>)> = Vec::new();
    for r in rows {
        if let Some(e) = by_exp.iter_mut().find(|(id, _)| *id == r.experiment) {
            e.1.push(r);
        } else {
            by_exp.push((&r.experiment, vec![r]));
        }
    }
    for (id, rs) in by_exp {
        let mut tsv = String::from(
            "preset\tsteps\tfinal_loss\teval_loss\taccuracy\tppl\tstep_ms\tthroughput\tparams\n");
        for r in &rs {
            let e = r.report.final_eval.unwrap_or_default();
            tsv.push_str(&format!(
                "{}\t{}\t{:.4}\t{:.4}\t{:.4}\t{:.2}\t{:.2}\t{:.1}\t{}\n",
                r.report.preset, r.report.steps, r.report.final_loss(),
                e.loss, e.accuracy, e.perplexity(),
                r.report.step_time.as_ref().map(|s| s.mean_ms()).unwrap_or(f64::NAN),
                r.report.throughput, r.report.param_count));
        }
        std::fs::write(out_dir.join(format!("{id}.tsv")), &tsv)?;
        // also dump loss curves for EXPERIMENTS.md plots
        for r in &rs {
            std::fs::write(
                out_dir.join(format!("{id}.{}.curve.tsv", r.report.preset)),
                r.report.curve_tsv())?;
        }
    }
    Ok(())
}

/// Run the whole matrix, writing into `out_dir`. `steps_scale` shrinks
/// everything for smoke runs.
pub fn run_all(artifacts: &Path, out_dir: &Path, steps_scale: f64, seed: u64)
               -> Result<PathBuf> {
    let mut rows = Vec::new();
    for spec in matrix(steps_scale) {
        rows.extend(run_experiment(artifacts, &spec, seed)?);
        // checkpoint after every experiment so a late failure loses nothing
        write_results(out_dir, &rows)?;
    }
    // seed sweep sanity: a couple of extra seeds on the headline run
    Ok(out_dir.to_path_buf())
}

/// Multi-seed variant of one experiment for error bars.
pub fn run_seeds(artifacts: &Path, spec: &ExperimentSpec, seeds: &[u64])
                 -> Result<Vec<(u64, Vec<ExperimentRow>)>> {
    let mut out = Vec::new();
    for &seed in seeds {
        out.push((seed, run_experiment(artifacts, spec, seed)?));
    }
    Ok(out)
}

/// Deterministic seeds for sweeps.
pub fn sweep_seeds(n: usize) -> Vec<u64> {
    let mut rng = Rng::new(0xC0FFEE);
    (0..n).map(|_| rng.next_u64() & 0xFFFF).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_is_well_formed() {
        for spec in matrix(1.0) {
            assert!(!spec.presets.is_empty());
            assert!(spec.steps > 0);
            assert!(spec.lr > 0.0);
        }
    }

    #[test]
    fn steps_scale_shrinks() {
        let full = matrix(1.0);
        let tiny = matrix(0.05);
        for (f, t) in full.iter().zip(&tiny) {
            assert!(t.steps <= f.steps);
            assert!(t.steps >= 5);
        }
    }

    #[test]
    fn write_results_emits_tsv() {
        let mut report = TrainReport::default();
        report.preset = "p".into();
        report.loss_curve = vec![(0, 1.0)];
        let rows = vec![ExperimentRow { experiment: "unit".into(), report }];
        let dir = std::env::temp_dir().join(format!("pixelfly_exp_{}", std::process::id()));
        write_results(&dir, &rows).unwrap();
        let tsv = std::fs::read_to_string(dir.join("unit.tsv")).unwrap();
        assert!(tsv.starts_with("preset\t"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_seeds_deterministic() {
        assert_eq!(sweep_seeds(3), sweep_seeds(3));
        assert_eq!(sweep_seeds(3).len(), 3);
    }
}
