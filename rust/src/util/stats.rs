//! Timing summaries for the in-crate bench harness and trainer metrics.

use std::time::{Duration, Instant};

/// Summary statistics over a set of duration samples (nanoseconds).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn from_durations(samples: &[Duration]) -> Self {
        let mut ns: Vec<f64> = samples.iter().map(|d| d.as_nanos() as f64).collect();
        Self::from_ns(&mut ns)
    }

    pub fn from_ns(ns: &mut [f64]) -> Self {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| ns[(((n - 1) as f64) * p) as usize];
        Summary {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            max_ns: ns[n - 1],
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn p50_ms(&self) -> f64 {
        self.p50_ns / 1e6
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms min={:.3}ms",
            self.n,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.min_ns / 1e6
        )
    }
}

/// Time a closure `iters` times after `warmup` runs, returning a Summary.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    Summary::from_durations(&samples)
}

/// Simple stopwatch accumulating named segments (trainer profiling).
#[derive(Default)]
pub struct Stopwatch {
    segments: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.segments.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.segments.push((name.to_string(), d));
        }
    }

    pub fn report(&self) -> String {
        let total: Duration = self.segments.iter().map(|(_, d)| *d).sum();
        let mut s = String::new();
        for (name, d) in &self.segments {
            let pct = if total.as_nanos() > 0 {
                100.0 * d.as_nanos() as f64 / total.as_nanos() as f64
            } else {
                0.0
            };
            s.push_str(&format!("{name}: {:.1}ms ({pct:.0}%)  ", d.as_secs_f64() * 1e3));
        }
        s
    }

    pub fn total(&self) -> Duration {
        self.segments.iter().map(|(_, d)| *d).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles_ordered() {
        let mut ns: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_ns(&mut ns);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p95_ns && s.p95_ns <= s.max_ns);
        assert_eq!(s.n, 100);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn time_it_counts() {
        let mut c = 0;
        let s = time_it(2, 5, || c += 1);
        assert_eq!(c, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.add("a", Duration::from_millis(2));
        sw.add("a", Duration::from_millis(3));
        sw.add("b", Duration::from_millis(5));
        assert_eq!(sw.total(), Duration::from_millis(10));
        assert!(sw.report().contains("a:"));
    }
}
