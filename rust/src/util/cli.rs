//! Minimal CLI argument parsing (offline substitute for clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults keep call sites terse.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (first element NOT skipped).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse process args (skips argv[0]).
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.f64_or(key, default as f64) as f32
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_kv_forms() {
        let a = args(&["train", "--steps", "100", "--lr=0.5", "--quiet"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!((a.f64_or("lr", 0.0) - 0.5).abs() < 1e-12);
        assert!(a.bool("quiet"));
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("model", "mixer"), "mixer");
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args(&["--x", "-3"]);
        assert_eq!(a.str_or("x", ""), "-3");
    }
}
