//! std-only utilities: deterministic PRNG, timing/stats, CLI parsing, and
//! property-test helpers (the offline substitutes for `rand`, `clap` and
//! `proptest` — see DESIGN.md §Substitutions).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

pub use cli::Args;
pub use rng::Rng;
pub use stats::Summary;
