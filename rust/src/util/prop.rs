//! Tiny property-testing harness (offline substitute for proptest).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded RNGs
//! and panics with the failing seed on the first violated property, so
//! failures are reproducible by seed.

use super::rng::Rng;

/// Run `f` for `cases` random cases. `f` returns Err(msg) on violation.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Assert helper producing Result for use inside `check` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 10, |rng| {
            let x = rng.below(10);
            if x < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_reports_failures() {
        check("always-fails", 3, |_rng| Err("boom".into()));
    }
}
