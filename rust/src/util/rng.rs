//! SplitMix64 + xoshiro256** PRNG with the small distribution surface the
//! crate needs (uniform ints, f32/f64, standard normal, choice, shuffle).
//! Deterministic from seed across platforms.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

fn splitmix(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *seed;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [splitmix(&mut sm), splitmix(&mut sm), splitmix(&mut sm), splitmix(&mut sm)],
            spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // rejection-free multiply-shift (Lemire); bias negligible for our use
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * scale).collect()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Zipf-distributed sample over [0, n) with exponent `a` (CDF inversion
    /// over a precomputed table is the caller's job for tight loops; this is
    /// the simple rejection-free harmonic version).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute the Zipf CDF table for `zipf()`.
pub fn zipf_cdf(n: usize, a: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|i| 1.0 / (i as f64).powf(a)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed() {
        let cdf = zipf_cdf(100, 1.2);
        let mut r = Rng::new(4);
        let mut c0 = 0;
        for _ in 0..2000 {
            if r.zipf(&cdf) == 0 {
                c0 += 1;
            }
        }
        assert!(c0 > 200, "head of zipf should dominate, got {c0}");
    }
}
