//! RigL baseline (Evci et al. 2020) — the dynamic-sparsity comparator of
//! Fig 6.
//!
//! RigL keeps a fixed per-layer nonzero budget but periodically *drops*
//! the smallest-magnitude weights and *grows* connections where the dense
//! gradient is largest.  We implement it at block granularity over the
//! Rust BSR substrate (so it can also run block-aligned — the paper's
//! point is that the original unstructured RigL gets no wall-clock
//! speedup; our block cover accounting shows exactly why).
//!
//! The trainer uses this to drive the Fig-6 comparison: the RigL variant's
//! mask changes during training (costing a mask-rebuild each update),
//! while Pixelfly's is static.

use crate::patterns::BlockMask;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RigLConfig {
    /// update every N steps
    pub period: usize,
    /// fraction of connections dropped+regrown per update (cosine-decayed)
    pub alpha: f64,
    /// total steps (for the cosine decay)
    pub total_steps: usize,
}

impl Default for RigLConfig {
    fn default() -> Self {
        RigLConfig { period: 100, alpha: 0.3, total_steps: 10_000 }
    }
}

/// State of one RigL-managed layer: current block mask + fixed budget.
#[derive(Clone, Debug)]
pub struct RigLLayer {
    pub mask: BlockMask,
    pub budget_blocks: usize,
}

impl RigLLayer {
    pub fn new(mask: BlockMask) -> Self {
        let budget_blocks = mask.nnz();
        RigLLayer { mask, budget_blocks }
    }

    /// Per-block L1 magnitude from element weights laid out dense.
    fn block_scores(values: &[f32], rows: usize, cols: usize, b: usize) -> Vec<Vec<f64>> {
        let (nbr, nbc) = (rows / b, cols / b);
        let mut s = vec![vec![0.0f64; nbc]; nbr];
        for r in 0..rows {
            for c in 0..cols {
                s[r / b][c / b] += values[r * cols + c].abs() as f64;
            }
        }
        s
    }

    /// One RigL update: drop the k lowest-|w| active blocks, grow the k
    /// highest-|g| inactive blocks. Returns (dropped, grown).
    pub fn update(&mut self, weights: &[f32], grads: &[f32], rows: usize,
                  cols: usize, step: usize, cfg: &RigLConfig) -> (usize, usize) {
        let b = rows / self.mask.rows;
        let wsc = Self::block_scores(weights, rows, cols, b);
        let gsc = Self::block_scores(grads, rows, cols, b);
        // cosine-decayed update fraction (Evci et al. eq. 1)
        let t = (step as f64 / cfg.total_steps as f64).min(1.0);
        let frac = cfg.alpha / 2.0 * (1.0 + (std::f64::consts::PI * t).cos());
        let k = ((self.budget_blocks as f64) * frac) as usize;
        if k == 0 {
            return (0, 0);
        }
        // candidates
        let mut active: Vec<(f64, usize, usize)> = Vec::new();
        let mut inactive: Vec<(f64, usize, usize)> = Vec::new();
        for i in 0..self.mask.rows {
            for j in 0..self.mask.cols {
                if self.mask.get(i, j) {
                    active.push((wsc[i][j], i, j));
                } else {
                    inactive.push((gsc[i][j], i, j));
                }
            }
        }
        active.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        inactive.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let k = k.min(active.len()).min(inactive.len());
        for (_, i, j) in active.iter().take(k) {
            self.mask.set(*i, *j, false);
        }
        for (_, i, j) in inactive.iter().take(k) {
            self.mask.set(*i, *j, true);
        }
        (k, k)
    }
}

/// Simulated RigL training-run accounting: per-step compute equals the
/// masked GEMM cost, plus a full *dense* gradient pass on update steps
/// (RigL needs dense grads to grow) — this is the mechanism behind Fig 6's
/// "no wall-clock speedup".
pub fn rigl_step_cost(mask: &BlockMask, m: usize, dev: &crate::costmodel::Device,
                      is_update_step: bool) -> f64 {
    // `mask` is at RigL's block granularity; expand to elements so the
    // cost model sees the true matrix dimensions.
    let emask = mask.expand(dev.block);
    let sparse = crate::costmodel::masked_gemm_cost(&emask, m, dev).total;
    if is_update_step {
        sparse + crate::costmodel::dense_gemm_cost(emask.rows, emask.cols, m, dev).total
    } else {
        sparse
    }
}

/// Initialise a RigL layer with a random mask at the given density (ERK
/// initialisation simplified to uniform-random at block level).
pub fn init_random(nbr: usize, nbc: usize, density: f64, seed: u64) -> RigLLayer {
    let mut rng = Rng::new(seed);
    RigLLayer::new(crate::patterns::baselines::random_mask(nbr, nbc, density, &mut rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Device;

    fn fake_weights(rows: usize, cols: usize, hot: (usize, usize), b: usize) -> Vec<f32> {
        let mut w = vec![0.01f32; rows * cols];
        for r in 0..b {
            for c in 0..b {
                w[(hot.0 * b + r) * cols + hot.1 * b + c] = 5.0;
            }
        }
        w
    }

    #[test]
    fn budget_is_conserved() {
        let mut layer = init_random(8, 8, 0.3, 1);
        let before = layer.mask.nnz();
        let w = vec![0.1f32; 64 * 64];
        let g = vec![0.2f32; 64 * 64];
        layer.update(&w, &g, 64, 64, 0, &RigLConfig::default());
        assert_eq!(layer.mask.nnz(), before);
    }

    #[test]
    fn grows_where_gradient_is_large() {
        let mut layer = RigLLayer::new(BlockMask::identity(8));
        let b = 8;
        let w = vec![0.01f32; 64 * 64];
        // gradient hot spot at inactive block (2, 5)
        let g = fake_weights(64, 64, (2, 5), b);
        layer.update(&w, &g, 64, 64, 0, &RigLConfig { alpha: 0.3, ..Default::default() });
        assert!(layer.mask.get(2, 5), "should grow the high-grad block");
    }

    #[test]
    fn drops_smallest_magnitude() {
        // all blocks tiny except (0,0): RigL must keep (0,0)
        let mut layer = RigLLayer::new(BlockMask::identity(8));
        let w = fake_weights(64, 64, (0, 0), 8);
        let g = vec![0.0f32; 64 * 64];
        layer.update(&w, &g, 64, 64, 0, &RigLConfig { alpha: 0.9, ..Default::default() });
        assert!(layer.mask.get(0, 0));
    }

    #[test]
    fn update_fraction_decays() {
        let cfg = RigLConfig { period: 1, alpha: 0.4, total_steps: 100 };
        let mut early = init_random(16, 16, 0.2, 3);
        let mut late = early.clone();
        let w = vec![0.1f32; 128 * 128];
        let g = vec![0.2f32; 128 * 128];
        let (d_early, _) = early.update(&w, &g, 128, 128, 0, &cfg);
        let (d_late, _) = late.update(&w, &g, 128, 128, 95, &cfg);
        assert!(d_early > d_late, "early {d_early} late {d_late}");
    }

    #[test]
    fn rigl_update_steps_cost_dense() {
        let dev = Device::default();
        let layer = init_random(16, 16, 0.1, 4);
        let normal = rigl_step_cost(&layer.mask, 64, &dev, false);
        let update = rigl_step_cost(&layer.mask, 64, &dev, true);
        assert!(update > 2.0 * normal, "dense grad pass dominates update steps");
    }
}
