//! The PJRT engine: compiled-executable cache + typed host<->device I/O.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{ElementType, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Re-exported so callers name the boundary type as `engine::Literal`,
/// keeping them source-compatible with the stub engine (stub.rs).
pub use xla::Literal;

use super::manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

/// A compiled artifact plus its boundary signature.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    pub exe: PjRtLoadedExecutable,
    pub compile_ms: f64,
}

impl LoadedArtifact {
    /// Execute with host literals; returns the flattened output literals
    /// (the XLA root tuple is decomposed).
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        self.check_args(args.len())?;
        let out = self.exe.execute::<Literal>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device buffers (hot path). Output is the root tuple
    /// buffer; call `decompose` on the synced literal to read it, or feed
    /// it back via [`Engine::retuple`]-style splitting.
    pub fn run_buffers(&self, args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.check_args(args.len())?;
        Ok(self.exe.execute_b(args)?)
    }

    fn check_args(&self, n: usize) -> Result<()> {
        if n != self.spec.inputs.len() {
            bail!(
                "artifact {}: got {} args, expected {} ({:?} ...)",
                self.spec.key,
                n,
                self.spec.inputs.len(),
                self.spec.inputs.first().map(|t| &t.name)
            );
        }
        Ok(())
    }
}

/// Engine: one PJRT client + manifest + executable cache.
pub struct Engine {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, LoadedArtifact>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: HashMap::new() })
    }

    /// Compile (or fetch cached) an artifact by manifest key.
    pub fn load(&mut self, key: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(key) {
            let spec = self.manifest.artifact(key)?.clone();
            let path = self.manifest.hlo_path(&spec);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.cache.insert(key.to_string(), LoadedArtifact { spec, exe, compile_ms });
        }
        Ok(&self.cache[key])
    }

    /// Read the initial param leaves serialized by aot.py for `preset`.
    /// Shapes/dtypes come from the first `n_leaves` inputs of `art_key`.
    pub fn load_initial_state(&self, preset: &str, art_key: &str) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(art_key)?;
        let dir = self.manifest.state_dir(preset)?;
        let n = spec.n_param_leaves;
        let mut out = Vec::with_capacity(n);
        for (i, t) in spec.inputs.iter().take(n).enumerate() {
            let path = dir.join(format!("param_{i:04}.bin"));
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {path:?}"))?;
            if bytes.len() != t.bytes() {
                bail!("{path:?}: {} bytes, expected {} for {:?}", bytes.len(), t.bytes(), t);
            }
            out.push(literal_from_bytes(t, &bytes)?);
        }
        Ok(out)
    }

    /// Copy a host literal to the device.
    pub fn to_device(&self, lit: &Literal) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

/// Build a Literal from raw little-endian bytes per the tensor spec.
pub fn literal_from_bytes(t: &TensorSpec, bytes: &[u8]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        t.dtype.element_type(),
        &t.dims,
        bytes,
    )?)
}

/// Build a zero literal for a tensor spec.
pub fn zero_literal(t: &TensorSpec) -> Result<Literal> {
    literal_from_bytes(t, &vec![0u8; t.bytes()])
}

/// f32 tensor literal from a slice (dims must multiply to len).
pub fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

/// i32 tensor literal from a slice.
pub fn i32_literal(dims: &[usize], data: &[i32]) -> Result<Literal> {
    assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)?)
}

/// Scalar literals.
pub fn f32_scalar(v: f32) -> Result<Literal> {
    f32_literal(&[], &[v])
}

pub fn i32_scalar(v: i32) -> Result<Literal> {
    i32_literal(&[], &[v])
}

/// Pull an f32 scalar/tensor out of an output literal.
pub fn literal_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[allow(unused)]
fn dtype_check(t: &TensorSpec, d: Dtype) -> bool {
    t.dtype == d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0];
        let lit = f32_literal(&[2, 2], &data).unwrap();
        assert_eq!(literal_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![5i32, -7, 0];
        let lit = i32_literal(&[3], &data).unwrap();
        assert_eq!(literal_i32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn zero_literal_is_zero() {
        let t = TensorSpec { name: "z".into(), dtype: Dtype::F32, dims: vec![4] };
        let lit = zero_literal(&t).unwrap();
        assert_eq!(literal_f32_vec(&lit).unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn scalars() {
        assert_eq!(literal_f32_vec(&f32_scalar(2.5).unwrap()).unwrap(), vec![2.5]);
        assert_eq!(literal_i32_vec(&i32_scalar(-3).unwrap()).unwrap(), vec![-3]);
    }
}
