//! Stub engine: the default-build (`--no-default-features`-free) stand-in
//! for the PJRT engine in `engine.rs`.
//!
//! Exposes the same module surface — [`Engine`], [`LoadedArtifact`],
//! [`Literal`], and the literal helper functions — so the trainer, CLI,
//! examples and integration tests compile identically with and without
//! the `pjrt` feature.  Host-side literal construction and inspection are
//! fully functional (the trainer's batch plumbing is real); anything that
//! would need a compiled executable fails with a clear, actionable error.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

fn feature_error(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the PJRT engine, but this binary was built without \
         the `pjrt` feature. Rebuild with `cargo build --release --features \
         pjrt` (vendored xla stub) or link the real xla bindings, and run \
         `make artifacts` to produce the HLO artifacts (see DESIGN.md)."
    )
}

/// Host tensor (or tuple): dims + dtype + little-endian element bytes.
#[derive(Clone, Debug)]
pub enum Literal {
    Tensor { dtype: Dtype, dims: Vec<usize>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// Host types storable in a stub literal.
pub trait NativeType: Copy {
    const DTYPE: Dtype;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const DTYPE: Dtype = Dtype::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const DTYPE: Dtype = Dtype::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

impl Literal {
    /// Decode into a host vector (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Tensor { dtype, data, .. } => {
                if *dtype != T::DTYPE {
                    bail!("literal dtype mismatch: stored {dtype:?}");
                }
                Ok(data
                    .chunks_exact(4)
                    .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => bail!("to_vec on a tuple literal"),
        }
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?.first().copied().context("empty literal")
    }

    /// Decompose a tuple literal (a tensor decomposes to itself).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(elems) => Ok(elems),
            t @ Literal::Tensor { .. } => Ok(vec![t]),
        }
    }
}

/// Device buffer stand-in; never constructed.
pub struct StubBuffer {
    _private: (),
}

impl StubBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(feature_error("buffer readback"))
    }
}

/// Executable stand-in; never constructed (Engine::new fails first), but
/// gives the trainer's execute chain something to typecheck against.
pub struct StubExecutable {
    _private: (),
}

impl StubExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<StubBuffer>>> {
        Err(feature_error("artifact execution"))
    }

    pub fn execute_b(&self, _args: &[&StubBuffer]) -> Result<Vec<Vec<StubBuffer>>> {
        Err(feature_error("artifact execution"))
    }
}

/// A compiled artifact plus its boundary signature (stub: never built).
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    pub exe: StubExecutable,
    pub compile_ms: f64,
}

impl LoadedArtifact {
    pub fn run(&self, _args: &[Literal]) -> Result<Vec<Literal>> {
        Err(feature_error("artifact execution"))
    }
}

/// Engine stand-in: construction always fails with an actionable message.
pub struct Engine {
    pub manifest: Manifest,
    _private: (),
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let _ = artifacts_dir;
        Err(feature_error("the PJRT engine"))
    }

    pub fn load(&mut self, _key: &str) -> Result<&LoadedArtifact> {
        Err(feature_error("artifact compilation"))
    }

    pub fn load_initial_state(&self, _preset: &str, _key: &str) -> Result<Vec<Literal>> {
        Err(feature_error("initial-state loading"))
    }
}

/// Build a Literal from raw little-endian bytes per the tensor spec.
pub fn literal_from_bytes(t: &TensorSpec, bytes: &[u8]) -> Result<Literal> {
    if bytes.len() != t.bytes() {
        bail!("literal for {:?} needs {} bytes, got {}", t.name, t.bytes(), bytes.len());
    }
    Ok(Literal::Tensor { dtype: t.dtype, dims: t.dims.clone(), data: bytes.to_vec() })
}

/// Build a zero literal for a tensor spec.
pub fn zero_literal(t: &TensorSpec) -> Result<Literal> {
    literal_from_bytes(t, &vec![0u8; t.bytes()])
}

/// f32 tensor literal from a slice (dims must multiply to len).
pub fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(Literal::Tensor { dtype: Dtype::F32, dims: dims.to_vec(), data: bytes })
}

/// i32 tensor literal from a slice.
pub fn i32_literal(dims: &[usize], data: &[i32]) -> Result<Literal> {
    assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(Literal::Tensor { dtype: Dtype::S32, dims: dims.to_vec(), data: bytes })
}

/// Scalar literals.
pub fn f32_scalar(v: f32) -> Result<Literal> {
    f32_literal(&[], &[v])
}

pub fn i32_scalar(v: i32) -> Result<Literal> {
    i32_literal(&[], &[v])
}

/// Pull an f32 vector out of an output literal.
pub fn literal_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
}

pub fn literal_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0];
        let lit = f32_literal(&[2, 2], &data).unwrap();
        assert_eq!(literal_f32_vec(&lit).unwrap(), data);
        assert!((lit.get_first_element::<f32>().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![5i32, -7, 0];
        let lit = i32_literal(&[3], &data).unwrap();
        assert_eq!(literal_i32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn zero_literal_is_zero() {
        let t = TensorSpec { name: "z".into(), dtype: Dtype::F32, dims: vec![4] };
        let lit = zero_literal(&t).unwrap();
        assert_eq!(literal_f32_vec(&lit).unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let lit = f32_scalar(1.0).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn engine_reports_missing_feature() {
        let err = Engine::new(Path::new("/nowhere")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("artifacts"), "{msg}");
    }

    #[test]
    fn tuple_decomposes() {
        let a = i32_scalar(7).unwrap();
        let t = Literal::Tuple(vec![a.clone(), a]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }
}
