//! PJRT runtime: load AOT artifacts, manage device state, execute.
//!
//! The pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  One compiled executable per artifact,
//! cached for the process lifetime.
//!
//! Hot-path discipline: the trainer keeps all state (params, optimizer
//! moments, step counter) as device-resident `PjRtBuffer`s and runs
//! `execute_b`, so the per-step host traffic is just the input batch and
//! the scalar loss (see `coordinator::trainer`).
//!
//! Feature gate (DESIGN.md "PJRT feature gate"): the real engine
//! (`engine.rs`, over the `xla` crate) compiles only with `--features
//! pjrt`.  The default build substitutes `stub.rs` — an API-compatible
//! pure-Rust engine whose host-side literal plumbing works but whose
//! `Engine::new` returns a clear error — so the trainer, CLI, examples
//! and integration tests compile identically in both modes and tier-1
//! stays green without artifacts or PJRT.

#[cfg(feature = "pjrt")]
#[path = "engine.rs"]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod engine;

pub mod manifest;

pub use engine::{Engine, LoadedArtifact};
pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

use std::path::PathBuf;

/// Default artifacts directory: $PIXELFLY_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PIXELFLY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
