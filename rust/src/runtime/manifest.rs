//! Artifact manifest parsing (`artifacts/manifest.rtxt`).
//!
//! The AOT driver emits a line-based, tab-separated manifest alongside the
//! human-readable JSON (DESIGN.md: no JSON dependency offline).  Format:
//!
//! ```text
//! artifact <key> <file> <entry> <preset> <batch> <n_param_leaves> <param_count> <flops_fwd>
//! in  <name> <dtype> <dims...>
//! out <dtype> <dims...>
//! cfg <field> <value>
//! state <preset> <dir> <n_leaves>
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Element dtype of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "s32" => Dtype::S32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }

    pub fn size(&self) -> usize {
        4
    }

    /// Map to the PJRT boundary dtype (real engine only).
    #[cfg(feature = "pjrt")]
    pub fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::S32 => xla::ElementType::S32,
        }
    }
}

/// Shape + dtype (+ name for inputs) of one boundary tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }
}

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub key: String,
    pub file: String,
    pub entry: String,
    pub preset: String,
    pub batch: usize,
    pub n_param_leaves: usize,
    pub param_count: usize,
    pub flops_fwd: u64,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: HashMap<String, String>,
}

impl ArtifactSpec {
    /// Config field accessor with parse.
    pub fn cfg<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.config.get(key).and_then(|v| v.parse().ok())
    }
}

/// Initial-state record (params serialized at AOT time).
#[derive(Clone, Debug)]
pub struct StateSpec {
    pub preset: String,
    pub dir: String,
    pub n_leaves: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub states: HashMap<String, StateSpec>,
    pub root: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.rtxt");
        let txt = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&txt, dir)
    }

    pub fn parse(txt: &str, root: &Path) -> Result<Self> {
        let mut m = Manifest { root: root.to_path_buf(), ..Default::default() };
        let mut current: Option<String> = None;
        for (lineno, line) in txt.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            let ctx = || format!("manifest line {}: {line:?}", lineno + 1);
            match f[0] {
                "artifact" => {
                    if f.len() != 9 {
                        bail!("{}: expected 9 fields", ctx());
                    }
                    let spec = ArtifactSpec {
                        key: f[1].to_string(),
                        file: f[2].to_string(),
                        entry: f[3].to_string(),
                        preset: f[4].to_string(),
                        batch: f[5].parse().with_context(ctx)?,
                        n_param_leaves: f[6].parse().with_context(ctx)?,
                        param_count: f[7].parse().with_context(ctx)?,
                        flops_fwd: f[8].parse().with_context(ctx)?,
                        inputs: vec![],
                        outputs: vec![],
                        config: HashMap::new(),
                    };
                    current = Some(spec.key.clone());
                    m.artifacts.insert(spec.key.clone(), spec);
                }
                "in" | "out" => {
                    let key = current.as_ref().with_context(ctx)?;
                    let spec = m.artifacts.get_mut(key).unwrap();
                    let (name, dt_idx, dim_idx) = if f[0] == "in" {
                        (f[1].to_string(), 2, 3)
                    } else {
                        (String::new(), 1, 2)
                    };
                    let dims = if f.len() > dim_idx && !f[dim_idx].is_empty() {
                        f[dim_idx]
                            .split_whitespace()
                            .map(|d| d.parse().map_err(|_| anyhow::anyhow!(ctx())))
                            .collect::<Result<Vec<usize>>>()?
                    } else {
                        vec![]
                    };
                    let t = TensorSpec { name, dtype: Dtype::parse(f[dt_idx])?, dims };
                    if f[0] == "in" {
                        spec.inputs.push(t);
                    } else {
                        spec.outputs.push(t);
                    }
                }
                "cfg" => {
                    let key = current.as_ref().with_context(ctx)?;
                    let spec = m.artifacts.get_mut(key).unwrap();
                    spec.config.insert(f[1].to_string(), f.get(2).unwrap_or(&"").to_string());
                }
                "state" => {
                    m.states.insert(
                        f[1].to_string(),
                        StateSpec {
                            preset: f[1].to_string(),
                            dir: f[2].to_string(),
                            n_leaves: f[3].parse().with_context(ctx)?,
                        },
                    );
                }
                other => bail!("{}: unknown record {other:?}", ctx()),
            }
        }
        Ok(m)
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(key).with_context(|| {
            let mut keys: Vec<_> = self.artifacts.keys().cloned().collect();
            keys.sort();
            format!("artifact {key:?} not in manifest; available: {keys:?}")
        })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.root.join(&spec.file)
    }

    pub fn state_dir(&self, preset: &str) -> Result<PathBuf> {
        let s = self
            .states
            .get(preset)
            .with_context(|| format!("no state for preset {preset:?}"))?;
        Ok(self.root.join(&s.dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "artifact\tm.train_step\tm.train_step.hlo.txt\ttrain_step\tm\t8\t2\t100\t999\n\
in\tp/w\tf32\t4 4\n\
in\tp/b\tf32\t4\n\
in\tstep\ts32\t\n\
out\tf32\t\n\
out\tf32\t4 4\n\
cfg\tfamily\tmixer\n\
cfg\tblock\t8\n\
state\tm\tstate/m\t2\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let a = m.artifact("m.train_step").unwrap();
        assert_eq!(a.batch, 8);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].dims, vec![4, 4]);
        assert_eq!(a.inputs[2].dims, Vec::<usize>::new());
        assert_eq!(a.inputs[2].dtype, Dtype::S32);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.cfg::<usize>("block"), Some(8));
        assert_eq!(a.config["family"], "mixer");
        assert_eq!(m.states["m"].n_leaves, 2);
    }

    #[test]
    fn scalar_tensor_bytes() {
        let t = TensorSpec { name: "s".into(), dtype: Dtype::F32, dims: vec![] };
        assert_eq!(t.elements(), 1);
        assert_eq!(t.bytes(), 4);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.rtxt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in m.artifacts.values() {
                assert!(!a.inputs.is_empty(), "{} has no inputs", a.key);
                assert!(!a.outputs.is_empty(), "{} has no outputs", a.key);
            }
        }
    }
}
