//! `PXD1` — the distributed-training wire protocol.
//!
//! Framed like serving's `PXF1`: 4-byte magic, a kind byte, a u32
//! length, the payload, then a CRC32 over kind+length+payload (same
//! polynomial table as the PXCK checkpoint format). Every receive path
//! verifies the CRC before parsing, bounds the payload, and surfaces a
//! typed [`ProtoError`] — a garbled or truncated frame can never panic
//! or be half-applied.
//!
//! Gradient/parameter vectors travel as a stream of [`Msg::Chunk`]
//! frames (bounded at [`CHUNK_ELEMS`] f32 each) terminated by one
//! [`Msg::End`], so no single frame ever needs an unbounded buffer and
//! a corrupt chunk costs one round-trip ([`Msg::Resend`]), not the run.

use std::io::{self, Read, Write};

use crate::ckpt::crc32;

pub const MAGIC: &[u8; 4] = b"PXD1";
pub const PROTO_VERSION: u32 = 1;

/// f32 elements per chunk frame (256 KiB of payload).
pub const CHUNK_ELEMS: usize = 1 << 16;
/// Largest accepted frame payload: a full chunk plus its header fields,
/// rounded up. Anything larger is rejected before allocation.
pub const MAX_PAYLOAD: usize = CHUNK_ELEMS * 4 + 64;

/// Chunked vector streams multiplexed over one connection.
pub const STREAM_CONTRIB: u8 = 0; // worker → coordinator, per-round gradients/weights
pub const STREAM_RESULT: u8 = 1; // coordinator → worker, averaged result
pub const STREAM_PARAMS_UP: u8 = 2; // donor worker → coordinator, full param state
pub const STREAM_PARAMS_DOWN: u8 = 3; // coordinator → replacement worker

/// Aggregation mode, carried in [`Msg::Welcome`].
pub const MODE_GRAD: u8 = 0;
pub const MODE_FEDAVG: u8 = 1;

#[derive(Debug)]
pub enum ProtoError {
    Io(io::Error),
    /// clean EOF on a frame boundary (peer closed)
    Eof,
    BadMagic([u8; 4]),
    BadCrc { kind: u8 },
    BadKind(u8),
    /// payload shorter than its kind's fixed fields claim
    Truncated { kind: u8 },
    TooLarge { len: usize },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "i/o error: {e}"),
            ProtoError::Eof => write!(f, "connection closed"),
            ProtoError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            ProtoError::BadCrc { kind } => write!(f, "crc mismatch on frame kind {kind}"),
            ProtoError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            ProtoError::Truncated { kind } => {
                write!(f, "truncated payload for frame kind {kind}")
            }
            ProtoError::TooLarge { len } => {
                write!(f, "frame payload {len} exceeds bound {MAX_PAYLOAD}")
            }
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ProtoError {
    fn from(e: io::Error) -> Self {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            ProtoError::Eof
        } else {
            ProtoError::Io(e)
        }
    }
}

/// True for the read-timeout errno family (the poll idiom `PXF1` uses:
/// timeouts are a tick, not a failure).
pub fn is_timeout(e: &ProtoError) -> bool {
    matches!(e, ProtoError::Io(io) if io.kind() == io::ErrorKind::WouldBlock
                                      || io.kind() == io::ErrorKind::TimedOut)
}

#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker → coordinator on connect: prove protocol + model identity
    Hello {
        proto_version: u32,
        /// `Model::state_fingerprint` — same gate a checkpoint load uses
        fingerprint: u64,
        grads_len: u64,
        params_len: u64,
        /// step counter the worker warm-started from (0 = fresh)
        start_step: u64,
    },
    /// coordinator → worker: admission + the run's shared hyperparams
    Welcome {
        rank: u32,
        nranks: u32,
        /// first round this worker contributes to (>0 for replacements)
        first_round: u64,
        total_rounds: u64,
        mode: u8,
        sync_every: u32,
        lr: f32,
        momentum: f32,
        data_seed: u64,
    },
    /// coordinator → worker: not admitted now, retry after a backoff
    Retry { backoff_ms: u32 },
    /// one slice of a chunked vector stream
    Chunk { stream: u8, round: u64, offset: u64, data: Vec<f32> },
    /// stream terminator; `loss`/`contributors` ride on RESULT and
    /// CONTRIB ends (zeroed elsewhere); for params streams `round` is
    /// the step stamp of the uploaded state
    End { stream: u8, round: u64, loss: f64, contributors: u32 },
    /// coordinator → donor worker: upload your full param state
    ParamsRequest,
    /// receiver → sender: a stream arrived incomplete, send it again
    Resend { round: u64 },
    /// worker → coordinator liveness signal between contributions
    Heartbeat,
    /// fatal, human-readable refusal (fingerprint mismatch, …)
    Error { msg: String },
}

impl Msg {
    pub fn kind(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Welcome { .. } => 2,
            Msg::Retry { .. } => 3,
            Msg::Chunk { .. } => 4,
            Msg::End { .. } => 5,
            Msg::ParamsRequest => 6,
            Msg::Resend { .. } => 7,
            Msg::Heartbeat => 8,
            Msg::Error { .. } => 9,
        }
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// A bounds-checked little-endian payload reader; every shortage is a
/// typed `Truncated`, never a slice panic.
///
/// Unwrap audit (the dist wire path must never panic on peer bytes): the
/// `try_into().unwrap()` in each fixed-width reader below is unreachable
/// by construction — `bytes(n)` either returns exactly `n` bytes or a
/// typed `Truncated` first, and `<[u8; N]>::try_from` on an `N`-byte
/// slice is infallible. They are conversions of a length the previous
/// line just proved, not assumptions about peer input, so they stay.
struct Take<'a> {
    buf: &'a [u8],
    pos: usize,
    kind: u8,
}

impl<'a> Take<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.pos + n > self.buf.len() {
            return Err(ProtoError::Truncated { kind: self.kind });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, ProtoError> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

fn encode_payload(msg: &Msg, buf: &mut Vec<u8>) {
    match msg {
        Msg::Hello { proto_version, fingerprint, grads_len, params_len, start_step } => {
            push_u32(buf, *proto_version);
            push_u64(buf, *fingerprint);
            push_u64(buf, *grads_len);
            push_u64(buf, *params_len);
            push_u64(buf, *start_step);
        }
        Msg::Welcome { rank, nranks, first_round, total_rounds, mode, sync_every,
                       lr, momentum, data_seed } => {
            push_u32(buf, *rank);
            push_u32(buf, *nranks);
            push_u64(buf, *first_round);
            push_u64(buf, *total_rounds);
            buf.push(*mode);
            push_u32(buf, *sync_every);
            push_u32(buf, lr.to_bits());
            push_u32(buf, momentum.to_bits());
            push_u64(buf, *data_seed);
        }
        Msg::Retry { backoff_ms } => push_u32(buf, *backoff_ms),
        Msg::Chunk { stream, round, offset, data } => {
            buf.push(*stream);
            push_u64(buf, *round);
            push_u64(buf, *offset);
            push_u32(buf, data.len() as u32);
            for v in data {
                push_u32(buf, v.to_bits());
            }
        }
        Msg::End { stream, round, loss, contributors } => {
            buf.push(*stream);
            push_u64(buf, *round);
            push_u64(buf, loss.to_bits());
            push_u32(buf, *contributors);
        }
        Msg::ParamsRequest | Msg::Heartbeat => {}
        Msg::Resend { round } => push_u64(buf, *round),
        Msg::Error { msg } => buf.extend_from_slice(msg.as_bytes()),
    }
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Msg, ProtoError> {
    let mut t = Take { buf: payload, pos: 0, kind };
    Ok(match kind {
        1 => Msg::Hello {
            proto_version: t.u32()?,
            fingerprint: t.u64()?,
            grads_len: t.u64()?,
            params_len: t.u64()?,
            start_step: t.u64()?,
        },
        2 => Msg::Welcome {
            rank: t.u32()?,
            nranks: t.u32()?,
            first_round: t.u64()?,
            total_rounds: t.u64()?,
            mode: t.u8()?,
            sync_every: t.u32()?,
            lr: f32::from_bits(t.u32()?),
            momentum: f32::from_bits(t.u32()?),
            data_seed: t.u64()?,
        },
        3 => Msg::Retry { backoff_ms: t.u32()? },
        4 => {
            let stream = t.u8()?;
            let round = t.u64()?;
            let offset = t.u64()?;
            let n = t.u32()? as usize;
            if n > CHUNK_ELEMS {
                return Err(ProtoError::TooLarge { len: n * 4 });
            }
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(t.f32()?);
            }
            Msg::Chunk { stream, round, offset, data }
        }
        5 => Msg::End {
            stream: t.u8()?,
            round: t.u64()?,
            loss: t.f64()?,
            contributors: t.u32()?,
        },
        6 => Msg::ParamsRequest,
        7 => Msg::Resend { round: t.u64()? },
        8 => Msg::Heartbeat,
        9 => Msg::Error { msg: String::from_utf8_lossy(payload).into_owned() },
        k => return Err(ProtoError::BadKind(k)),
    })
}

/// Write one frame: magic, then kind+len+payload, then the CRC of those
/// three (the magic is framing, not content).
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<(), ProtoError> {
    let mut payload = Vec::new();
    encode_payload(msg, &mut payload);
    debug_assert!(payload.len() <= MAX_PAYLOAD, "oversized frame built locally");
    let mut body = Vec::with_capacity(5 + payload.len());
    body.push(msg.kind());
    push_u32(&mut body, payload.len() as u32);
    body.extend_from_slice(&payload);
    let crc = crc32(&body);
    w.write_all(MAGIC)?;
    w.write_all(&body)?;
    w.write_all(&crc.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

fn read_exact(r: &mut impl Read, buf: &mut [u8]) -> Result<(), ProtoError> {
    r.read_exact(buf).map_err(ProtoError::from)
}

/// Read one frame. `garble` flips one payload bit BEFORE the CRC check —
/// the fault-injection hook proving the CRC layer catches wire
/// corruption ([`crate::dist::faults`] drives it).
pub fn read_msg_garbled(r: &mut impl Read, garble: bool) -> Result<Msg, ProtoError> {
    let mut magic = [0u8; 4];
    read_exact(r, &mut magic)?;
    if &magic != MAGIC {
        return Err(ProtoError::BadMagic(magic));
    }
    let mut head = [0u8; 5];
    read_exact(r, &mut head)?;
    let kind = head[0];
    let len = u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(ProtoError::TooLarge { len });
    }
    let mut body = vec![0u8; 5 + len];
    body[..5].copy_from_slice(&head);
    read_exact(r, &mut body[5..])?;
    let mut crc_bytes = [0u8; 4];
    read_exact(r, &mut crc_bytes)?;
    if garble && len > 0 {
        body[5] ^= 0x10; // first payload byte, one bit
    }
    if crc32(&body) != u32::from_le_bytes(crc_bytes) {
        return Err(ProtoError::BadCrc { kind });
    }
    decode_payload(kind, &body[5..])
}

pub fn read_msg(r: &mut impl Read) -> Result<Msg, ProtoError> {
    read_msg_garbled(r, false)
}

/// Read one frame off a socket whose read timeout is used as a POLL
/// TICK: if no byte of a new frame arrives within the socket timeout,
/// the timeout surfaces (so the caller's loop can check liveness /
/// shutdown), but once the first byte lands, short reads retry until
/// `patience` runs out — a tick can therefore never split a frame and
/// desync the stream. Exhausted patience mid-frame IS desync, so it
/// surfaces as a non-timeout `Io` error (treat the peer as dead).
pub fn read_frame_socket(conn: &std::net::TcpStream, garble: bool,
                         patience: std::time::Duration)
                         -> Result<Msg, ProtoError> {
    use std::time::Instant;
    struct Patient<'a> {
        conn: &'a std::net::TcpStream,
        deadline: Option<Instant>,
        patience: std::time::Duration,
    }
    impl Read for Patient<'_> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            loop {
                match Read::read(&mut self.conn, buf) {
                    Ok(0) => return Ok(0),
                    Ok(n) => {
                        if self.deadline.is_none() {
                            self.deadline = Some(Instant::now() + self.patience);
                        }
                        return Ok(n);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock
                              || e.kind() == io::ErrorKind::TimedOut => {
                        match self.deadline {
                            // nothing consumed yet: surface the tick
                            None => return Err(e),
                            Some(d) if Instant::now() > d => {
                                return Err(io::Error::new(
                                    io::ErrorKind::Other,
                                    "peer stalled mid-frame (stream desynced)",
                                ));
                            }
                            Some(_) => {} // mid-frame: keep waiting
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
            }
        }
    }
    let mut p = Patient { conn, deadline: None, patience };
    read_msg_garbled(&mut p, garble)
}

/// Send one sub-range of a flat f32 vector as chunks addressed at
/// absolute offset `offset`, WITHOUT an `End` frame — the bucket-granular
/// half of [`send_flat`] that overlapped dist workers use to stream each
/// layer's gradient slice as it lands. No wire change: the receiver's
/// [`Assembly::absorb`] is already offset-addressed and order-blind, so a
/// contribution sent as N bucket calls (any order) + one `End` is
/// indistinguishable from one `send_flat`.
pub fn send_range(w: &mut impl Write, stream: u8, round: u64, offset: usize,
                  data: &[f32]) -> Result<(), ProtoError> {
    let mut off = 0usize;
    while off < data.len() {
        let n = CHUNK_ELEMS.min(data.len() - off);
        write_msg(w, &Msg::Chunk {
            stream,
            round,
            offset: (offset + off) as u64,
            data: data[off..off + n].to_vec(),
        })?;
        off += n;
    }
    Ok(())
}

/// Send a flat f32 vector as a chunked stream + its `End` frame.
pub fn send_flat(w: &mut impl Write, stream: u8, round: u64, data: &[f32],
                 loss: f64, contributors: u32) -> Result<(), ProtoError> {
    send_range(w, stream, round, 0, data)?;
    write_msg(w, &Msg::End { stream, round, loss, contributors })
}

/// Reassembly buffer for one chunked stream: fixed length, received
/// element count for completeness (TCP never duplicates in-order bytes,
/// and every resend restarts the count via [`Assembly::reset`]).
pub struct Assembly {
    pub buf: Vec<f32>,
    received: usize,
}

impl Assembly {
    pub fn new(len: usize) -> Self {
        Assembly { buf: vec![0.0; len], received: 0 }
    }

    pub fn reset(&mut self) {
        self.received = 0;
    }

    /// Absorb one chunk; false = out-of-bounds (corrupt offset survived
    /// no-CRC odds, or peer speaks a different layout) — drop the frame.
    pub fn absorb(&mut self, offset: u64, data: &[f32]) -> bool {
        let off = offset as usize;
        if off.checked_add(data.len()).map_or(true, |end| end > self.buf.len()) {
            return false;
        }
        self.buf[off..off + data.len()].copy_from_slice(data);
        self.received += data.len();
        true
    }

    pub fn complete(&self) -> bool {
        self.received >= self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let got = read_msg(&mut &buf[..]).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn every_message_kind_roundtrips() {
        roundtrip(Msg::Hello { proto_version: 1, fingerprint: 0xDEAD_BEEF,
                               grads_len: 10, params_len: 20, start_step: 3 });
        roundtrip(Msg::Welcome { rank: 1, nranks: 4, first_round: 7,
                                 total_rounds: 100, mode: MODE_FEDAVG,
                                 sync_every: 5, lr: 1e-2, momentum: 0.9,
                                 data_seed: 42 });
        roundtrip(Msg::Retry { backoff_ms: 250 });
        roundtrip(Msg::Chunk { stream: STREAM_CONTRIB, round: 9, offset: 128,
                               data: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE] });
        roundtrip(Msg::End { stream: STREAM_RESULT, round: 9, loss: 0.125,
                             contributors: 3 });
        roundtrip(Msg::ParamsRequest);
        roundtrip(Msg::Resend { round: 4 });
        roundtrip(Msg::Heartbeat);
        roundtrip(Msg::Error { msg: "fingerprint mismatch".into() });
    }

    #[test]
    fn garbled_frame_is_a_typed_crc_error() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Resend { round: 11 }).unwrap();
        let err = read_msg_garbled(&mut &buf[..], true).unwrap_err();
        assert!(matches!(err, ProtoError::BadCrc { kind: 7 }), "{err}");
    }

    #[test]
    fn corrupt_bytes_never_panic() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Chunk { stream: 0, round: 1, offset: 0,
                                          data: vec![1.0; 16] }).unwrap();
        // bad magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(read_msg(&mut &bad[..]), Err(ProtoError::BadMagic(_))));
        // every truncation point is Eof, not a panic
        for cut in 0..buf.len() {
            let err = read_msg(&mut &buf[..cut]).unwrap_err();
            assert!(matches!(err, ProtoError::Eof | ProtoError::BadMagic(_)),
                    "cut {cut}: {err}");
        }
        // oversized length field is rejected before allocation
        let mut huge = buf.clone();
        huge[5..9].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(read_msg(&mut &huge[..]), Err(ProtoError::TooLarge { .. })));
    }

    #[test]
    fn truncated_payload_is_typed() {
        // a Resend frame whose payload claims 8 bytes but carries 2:
        // rebuild the frame by hand with a valid CRC
        let mut body = vec![7u8]; // kind
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(&[1, 2]);
        let crc = crc32(&body);
        let mut buf = MAGIC.to_vec();
        buf.extend_from_slice(&body);
        buf.extend_from_slice(&crc.to_le_bytes());
        let err = read_msg(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, ProtoError::Truncated { kind: 7 }), "{err}");
    }

    #[test]
    fn socket_reader_survives_mid_frame_timeouts() {
        use std::net::{TcpListener, TcpStream};
        use std::time::{Duration, Instant};
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.set_nodelay(true).unwrap();
            let mut buf = Vec::new();
            write_msg(&mut buf, &Msg::Resend { round: 3 }).unwrap();
            // dribble the frame byte by byte, slower than the reader's
            // 2ms tick, so many timeouts fire mid-frame
            for b in buf {
                c.write_all(&[b]).unwrap();
                c.flush().unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
            c
        });
        let (conn, _) = l.accept().unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(2))).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        let msg = loop {
            match read_frame_socket(&conn, false, Duration::from_secs(10)) {
                Ok(m) => break m,
                Err(e) if is_timeout(&e) => {
                    assert!(Instant::now() < deadline, "no frame within 30s");
                }
                Err(e) => panic!("fatal read error: {e}"),
            }
        };
        assert_eq!(msg, Msg::Resend { round: 3 });
        let _ = writer.join();
    }

    #[test]
    fn send_flat_chunks_and_reassembles() {
        let data: Vec<f32> = (0..(CHUNK_ELEMS + 100)).map(|i| i as f32).collect();
        let mut buf = Vec::new();
        send_flat(&mut buf, STREAM_CONTRIB, 2, &data, 0.5, 1).unwrap();
        let mut asm = Assembly::new(data.len());
        let mut r = &buf[..];
        loop {
            match read_msg(&mut r).unwrap() {
                Msg::Chunk { stream, round, offset, data } => {
                    assert_eq!((stream, round), (STREAM_CONTRIB, 2));
                    assert!(asm.absorb(offset, &data));
                }
                Msg::End { round, loss, contributors, .. } => {
                    assert_eq!((round, contributors), (2, 1));
                    assert_eq!(loss, 0.5);
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(asm.complete());
        assert_eq!(asm.buf, data);
        // an out-of-bounds chunk offset is dropped, not a panic
        assert!(!asm.absorb(u64::MAX, &[1.0]));
    }
}
