//! The worker side of the fleet: connect-with-backoff, per-round
//! contribution compute, result application, and the donor/replacement
//! halves of elastic recovery.
//!
//! A worker is a plain synchronous loop — one socket, one thread. All
//! waiting goes through [`proto::read_frame_socket`] with a short
//! socket timeout as a poll tick, so every wait is bounded and every
//! exit is a typed [`DistError`]: the fault suite's "zero hangs, zero
//! panics" guarantee is enforced here, not hoped for.
//!
//! Faults ([`super::faults`]) are injected at the three chokepoints:
//! `kill-conn@K` drops the socket before round K's compute,
//! `stall@K` sleeps past the coordinator's deadline, and
//! `garble-frame@K` flips a bit in the next received frame of round K
//! (consumed by the first actual frame, so a poll tick can't waste it).

use std::net::{Shutdown, TcpStream};
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use crate::ckpt::Snapshotter;
use crate::data::shard::{shard_batch, ShardSpec, ShardStream};
use crate::nn::{GradSink, Model, TrainTensors};
use crate::sparse::exec;

use super::faults::{self, Kind};
use super::proto::{self, is_timeout, read_msg, send_flat, write_msg, Assembly, Msg,
                   ProtoError};
use super::{DistError, Mode, SnapshotCfg};

/// How often a waiting worker nudges the coordinator with a `Resend`
/// for the stream it is missing (recovers a garbled `End` frame, and
/// doubles as a liveness signal while parked at the barrier).
const NUDGE_EVERY: Duration = Duration::from_millis(300);
/// Mid-frame patience for [`proto::read_frame_socket`].
const FRAME_PATIENCE: Duration = Duration::from_secs(10);

#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// coordinator `host:port`
    pub addr: String,
    /// unique worker tag: names the thread, scopes injected faults
    pub tag: String,
    /// checkpoint file or directory to warm-start from before joining
    pub warm_start: Option<PathBuf>,
    /// background PXCK snapshotting (honored on rank 0 only)
    pub snapshot: Option<SnapshotCfg>,
    pub connect_attempts: u32,
    pub handshake_timeout: Duration,
    /// how long to wait for a round's result before declaring the
    /// coordinator lost
    pub result_wait: Duration,
    /// how long an injected `stall@K` sleeps
    pub stall: Duration,
    /// shard prefetch depth (grad mode)
    pub prefetch: usize,
}

impl WorkerConfig {
    pub fn new(addr: &str, tag: &str) -> Self {
        WorkerConfig {
            addr: addr.to_string(),
            tag: tag.to_string(),
            warm_start: None,
            snapshot: None,
            connect_attempts: 60,
            handshake_timeout: Duration::from_secs(10),
            result_wait: Duration::from_secs(20),
            stall: Duration::from_secs(1),
            prefetch: 2,
        }
    }
}

#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub rank: u32,
    /// fleet-averaged loss per round this worker applied (a replacement
    /// starts at its catch-up round, not round 0)
    pub losses: Vec<f64>,
    /// PXCK snapshots offered (rank 0 with snapshotting on)
    pub snapshots: u64,
    /// mean per-round contribution-upload time NOT hidden behind
    /// backward compute, ms. With `PIXELFLY_OVERLAP=dw+comm` buckets
    /// stream during backward and only the tail past the last dW is
    /// exposed; otherwise the whole post-backward send is.
    pub comm_exposed_ms: f64,
}

/// Unblocks a parked bucket sender if the backward pass aborts — drops
/// on both the normal and unwind exits of the overlapped compute block.
struct FinishGuard<'a>(&'a GradSink);

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        self.0.finish();
    }
}

/// The run parameters `Welcome` carried back, decoded.
struct Admission {
    rank: u32,
    nranks: u32,
    first_round: u64,
    total_rounds: u64,
    mode: Mode,
    sync_every: u32,
    lr: f32,
    momentum: f32,
    data_seed: u64,
}

fn lost(e: ProtoError, what: &str) -> DistError {
    match e {
        ProtoError::Io(_) | ProtoError::Eof => {
            DistError::CoordinatorLost(format!("{what}: {e}"))
        }
        other => DistError::Proto(other),
    }
}

/// Connect + `Hello`/`Welcome` handshake with retry and exponential
/// backoff; a `Retry` (fleet full, or a replacement already syncing)
/// waits the coordinator's suggested backoff and tries again.
fn connect(cfg: &WorkerConfig, model: &mut Model, start_step: u64)
           -> Result<(TcpStream, Admission), DistError> {
    let mut backoff = Duration::from_millis(50);
    let mut last_err = String::from("never reached the coordinator");
    let attempts = cfg.connect_attempts.max(1);
    for _ in 0..attempts {
        let conn = match TcpStream::connect(&cfg.addr) {
            Ok(c) => c,
            Err(e) => {
                last_err = format!("connect: {e}");
                thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
                continue;
            }
        };
        let _ = conn.set_nodelay(true);
        let _ = conn.set_read_timeout(Some(cfg.handshake_timeout));
        let _ = conn.set_write_timeout(Some(Duration::from_secs(5)));
        let hello = Msg::Hello {
            proto_version: proto::PROTO_VERSION,
            fingerprint: model.state_fingerprint(),
            grads_len: model.train_flat_len(TrainTensors::Grads) as u64,
            params_len: model.train_flat_len(TrainTensors::Params) as u64,
            start_step,
        };
        if let Err(e) = write_msg(&mut &conn, &hello) {
            last_err = format!("sending hello: {e}");
            thread::sleep(backoff);
            backoff = (backoff * 2).min(Duration::from_secs(1));
            continue;
        }
        match read_msg(&mut &conn) {
            Ok(Msg::Welcome { rank, nranks, first_round, total_rounds, mode,
                              sync_every, lr, momentum, data_seed }) => {
                let mode = Mode::from_wire(mode).ok_or_else(|| {
                    DistError::Handshake(format!("coordinator sent unknown mode {mode}"))
                })?;
                return Ok((conn, Admission {
                    rank,
                    nranks,
                    first_round,
                    total_rounds,
                    mode,
                    sync_every: sync_every.max(1),
                    lr,
                    momentum,
                    data_seed,
                }));
            }
            Ok(Msg::Retry { backoff_ms }) => {
                let _ = conn.shutdown(Shutdown::Both);
                last_err = "fleet full, told to retry".to_string();
                thread::sleep(Duration::from_millis(u64::from(backoff_ms.max(10))));
            }
            Ok(Msg::Error { msg }) => return Err(DistError::Handshake(msg)),
            Ok(other) => {
                last_err = format!("unexpected frame kind {} during handshake",
                                   other.kind());
                let _ = conn.shutdown(Shutdown::Both);
                thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
            Err(e) => {
                last_err = format!("reading welcome: {e}");
                let _ = conn.shutdown(Shutdown::Both);
                thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
        }
    }
    Err(DistError::Handshake(format!(
        "could not join {} after {attempts} attempts (last: {last_err})", cfg.addr
    )))
}

struct RoundResult {
    data: Vec<f32>,
    loss: f64,
}

/// Wait for one complete stream from the coordinator, servicing its
/// requests while parked: donor params uploads (`ParamsRequest`),
/// contribution resends, and recovery nudges when frames were lost to
/// corruption. Bounded by `cfg.result_wait`; every exit is typed.
fn recv_stream(conn: &TcpStream, cfg: &WorkerConfig, stream: u8, round: u64,
               rlen: usize, resend: Option<(&[f32], f64)>, model: &mut Model)
               -> Result<RoundResult, DistError> {
    let mut asm = Assembly::new(rlen);
    let deadline = Instant::now() + cfg.result_wait;
    let mut next_nudge = Instant::now() + NUDGE_EVERY;
    let mut params: Vec<f32> = Vec::new();
    // one-shot: garble the next frame of this round if so armed
    let mut garble = faults::take(Kind::GarbleFrame, round, &cfg.tag);
    loop {
        if Instant::now() > deadline {
            return Err(DistError::CoordinatorLost(format!(
                "no stream {stream} for round {round} within {:?}", cfg.result_wait
            )));
        }
        let msg = match proto::read_frame_socket(conn, garble, FRAME_PATIENCE) {
            Err(e) if is_timeout(&e) => {
                // no frame consumed: an armed garble stays armed
                if Instant::now() > next_nudge {
                    write_msg(&mut &*conn, &Msg::Resend { round })
                        .map_err(|e| lost(e, "nudging coordinator"))?;
                    next_nudge = Instant::now() + NUDGE_EVERY;
                    // a resend restarts the stream from scratch
                    asm.reset();
                }
                continue;
            }
            Err(ProtoError::BadCrc { .. }) | Err(ProtoError::BadKind(_))
            | Err(ProtoError::Truncated { .. }) | Err(ProtoError::TooLarge { .. }) => {
                // a frame was consumed (and rejected): the garble fired
                garble = false;
                continue;
            }
            Err(e) => {
                return Err(DistError::CoordinatorLost(format!(
                    "reading stream {stream} for round {round}: {e}"
                )));
            }
            Ok(m) => {
                garble = false;
                m
            }
        };
        match msg {
            Msg::Chunk { stream: s, round: r, offset, data }
                if s == stream && r == round =>
            {
                let _ = asm.absorb(offset, &data);
            }
            Msg::End { stream: s, round: r, loss, .. } if s == stream && r == round => {
                if asm.complete() {
                    return Ok(RoundResult { data: std::mem::take(&mut asm.buf), loss });
                }
                // lost chunks (garble, corruption): ask for the stream again
                write_msg(&mut &*conn, &Msg::Resend { round })
                    .map_err(|e| lost(e, "requesting stream resend"))?;
                asm = Assembly::new(rlen);
                next_nudge = Instant::now() + NUDGE_EVERY;
            }
            Msg::ParamsRequest => {
                // this rank is the donor for a replacement: upload the
                // full param view, stamped with the round we're parked
                // at (= the round whose result we have not yet applied)
                model.read_train_flat(TrainTensors::Params, &mut params);
                send_flat(&mut &*conn, proto::STREAM_PARAMS_UP, round, &params, 0.0, 0)
                    .map_err(|e| lost(e, "uploading donor params"))?;
            }
            Msg::Resend { round: r } => {
                if let Some((data, loss)) = resend {
                    if r == round {
                        send_flat(&mut &*conn, proto::STREAM_CONTRIB, round, data,
                                  loss, 1)
                            .map_err(|e| lost(e, "resending contribution"))?;
                    }
                }
            }
            Msg::Error { msg } => return Err(DistError::CoordinatorLost(msg)),
            // stale chunks from a superseded round, heartbeat echoes, …
            _ => {}
        }
    }
}

fn apply_result(model: &mut Model, mode: Mode, lr: f32, momentum: f32,
                result: &RoundResult) {
    match mode {
        Mode::Grad => {
            model.write_train_flat(TrainTensors::Grads, &result.data);
            model.apply_update(lr, momentum);
        }
        Mode::Fedavg => {
            model.write_train_flat(TrainTensors::Params, &result.data);
        }
    }
}

/// Join the fleet at `cfg.addr` and train to completion. Blocks; one
/// call per worker process (or thread, via [`super::run_local`]).
pub fn run(mut model: Model, cfg: WorkerConfig) -> Result<WorkerReport, DistError> {
    // warm start before the handshake so Hello carries the right step
    let mut start_step = 0u64;
    if let Some(from) = &cfg.warm_start {
        start_step = model.load_weights(from)?.step;
    }
    let (conn, adm) = connect(&cfg, &mut model, start_step)?;
    let _ = conn.set_read_timeout(Some(Duration::from_millis(100)));

    let (rows, din, dout) = (model.seq, model.in_dim(), model.out_dim());
    let glen = model.train_flat_len(TrainTensors::Grads);
    let plen = model.train_flat_len(TrainTensors::Params);
    let rlen = match adm.mode {
        Mode::Grad => glen,
        Mode::Fedavg => plen,
    };
    let spr = match adm.mode {
        Mode::Grad => 1u64,
        Mode::Fedavg => u64::from(adm.sync_every),
    };
    let spec = ShardSpec { rank: adm.rank, nranks: adm.nranks, seed: adm.data_seed };
    let snap = match (&cfg.snapshot, adm.rank) {
        (Some(sc), 0) => Some((Snapshotter::start(&sc.dir, sc.retain)?, sc.every)),
        _ => None,
    };

    let mut losses: Vec<f64> = Vec::new();
    let mut snapshots = 0u64;

    // replacement catch-up: receive the donor's param view (stamped
    // first_round - 1, i.e. the state every rank held entering that
    // round), then that round's result, exactly as a rank that had
    // been here all along would apply them
    if adm.first_round > 0 {
        let stamp = adm.first_round - 1;
        let params = recv_stream(&conn, &cfg, proto::STREAM_PARAMS_DOWN, stamp, plen,
                                 None, &mut model)?;
        model.write_train_flat(TrainTensors::Params, &params.data);
        let result = recv_stream(&conn, &cfg, proto::STREAM_RESULT, stamp, rlen,
                                 None, &mut model)?;
        apply_result(&mut model, adm.mode, adm.lr, adm.momentum, &result);
        losses.push(result.loss);
    }

    // grad mode streams one batch per round off this rank's shard
    let stream = match adm.mode {
        Mode::Grad => Some(ShardStream::new(spec, adm.first_round, cfg.prefetch,
                                            rows, din, dout)),
        Mode::Fedavg => None,
    };

    // comm/compute overlap (grad mode only): per-layer flat grad bucket
    // layout, streamed over the socket as each layer's dW lands
    let overlap_comm = matches!(adm.mode, Mode::Grad) && exec::overlap_mode().comm();
    let buckets = if overlap_comm { model.grad_bucket_ranges() } else { Vec::new() };

    let mut contrib: Vec<f32> = Vec::new();
    let mut comm_exposed: Vec<Duration> = Vec::new();
    for round in adm.first_round..adm.total_rounds {
        if faults::take(Kind::KillConn, round, &cfg.tag) {
            let _ = conn.shutdown(Shutdown::Both);
            return Err(DistError::InjectedKill { round });
        }
        if faults::take(Kind::Stall, round, &cfg.tag) {
            thread::sleep(cfg.stall);
        }
        // each arm sends its own contribution (the overlapped one
        // interleaves the sends with backward) and records the comm
        // time left exposed on the critical path
        let loss = match (&adm.mode, &stream) {
            (Mode::Grad, Some(stream)) if overlap_comm => {
                let (x, t) = stream.next();
                contrib.clear();
                contrib.resize(glen, 0.0);
                let sink = GradSink::new(&mut contrib, buckets.clone());
                let n = buckets.len();
                // scoped sender: waits on the sink's completion latch
                // and streams bucket j the moment layer j's dW lands
                // (reverse-layer order = the worker's completion order);
                // chunks are offset-addressed, so the coordinator's
                // assembly needs no End until the loss is known
                let (loss, exposed) =
                    thread::scope(|s| -> Result<(f64, Duration), DistError> {
                        let sender = s.spawn(|| -> Result<Instant, ProtoError> {
                            for j in (0..n).rev() {
                                if !sink.wait_completed(n - j) {
                                    break; // backward aborted
                                }
                                let r = sink.ranges()[j].clone();
                                proto::send_range(&mut &conn, proto::STREAM_CONTRIB,
                                                  round, r.start, sink.bucket(j))?;
                            }
                            Ok(Instant::now())
                        });
                        let loss = {
                            let _finish = FinishGuard(&sink);
                            model.forward_backward_overlap(&x, &t, &sink)
                        };
                        let bwd_done = Instant::now();
                        let sent_at = sender
                            .join()
                            .map_err(|_| DistError::CoordinatorLost(
                                "contribution sender panicked".into()))?
                            .map_err(|e| lost(e, "streaming contribution"))?;
                        Ok((loss, sent_at.saturating_duration_since(bwd_done)))
                    })?;
                let t0 = Instant::now();
                write_msg(&mut &conn, &Msg::End {
                    stream: proto::STREAM_CONTRIB,
                    round,
                    loss,
                    contributors: 1,
                }).map_err(|e| lost(e, "sending contribution end"))?;
                comm_exposed.push(exposed + t0.elapsed());
                loss
            }
            (Mode::Grad, Some(stream)) => {
                let (x, t) = stream.next();
                let loss = model.forward_backward(&x, &t);
                model.read_train_flat(TrainTensors::Grads, &mut contrib);
                let t0 = Instant::now();
                send_flat(&mut &conn, proto::STREAM_CONTRIB, round, &contrib, loss, 1)
                    .map_err(|e| lost(e, "sending contribution"))?;
                comm_exposed.push(t0.elapsed());
                loss
            }
            _ => {
                let mut last = 0f64;
                for j in 0..u64::from(adm.sync_every) {
                    let step = round * u64::from(adm.sync_every) + j;
                    let (x, t) = shard_batch(&spec, step, rows, din, dout);
                    last = model.forward_backward(&x, &t);
                    model.apply_update(adm.lr, adm.momentum);
                    // liveness between fat local steps
                    let _ = write_msg(&mut &conn, &Msg::Heartbeat);
                }
                model.read_train_flat(TrainTensors::Params, &mut contrib);
                let t0 = Instant::now();
                send_flat(&mut &conn, proto::STREAM_CONTRIB, round, &contrib, last, 1)
                    .map_err(|e| lost(e, "sending contribution"))?;
                comm_exposed.push(t0.elapsed());
                last
            }
        };
        let result = recv_stream(&conn, &cfg, proto::STREAM_RESULT, round, rlen,
                                 Some((&contrib, loss)), &mut model)?;
        apply_result(&mut model, adm.mode, adm.lr, adm.momentum, &result);
        losses.push(result.loss);
        if let Some((snapper, every)) = &snap {
            let gstep = (round + 1) * spr;
            if *every > 0 && gstep % every == 0 {
                let meta = format!("dist rank {} round {round}", adm.rank);
                snapper.offer(|b| model.snapshot_into(b, gstep, &meta));
                snapshots += 1;
            }
        }
    }

    let _ = conn.shutdown(Shutdown::Both);
    if let Some((snapper, _)) = snap {
        snapper.finish();
    }
    let comm_exposed_ms = if comm_exposed.is_empty() {
        0.0
    } else {
        comm_exposed.iter().sum::<Duration>().as_secs_f64() * 1e3
            / comm_exposed.len() as f64
    };
    Ok(WorkerReport { rank: adm.rank, losses, snapshots, comm_exposed_ms })
}
