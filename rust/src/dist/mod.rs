//! Fault-tolerant multi-worker data-parallel training (ROADMAP item 2).
//!
//! Star topology over TCP: a [`coordinator::Coordinator`] owns the step
//! barrier and N [`worker`] processes each own a data shard
//! ([`crate::data::shard`]) plus an identically-compiled [`Model`]. Every
//! round each worker ships its contribution — gradients
//! ([`Mode::Grad`]) or locally-stepped weights ([`Mode::Fedavg`]) — as a
//! chunked, CRC-checked [`proto`] stream; the coordinator averages the
//! contributions IN RANK ORDER with the exact arithmetic
//! [`simulate_grad_allreduce`] uses, so a fault-free fleet bit-matches
//! the single-process loss curve at equal global batch.
//!
//! Robustness is the headline, not an afterthought:
//!
//! - every frame is length-bounded and CRC-verified; a garbled frame
//!   costs one [`proto::Msg::Resend`] round-trip, never the run;
//! - workers connect with retry-and-backoff and heartbeat between
//!   contributions; the coordinator detects a dead or wedged rank by
//!   EOF or heartbeat-deadline, pauses the barrier, excludes the rank,
//!   and rescales the average over the survivors;
//! - a replacement worker warm-starts from the latest PXCK snapshot
//!   (rank 0 runs a [`crate::ckpt::Snapshotter`]), is re-admitted under
//!   the dead rank's shard, and is brought bit-exact via a
//!   donor-params transfer before its first contribution;
//! - the `PIXELFLY_DIST_FAULT` hook ([`faults`]) injects kill-conn,
//!   stall, and garble-frame failures to prove all of the above in
//!   tests — zero hangs, zero panics, typed [`DistError`]s only.

pub mod coordinator;
pub mod faults;
pub mod proto;
pub mod worker;

use std::path::PathBuf;
use std::time::Duration;

use crate::ckpt::CkptError;
use crate::data::shard::{shard_batch, ShardSpec};
use crate::nn::compile::WeightsError;
use crate::nn::{Model, TrainTensors};

pub use coordinator::{CoordReport, Coordinator};
pub use worker::{WorkerConfig, WorkerReport};

/// Every way a distributed run can fail, typed. The fault-injection
/// suite asserts these are the ONLY exits — no panic ever crosses a
/// dist API boundary.
#[derive(Debug)]
pub enum DistError {
    Io(std::io::Error),
    Proto(proto::ProtoError),
    /// join refused or never completed (mismatched model, full fleet,
    /// coordinator unreachable)
    Handshake(String),
    /// the coordinator stopped talking to this worker mid-run (its
    /// death, or this rank's exclusion)
    CoordinatorLost(String),
    /// every worker is dead or excluded — nothing left to train
    FleetLost,
    /// a `kill-conn@K` fault fired on this worker
    InjectedKill { round: u64 },
    Ckpt(CkptError),
    Weights(WeightsError),
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::Io(e) => write!(f, "i/o error: {e}"),
            DistError::Proto(e) => write!(f, "protocol error: {e}"),
            DistError::Handshake(why) => write!(f, "handshake failed: {why}"),
            DistError::CoordinatorLost(why) => write!(f, "coordinator lost: {why}"),
            DistError::FleetLost => write!(f, "every worker is dead or excluded"),
            DistError::InjectedKill { round } => {
                write!(f, "injected kill-conn at round {round}")
            }
            DistError::Ckpt(e) => write!(f, "checkpoint error: {e}"),
            DistError::Weights(e) => write!(f, "weights error: {e}"),
        }
    }
}

impl std::error::Error for DistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DistError::Io(e) => Some(e),
            DistError::Proto(e) => Some(e),
            DistError::Ckpt(e) => Some(e),
            DistError::Weights(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DistError {
    fn from(e: std::io::Error) -> Self {
        DistError::Io(e)
    }
}

impl From<proto::ProtoError> for DistError {
    fn from(e: proto::ProtoError) -> Self {
        DistError::Proto(e)
    }
}

impl From<CkptError> for DistError {
    fn from(e: CkptError) -> Self {
        DistError::Ckpt(e)
    }
}

impl From<WeightsError> for DistError {
    fn from(e: WeightsError) -> Self {
        DistError::Weights(e)
    }
}

/// What the fleet aggregates each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// average gradients every step (synchronous data parallelism)
    Grad,
    /// run `sync_every` local steps, then average weights (federated
    /// averaging — fewer, fatter exchanges)
    Fedavg,
}

impl Mode {
    pub fn wire(self) -> u8 {
        match self {
            Mode::Grad => proto::MODE_GRAD,
            Mode::Fedavg => proto::MODE_FEDAVG,
        }
    }

    pub fn from_wire(b: u8) -> Option<Mode> {
        match b {
            proto::MODE_GRAD => Some(Mode::Grad),
            proto::MODE_FEDAVG => Some(Mode::Fedavg),
            _ => None,
        }
    }
}

/// The run parameters every member of the fleet must agree on — the
/// coordinator owns them and hands them to workers in `Welcome`.
#[derive(Clone, Debug)]
pub struct DistConfig {
    pub nranks: u32,
    /// allreduce rounds to run (in grad mode, rounds == global steps)
    pub rounds: u64,
    pub mode: Mode,
    /// local steps per round (forced to 1 in grad mode)
    pub sync_every: u32,
    pub lr: f32,
    pub momentum: f32,
    pub data_seed: u64,
    /// how long the coordinator waits for a round's contributions
    /// before the exclusion machinery engages
    pub round_timeout: Duration,
    /// how long the coordinator waits for the initial fleet to join
    pub admit_timeout: Duration,
}

impl DistConfig {
    pub fn new(nranks: u32, rounds: u64) -> Self {
        DistConfig {
            nranks,
            rounds,
            mode: Mode::Grad,
            sync_every: 1,
            lr: 1e-2,
            momentum: 0.9,
            data_seed: 0xDA7A_5EED,
            round_timeout: Duration::from_secs(5),
            admit_timeout: Duration::from_secs(30),
        }
    }

    /// Local steps per round as actually executed (grad mode is 1).
    pub fn steps_per_round(&self) -> u64 {
        match self.mode {
            Mode::Grad => 1,
            Mode::Fedavg => self.sync_every.max(1) as u64,
        }
    }
}

/// Background snapshotting for a worker (applied on rank 0 only):
/// offer a PXCK snapshot every `every` global steps into `dir`.
#[derive(Clone, Debug)]
pub struct SnapshotCfg {
    pub dir: PathBuf,
    pub every: u64,
    pub retain: usize,
}

/// Single-process oracle for [`Mode::Grad`]: gradient accumulation over
/// the N shard batches in rank order, averaged with the same f32
/// arithmetic the coordinator uses — the loss curve (and final params)
/// a fault-free fleet must bit-match.
pub fn simulate_grad_allreduce(model: &mut Model, cfg: &DistConfig) -> Vec<f64> {
    let (rows, din, dout) = (model.seq, model.in_dim(), model.out_dim());
    let glen = model.train_flat_len(TrainTensors::Grads);
    let mut acc = vec![0f32; glen];
    let mut g: Vec<f32> = Vec::new();
    let mut losses = Vec::with_capacity(cfg.rounds as usize);
    for step in 0..cfg.rounds {
        acc.iter_mut().for_each(|v| *v = 0.0);
        let mut loss_sum = 0f64;
        for rank in 0..cfg.nranks {
            let spec = ShardSpec { rank, nranks: cfg.nranks, seed: cfg.data_seed };
            let (x, t) = shard_batch(&spec, step, rows, din, dout);
            loss_sum += model.forward_backward(&x, &t);
            model.read_train_flat(TrainTensors::Grads, &mut g);
            for (a, v) in acc.iter_mut().zip(&g) {
                *a += v;
            }
        }
        let inv = 1.0 / cfg.nranks as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        model.write_train_flat(TrainTensors::Grads, &acc);
        model.apply_update(cfg.lr, cfg.momentum);
        losses.push(loss_sum / cfg.nranks as f64);
    }
    losses
}

/// Single-process oracle for [`Mode::Fedavg`]: every rank runs
/// `sync_every` local steps from the shared round-start state, then the
/// full param views (weights + momentum) are averaged in rank order.
/// The per-round loss is the rank-average of each rank's LAST local
/// loss — the same number the fleet reports.
pub fn simulate_fedavg(model: &mut Model, cfg: &DistConfig) -> Vec<f64> {
    let (rows, din, dout) = (model.seq, model.in_dim(), model.out_dim());
    let plen = model.train_flat_len(TrainTensors::Params);
    let sync = cfg.sync_every.max(1) as u64;
    let mut start: Vec<f32> = Vec::new();
    let mut p: Vec<f32> = Vec::new();
    let mut acc = vec![0f32; plen];
    let mut losses = Vec::with_capacity(cfg.rounds as usize);
    for round in 0..cfg.rounds {
        model.read_train_flat(TrainTensors::Params, &mut start);
        acc.iter_mut().for_each(|v| *v = 0.0);
        let mut loss_sum = 0f64;
        for rank in 0..cfg.nranks {
            model.write_train_flat(TrainTensors::Params, &start);
            let spec = ShardSpec { rank, nranks: cfg.nranks, seed: cfg.data_seed };
            let mut last = 0f64;
            for j in 0..sync {
                let step = round * sync + j;
                let (x, t) = shard_batch(&spec, step, rows, din, dout);
                last = model.forward_backward(&x, &t);
                model.apply_update(cfg.lr, cfg.momentum);
            }
            loss_sum += last;
            model.read_train_flat(TrainTensors::Params, &mut p);
            for (a, v) in acc.iter_mut().zip(&p) {
                *a += v;
            }
        }
        let inv = 1.0 / cfg.nranks as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        model.write_train_flat(TrainTensors::Params, &acc);
        losses.push(loss_sum / cfg.nranks as f64);
    }
    losses
}

/// Run a whole fleet in-process on localhost: bind the coordinator on
/// an ephemeral port, point every worker at it, run to completion. The
/// workhorse of the integration tests and the scaling bench — identical
/// code paths to separate processes, minus the process boundary
/// (per-dispatch determinism of the shared substrate pool is documented
/// safe for concurrent dispatchers).
pub fn run_local(dist: DistConfig, workers: Vec<(Model, WorkerConfig)>)
                 -> Result<(CoordReport, Vec<Result<WorkerReport, DistError>>),
                           DistError> {
    let mut fleet = workers;
    let spec = {
        let (m, _) = fleet.first_mut().ok_or(DistError::FleetLost)?;
        coordinator::FleetSpec::of(m)
    };
    let coord = Coordinator::bind("127.0.0.1:0", dist, spec)?;
    let addr = coord.local_addr()?.to_string();
    std::thread::scope(|s| {
        let ch = s.spawn(move || coord.run());
        let handles: Vec<_> = fleet
            .into_iter()
            .map(|(model, mut wc)| {
                wc.addr = addr.clone();
                s.spawn(move || worker::run(model, wc))
            })
            .collect();
        let worker_results: Vec<Result<WorkerReport, DistError>> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| {
                Err(DistError::Handshake("worker thread panicked".into()))
            }))
            .collect();
        let coord_result = ch.join().unwrap_or_else(|_| {
            Err(DistError::Handshake("coordinator thread panicked".into()))
        })?;
        Ok((coord_result, worker_results))
    })
}
