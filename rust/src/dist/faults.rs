//! Fault injection for the distributed-training chokepoints, mirroring
//! `ckpt/faults.rs`: arm one-shot faults test-first (`arm(spec, tag)`)
//! with a WORKER TAG so parallel tests never contaminate each other, or
//! arm one tag-free fault at process start via `PIXELFLY_DIST_FAULT`.
//!
//! Three failure classes, each fired at allreduce round `K` by the
//! worker whose tag matches:
//!
//! - `kill-conn@K` — the worker drops its connection and exits with a
//!   typed error, simulating a process crash: the coordinator must
//!   detect the death, exclude the rank, and keep the fleet training.
//! - `stall@K` — the worker sleeps past the coordinator's round
//!   deadline, simulating a wedged host: it must be excluded exactly
//!   like a dead one (heartbeats stop too).
//! - `garble-frame@K` — one bit of the next received frame flips before
//!   the CRC check, simulating wire corruption: the frame is rejected
//!   and the chunked-stream resend protocol must recover bit-exactly.

use std::sync::{Mutex, Once};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    KillConn,
    Stall,
    GarbleFrame,
}

#[derive(Debug)]
struct Armed {
    kind: Kind,
    at: u64,
    /// fault fires only for workers whose tag contains this ("" = any)
    tag: String,
}

static ARMED: Mutex<Vec<Armed>> = Mutex::new(Vec::new());
static ENV_ONCE: Once = Once::new();

fn parse(spec: &str) -> Option<(Kind, u64)> {
    let (name, at) = spec.split_once('@')?;
    let at: u64 = at.trim().parse().ok()?;
    let kind = match name.trim() {
        "kill-conn" => Kind::KillConn,
        "stall" => Kind::Stall,
        "garble-frame" => Kind::GarbleFrame,
        _ => return None,
    };
    Some((kind, at))
}

/// Arm one fault (`"kill-conn@3"`, `"stall@2"`, `"garble-frame@1"`)
/// scoped to worker tags containing `tag`. One-shot: the fault disarms
/// when it fires. Returns false on an unparseable spec.
pub fn arm(spec: &str, tag: &str) -> bool {
    match parse(spec) {
        Some((kind, at)) => {
            ARMED.lock().unwrap().push(Armed { kind, at, tag: tag.to_string() });
            true
        }
        None => false,
    }
}

/// Drop every armed fault scoped to `tag` (test cleanup).
pub fn disarm(tag: &str) {
    ARMED.lock().unwrap().retain(|a| a.tag != tag);
}

/// Consume a matching armed fault: fires once when worker `worker_tag`
/// reaches round `round` with `kind` armed at that round.
pub fn take(kind: Kind, round: u64, worker_tag: &str) -> bool {
    ENV_ONCE.call_once(|| {
        if let Ok(spec) = std::env::var("PIXELFLY_DIST_FAULT") {
            if !spec.is_empty() && !arm(&spec, "") {
                eprintln!("PIXELFLY_DIST_FAULT: ignoring unparseable spec {spec:?} \
                           (want kill-conn@K | stall@K | garble-frame@K)");
            }
        }
    });
    let mut g = ARMED.lock().unwrap();
    match g.iter().position(|a| a.kind == kind && a.at == round
                            && worker_tag.contains(a.tag.as_str())) {
        Some(i) => {
            g.remove(i);
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_bad_specs_do_not() {
        assert!(parse("kill-conn@3").is_some());
        assert!(parse("stall@0").is_some());
        assert!(parse("garble-frame@ 7").is_some());
        assert!(parse("explode@3").is_none());
        assert!(parse("kill-conn").is_none());
        assert!(parse("stall@x").is_none());
    }

    #[test]
    fn faults_are_tag_and_round_scoped_and_one_shot() {
        assert!(arm("kill-conn@5", "dist-fault-unit-w1"));
        // wrong round: not consumed
        assert!(!take(Kind::KillConn, 4, "dist-fault-unit-w1"));
        // wrong worker: not consumed
        assert!(!take(Kind::KillConn, 5, "dist-fault-unit-w2"));
        // wrong kind: not consumed
        assert!(!take(Kind::Stall, 5, "dist-fault-unit-w1"));
        // exact match fires once…
        assert!(take(Kind::KillConn, 5, "dist-fault-unit-w1"));
        // …and is consumed
        assert!(!take(Kind::KillConn, 5, "dist-fault-unit-w1"));
        disarm("dist-fault-unit-w1");
    }
}
