//! The fleet coordinator: admission, the per-round contribution
//! barrier, rank-ordered averaging, death detection, and elastic
//! replacement.
//!
//! Threading mirrors the serving server: one acceptor thread performs
//! handshakes, one reader thread per admitted worker assembles its
//! chunked streams, and the MAIN LOOP OWNS EVERY WRITE — readers and
//! the acceptor only push [`Event`]s through a [`Doorbell`], so no
//! socket is ever written from two threads and the barrier state
//! machine lives in exactly one place.
//!
//! The barrier state machine per round:
//!
//! 1. collect one contribution per *contributing* rank (alive, and
//!    `first_round <= round`);
//! 2. at the round deadline, a missing rank with stale heartbeats is
//!    excluded (connection closed, average rescaled over survivors); a
//!    missing rank that still heartbeats gets until the 3× hard cap;
//! 3. when all contributions are in, average IN RANK ORDER with the
//!    exact f32 arithmetic [`super::simulate_grad_allreduce`] uses and
//!    broadcast the result (cached for one round of resend requests).
//!
//! Replacement admission pauses step 3 ("the barrier pauses"): the
//! newcomer is welcomed under the dead rank with
//! `first_round = round + 1`, a donor (lowest contributing rank) is
//! asked to upload its full param view — stamped `round`, since the
//! donor cannot apply this round's result while the barrier holds —
//! and the upload is forwarded before the round's result is broadcast.
//! The replacement therefore sees params(start of `round`), then
//! result(`round`), and enters the barrier at `round + 1` bit-exact
//! with the fleet.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::nn::{Model, TrainTensors};
use crate::sparse::exec::pool::Doorbell;

use super::proto::{self, is_timeout, read_msg, send_flat, write_msg, Assembly, Msg,
                   ProtoError};
use super::{DistConfig, DistError, Mode};

/// The model identity every joining worker must prove (same gate a
/// checkpoint load uses) plus the flat-view lengths that bound every
/// stream buffer.
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    pub fingerprint: u64,
    pub grads_len: usize,
    pub params_len: usize,
}

impl FleetSpec {
    pub fn of(model: &mut Model) -> FleetSpec {
        FleetSpec {
            fingerprint: model.state_fingerprint(),
            grads_len: model.train_flat_len(TrainTensors::Grads),
            params_len: model.train_flat_len(TrainTensors::Params),
        }
    }
}

/// What a completed (or failed-over) run did.
#[derive(Clone, Debug)]
pub struct CoordReport {
    /// rank-averaged loss per completed round
    pub losses: Vec<f64>,
    /// every rank ever excluded (death or stall), in exclusion order
    pub excluded: Vec<u32>,
    /// replacement workers admitted mid-run
    pub replacements: u32,
    pub rounds: u64,
}

/// Poison-tolerant accessors for a rank's shared liveness clock. The
/// `Instant` inside is always valid as a whole (no partially-written
/// state a panic could expose), so a reader thread that panicked while
/// holding the lock must not cascade: the stamping side would otherwise
/// panic on the next frame and the freshness check would take the whole
/// fleet down with it.
fn stamp_now(clock: &Mutex<Instant>) {
    *clock.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = Instant::now();
}

fn clock_elapsed(clock: &Mutex<Instant>) -> Duration {
    clock.lock().unwrap_or_else(std::sync::PoisonError::into_inner).elapsed()
}

struct HelloInfo {
    fingerprint: u64,
    grads_len: u64,
    params_len: u64,
}

enum Event {
    Join { conn: TcpStream, hello: HelloInfo },
    Contrib { rank: u32, round: u64, loss: f64, data: Vec<f32> },
    ContribIncomplete { rank: u32, round: u64 },
    ParamsUp { stamp: u64, data: Vec<f32> },
    ResendRequest { rank: u32, round: u64 },
    Dead { rank: u32 },
}

struct Shared {
    events: Vec<Event>,
    done: bool,
}

struct Slot {
    /// write half — only the main loop touches it
    conn: TcpStream,
    alive: bool,
    first_round: u64,
    last_seen: Arc<Mutex<Instant>>,
    /// buffered contributions (current round, possibly next round from
    /// a fast worker) — bounded at 2
    contribs: Vec<(u64, f64, Vec<f32>)>,
    needs_params: bool,
    /// last params forward, kept for one resend request
    sent_params: Option<(u64, Vec<f32>)>,
}

pub struct Coordinator {
    listener: TcpListener,
    dist: DistConfig,
    spec: FleetSpec,
}

impl Coordinator {
    pub fn bind(addr: &str, dist: DistConfig, spec: FleetSpec)
                -> Result<Coordinator, DistError> {
        let listener = TcpListener::bind(addr)?;
        Ok(Coordinator { listener, dist, spec })
    }

    pub fn local_addr(&self) -> Result<SocketAddr, DistError> {
        Ok(self.listener.local_addr()?)
    }

    /// Drive the fleet to `dist.rounds` completed rounds (or a typed
    /// failure), then tear every thread and socket down.
    pub fn run(self) -> Result<CoordReport, DistError> {
        let Coordinator { listener, dist, spec } = self;
        let local = listener.local_addr()?;
        let bell: Arc<Doorbell<Shared>> =
            Arc::new(Doorbell::new(Shared { events: Vec::new(), done: false }));
        let ab = bell.clone();
        let acceptor = thread::Builder::new()
            .name("pxd-accept".into())
            .spawn(move || accept_loop(listener, ab))?;

        let mut slots: Vec<Slot> = Vec::new();
        let mut readers: Vec<JoinHandle<()>> = Vec::new();
        let outcome = drive(&dist, &spec, &bell, &mut slots, &mut readers);

        bell.update(|s| s.done = true);
        for s in slots.iter() {
            let _ = s.conn.shutdown(Shutdown::Both);
        }
        // unblock the acceptor exactly like `TcpServer::halt`
        let _ = TcpStream::connect(local);
        for r in readers {
            let _ = r.join();
        }
        let _ = acceptor.join();
        outcome
    }
}

fn accept_loop(listener: TcpListener, bell: Arc<Doorbell<Shared>>) {
    loop {
        let mut conn = match listener.accept() {
            Ok((c, _)) => c,
            Err(_) => {
                if bell.update(|s| s.done) {
                    return;
                }
                continue;
            }
        };
        if bell.update(|s| s.done) {
            return;
        }
        let _ = conn.set_nodelay(true);
        let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = conn.set_write_timeout(Some(Duration::from_secs(5)));
        match read_msg(&mut conn) {
            Ok(Msg::Hello { proto_version, fingerprint, grads_len, params_len, .. }) => {
                if proto_version != proto::PROTO_VERSION {
                    let _ = write_msg(&mut conn, &Msg::Error {
                        msg: format!("protocol version {proto_version} unsupported \
                                      (coordinator speaks {})", proto::PROTO_VERSION),
                    });
                    continue;
                }
                bell.update(|s| {
                    s.events.push(Event::Join {
                        conn,
                        hello: HelloInfo { fingerprint, grads_len, params_len },
                    });
                });
            }
            // anything else — garbage, timeout, wrong first frame — is
            // not a worker; drop the connection
            _ => {}
        }
    }
}

/// Per-worker reader: assembles chunked streams off the read half and
/// reports completed contributions / uploads / liveness as events.
/// Transient frame corruption (bad CRC, unknown kind) drops the frame;
/// only a dead socket ends the loop.
fn reader_loop(conn: TcpStream, rank: u32, contrib_len: usize, params_len: usize,
               last_seen: Arc<Mutex<Instant>>, bell: Arc<Doorbell<Shared>>) {
    let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
    let patience = Duration::from_secs(10);
    let mut contrib = Assembly::new(contrib_len);
    let mut contrib_round = u64::MAX;
    let mut params = Assembly::new(params_len);
    loop {
        if bell.update(|s| s.done) {
            return;
        }
        let msg = match proto::read_frame_socket(&conn, false, patience) {
            Err(e) if is_timeout(&e) => continue,
            Err(ProtoError::BadCrc { .. }) | Err(ProtoError::BadKind(_))
            | Err(ProtoError::Truncated { .. }) | Err(ProtoError::TooLarge { .. }) => {
                continue;
            }
            Err(_) => {
                bell.update(|s| s.events.push(Event::Dead { rank }));
                return;
            }
            Ok(m) => m,
        };
        stamp_now(&last_seen);
        match msg {
            Msg::Chunk { stream, round, offset, data } => {
                if stream == proto::STREAM_CONTRIB {
                    if round != contrib_round {
                        contrib.reset();
                        contrib_round = round;
                    }
                    let _ = contrib.absorb(offset, &data);
                } else if stream == proto::STREAM_PARAMS_UP {
                    let _ = params.absorb(offset, &data);
                }
            }
            Msg::End { stream, round, loss, .. } => {
                if stream == proto::STREAM_CONTRIB {
                    let ev = if round == contrib_round && contrib.complete() {
                        Event::Contrib { rank, round, loss, data: contrib.buf.clone() }
                    } else {
                        Event::ContribIncomplete { rank, round }
                    };
                    bell.update(|s| s.events.push(ev));
                    contrib.reset();
                    contrib_round = u64::MAX;
                } else if stream == proto::STREAM_PARAMS_UP {
                    if params.complete() {
                        let ev = Event::ParamsUp { stamp: round, data: params.buf.clone() };
                        bell.update(|s| s.events.push(ev));
                    }
                    // incomplete upload: the main loop re-requests on its
                    // params deadline, no resend needed here
                    params.reset();
                }
            }
            Msg::Resend { round } => {
                bell.update(|s| s.events.push(Event::ResendRequest { rank, round }));
            }
            // Heartbeat (and anything unexpected) only refreshes last_seen
            _ => {}
        }
    }
}

fn kill_slot(slots: &mut [Slot], i: usize, excluded: &mut Vec<u32>) {
    let s = &mut slots[i];
    if !s.alive {
        return;
    }
    s.alive = false;
    s.needs_params = false;
    s.contribs.clear();
    s.sent_params = None;
    let _ = s.conn.shutdown(Shutdown::Both);
    excluded.push(i as u32);
}

/// Lowest contributing rank — the donor for replacement catch-up.
fn donor_index(slots: &[Slot], round: u64) -> Option<usize> {
    (0..slots.len()).find(|&i| slots[i].alive && slots[i].first_round <= round)
}

fn drive(dist: &DistConfig, spec: &FleetSpec, bell: &Arc<Doorbell<Shared>>,
         slots: &mut Vec<Slot>, readers: &mut Vec<JoinHandle<()>>)
         -> Result<CoordReport, DistError> {
    let nranks = dist.nranks as usize;
    let contrib_len = match dist.mode {
        Mode::Grad => spec.grads_len,
        Mode::Fedavg => spec.params_len,
    };
    let mut started = false;
    let admit_deadline = Instant::now() + dist.admit_timeout;
    let mut round: u64 = 0;
    let mut round_start = Instant::now();
    let mut losses: Vec<f64> = Vec::new();
    let mut excluded: Vec<u32> = Vec::new();
    let mut replacements: u32 = 0;
    let mut last_result: Option<(u64, Vec<f32>, f64, u32)> = None;
    // replacement params transfer bookkeeping
    let mut params_req_at: Option<Instant> = None;
    let mut params_give_up: Option<Instant> = None;

    loop {
        let events = bell
            .wait_timeout_until(Duration::from_millis(50), |s| {
                if s.events.is_empty() {
                    None
                } else {
                    Some(std::mem::take(&mut s.events))
                }
            })
            .unwrap_or_default();

        for ev in events {
            match ev {
                Event::Join { mut conn, hello } => {
                    if hello.fingerprint != spec.fingerprint
                        || hello.grads_len != spec.grads_len as u64
                        || hello.params_len != spec.params_len as u64
                    {
                        let _ = write_msg(&mut conn, &Msg::Error {
                            msg: format!(
                                "model mismatch: fleet fingerprint {:016x} \
                                 ({} grad / {} param elems), worker {:016x} \
                                 ({} / {})",
                                spec.fingerprint, spec.grads_len, spec.params_len,
                                hello.fingerprint, hello.grads_len, hello.params_len
                            ),
                        });
                        continue;
                    }
                    let assign: Option<(usize, u64)> = if !started {
                        if slots.len() < nranks {
                            Some((slots.len(), 0))
                        } else {
                            None
                        }
                    } else if slots.iter().any(|s| s.alive && s.needs_params) {
                        // one replacement catch-up in flight at a time
                        None
                    } else {
                        slots.iter().position(|s| !s.alive).map(|i| (i, round + 1))
                    };
                    let (i, first_round) = match assign {
                        None => {
                            let _ = write_msg(&mut conn, &Msg::Retry { backoff_ms: 100 });
                            continue;
                        }
                        Some(a) => a,
                    };
                    let welcome = Msg::Welcome {
                        rank: i as u32,
                        nranks: dist.nranks,
                        first_round,
                        total_rounds: dist.rounds,
                        mode: dist.mode.wire(),
                        sync_every: dist.sync_every.max(1),
                        lr: dist.lr,
                        momentum: dist.momentum,
                        data_seed: dist.data_seed,
                    };
                    if write_msg(&mut conn, &welcome).is_err() {
                        continue;
                    }
                    let reader_conn = match conn.try_clone() {
                        Ok(c) => c,
                        Err(_) => continue,
                    };
                    let last_seen = Arc::new(Mutex::new(Instant::now()));
                    let (ls, rb) = (last_seen.clone(), bell.clone());
                    let plen = spec.params_len;
                    let handle = thread::Builder::new()
                        .name(format!("pxd-read-{i}"))
                        .spawn(move || reader_loop(reader_conn, i as u32, contrib_len,
                                                   plen, ls, rb));
                    let handle = match handle {
                        Ok(h) => h,
                        Err(_) => continue,
                    };
                    readers.push(handle);
                    let slot = Slot {
                        conn,
                        alive: true,
                        first_round,
                        last_seen,
                        contribs: Vec::new(),
                        needs_params: started,
                        sent_params: None,
                    };
                    if i == slots.len() {
                        slots.push(slot);
                    } else {
                        slots[i] = slot;
                    }
                    if started {
                        replacements += 1;
                        // force an immediate donor request below
                        params_req_at = None;
                        params_give_up = None;
                    }
                }
                Event::Contrib { rank, round: r, loss, data } => {
                    let i = rank as usize;
                    if i >= slots.len() || !slots[i].alive {
                        continue;
                    }
                    // current round, or one round ahead from a fast
                    // worker racing the barrier — anything else is stale
                    if r >= round && r <= round + 1 {
                        let slot = &mut slots[i];
                        slot.contribs.retain(|c| c.0 != r);
                        slot.contribs.push((r, loss, data));
                        if slot.contribs.len() > 2 {
                            slot.contribs.remove(0);
                        }
                    }
                }
                Event::ContribIncomplete { rank, round: r } => {
                    let i = rank as usize;
                    if i < slots.len() && slots[i].alive && r >= round {
                        if write_msg(&mut slots[i].conn, &Msg::Resend { round: r })
                            .is_err()
                        {
                            kill_slot(slots, i, &mut excluded);
                        }
                    }
                }
                Event::ParamsUp { stamp, data } => {
                    if let Some(i) = slots.iter().position(|s| s.alive && s.needs_params) {
                        if send_flat(&mut slots[i].conn, proto::STREAM_PARAMS_DOWN,
                                     stamp, &data, 0.0, 0)
                            .is_ok()
                        {
                            let slot = &mut slots[i];
                            slot.needs_params = false;
                            slot.sent_params = Some((stamp, data));
                        } else {
                            kill_slot(slots, i, &mut excluded);
                        }
                        params_req_at = None;
                        params_give_up = None;
                    }
                }
                Event::ResendRequest { rank, round: r } => {
                    let i = rank as usize;
                    if i >= slots.len() || !slots[i].alive {
                        continue;
                    }
                    let resent = match &last_result {
                        Some((lr, data, loss, k)) if *lr == r => {
                            send_flat(&mut slots[i].conn, proto::STREAM_RESULT, r,
                                      data, *loss, *k)
                                .is_ok()
                        }
                        _ => {
                            let Slot { conn, sent_params, .. } = &mut slots[i];
                            match sent_params {
                                Some((stamp, data)) if *stamp == r => {
                                    send_flat(conn, proto::STREAM_PARAMS_DOWN, r,
                                              data, 0.0, 0)
                                        .is_ok()
                                }
                                _ => true, // nothing cached for that round: ignore
                            }
                        }
                    };
                    if !resent {
                        kill_slot(slots, i, &mut excluded);
                    }
                }
                Event::Dead { rank } => {
                    let i = rank as usize;
                    if i < slots.len() {
                        kill_slot(slots, i, &mut excluded);
                    }
                }
            }
        }

        // initial admission barrier
        if !started {
            if slots.len() == nranks && slots.iter().all(|s| s.alive) {
                started = true;
                round_start = Instant::now();
            } else if Instant::now() > admit_deadline {
                return Err(DistError::Handshake(format!(
                    "only {} of {nranks} workers joined within {:?}",
                    slots.iter().filter(|s| s.alive).count(),
                    dist.admit_timeout
                )));
            } else {
                continue;
            }
        }

        // a replacement catch-up in flight pauses the round barrier
        if slots.iter().any(|s| s.alive && s.needs_params) {
            let now = Instant::now();
            let give_up = *params_give_up.get_or_insert(now + dist.round_timeout * 3);
            if now > give_up {
                // the transfer never completed: drop the replacement so
                // the fleet can move again
                if let Some(i) = slots.iter().position(|s| s.alive && s.needs_params) {
                    kill_slot(slots, i, &mut excluded);
                }
                params_req_at = None;
                params_give_up = None;
            } else {
                let due = match params_req_at {
                    None => true,
                    Some(t) => now > t + dist.round_timeout,
                };
                if due {
                    match donor_index(slots, round) {
                        Some(d) => {
                            if write_msg(&mut slots[d].conn, &Msg::ParamsRequest)
                                .is_err()
                            {
                                kill_slot(slots, d, &mut excluded);
                            }
                            params_req_at = Some(now);
                        }
                        None => {
                            // nobody left to donate: the un-synced
                            // replacement cannot be saved
                            if let Some(i) =
                                slots.iter().position(|s| s.alive && s.needs_params)
                            {
                                kill_slot(slots, i, &mut excluded);
                            }
                            params_req_at = None;
                            params_give_up = None;
                        }
                    }
                }
                continue;
            }
        }

        // round barrier: completion, then deadline-driven exclusion
        let contributing: Vec<usize> = (0..slots.len())
            .filter(|&i| slots[i].alive && slots[i].first_round <= round)
            .collect();
        if contributing.is_empty() {
            return Err(DistError::FleetLost);
        }
        let have_all = contributing
            .iter()
            .all(|&i| slots[i].contribs.iter().any(|c| c.0 == round));
        if have_all {
            let k = contributing.len() as u32;
            let mut acc = vec![0f32; contrib_len];
            let mut loss_sum = 0f64;
            // rank order — the exact arithmetic of the sim oracle
            for &i in &contributing {
                // unreachable-by-construction: `have_all` above proved a
                // round-`round` contribution exists for every index here
                let c = slots[i].contribs.iter().find(|c| c.0 == round).unwrap();
                loss_sum += c.1;
                for (a, v) in acc.iter_mut().zip(&c.2) {
                    *a += v;
                }
            }
            let inv = 1.0 / k as f32;
            for a in acc.iter_mut() {
                *a *= inv;
            }
            let avg_loss = loss_sum / k as f64;
            losses.push(avg_loss);
            for i in 0..slots.len() {
                if !slots[i].alive {
                    continue;
                }
                if send_flat(&mut slots[i].conn, proto::STREAM_RESULT, round, &acc,
                             avg_loss, k)
                    .is_err()
                {
                    kill_slot(slots, i, &mut excluded);
                }
            }
            last_result = Some((round, acc, avg_loss, k));
            for s in slots.iter_mut() {
                s.contribs.retain(|c| c.0 > round);
            }
            round += 1;
            round_start = Instant::now();
            if round == dist.rounds {
                return Ok(CoordReport { losses, excluded, replacements, rounds: round });
            }
        } else if round_start.elapsed() > dist.round_timeout {
            let hard = round_start.elapsed() > dist.round_timeout * 3;
            let missing: Vec<usize> = contributing
                .iter()
                .copied()
                .filter(|&i| !slots[i].contribs.iter().any(|c| c.0 == round))
                .collect();
            for i in missing {
                let fresh = clock_elapsed(&slots[i].last_seen) < dist.round_timeout;
                if hard || !fresh {
                    kill_slot(slots, i, &mut excluded);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn liveness_clock_survives_poisoning() {
        // a reader thread dying while holding a rank's liveness clock
        // must not cascade: both the stamp (reader side) and the
        // freshness check (barrier side) go through poison-tolerant
        // accessors
        let clock = Arc::new(Mutex::new(Instant::now()));
        let c2 = Arc::clone(&clock);
        let _ = thread::spawn(move || {
            let _g = c2.lock().unwrap();
            panic!("poison the clock");
        })
        .join();
        assert!(clock.is_poisoned(), "setup must poison the lock");
        stamp_now(&clock);
        assert!(clock_elapsed(&clock) < Duration::from_secs(5));
    }
}
