//! Synthetic token corpus for the language-modeling experiments (Fig 8).
//!
//! A first-order Markov chain with Zipf-distributed stationary unigrams
//! over a configurable vocabulary: the stream has learnable bigram
//! structure (so training ppl drops well below the unigram entropy) and a
//! heavy-tailed token distribution like natural text.

use super::TokenBatch;
use crate::util::{rng::zipf_cdf, Rng};

#[derive(Clone, Debug)]
pub struct MarkovCorpus {
    pub vocab: usize,
    /// transition CDFs: next-token distribution conditioned on a bucket of
    /// the previous token (buckets keep the table small for big vocabs)
    trans: Vec<Vec<f64>>,
    buckets: usize,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let buckets = 16.min(vocab);
        // each bucket gets its own Zipf permutation => strong bigram signal
        let trans = (0..buckets)
            .map(|_| {
                let a = 1.0 + rng.f64(); // exponent 1..2
                zipf_cdf(vocab, a)
            })
            .collect();
        MarkovCorpus { vocab, trans, buckets }
    }

    fn bucket(&self, tok: usize) -> usize {
        tok % self.buckets
    }

    pub fn sample(&self, batch: usize, seq: usize, rng: &mut Rng) -> TokenBatch {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.sample_into(batch, seq, rng, &mut x, &mut y);
        TokenBatch { x, y, batch, seq }
    }

    /// Fill caller-owned buffers (cleared first); the chain is generated
    /// streaming — `y[t] = x[t+1]` falls out of pushing (prev, next)
    /// pairs — so steady-state sampling allocates nothing at all.
    pub fn sample_into(&self, batch: usize, seq: usize, rng: &mut Rng,
                       x: &mut Vec<i32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(batch * seq);
        y.reserve(batch * seq);
        for _ in 0..batch {
            let mut prev = rng.below(self.vocab);
            for _ in 0..seq {
                // token ranks permuted per bucket so the mapping differs
                let r = rng.zipf(&self.trans[self.bucket(prev)]);
                let tok = (r * 31 + self.bucket(prev) * 7) % self.vocab;
                x.push(prev as i32);
                y.push(tok as i32);
                prev = tok;
            }
        }
    }

    /// Unigram entropy estimate (nats) from a sample — the ppl ceiling a
    /// context-free model would hit; tests assert trained models beat it.
    pub fn unigram_entropy(&self, rng: &mut Rng) -> f64 {
        let b = self.sample(8, 256, rng);
        let mut counts = vec![0usize; self.vocab];
        for &t in &b.x {
            counts[t as usize] += 1;
        }
        let total: usize = counts.iter().sum();
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total as f64;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = MarkovCorpus::new(512, 0);
        let mut rng = Rng::new(1);
        let b = c.sample(2, 64, &mut rng);
        assert!(b.x.iter().all(|&t| (t as usize) < 512));
        assert_eq!(b.x.len(), 2 * 64);
        assert_eq!(b.y.len(), 2 * 64);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let c = MarkovCorpus::new(64, 2);
        let mut rng = Rng::new(3);
        let b = c.sample(1, 32, &mut rng);
        // y[t] must equal x[t+1]
        for t in 0..31 {
            assert_eq!(b.y[t], b.x[t + 1]);
        }
    }

    #[test]
    fn bigram_structure_exists() {
        // conditional distribution must differ across previous-token buckets
        let c = MarkovCorpus::new(128, 4);
        let mut rng = Rng::new(5);
        let b = c.sample(16, 256, &mut rng);
        let mut next_given: Vec<Vec<usize>> = vec![vec![0; 128]; 2];
        for i in 0..b.x.len() - 1 {
            let bucket = (b.x[i] as usize % 16) % 2;
            next_given[bucket][b.y[i] as usize] += 1;
        }
        let tv: f64 = (0..128)
            .map(|t| {
                let a = next_given[0][t] as f64 / next_given[0].iter().sum::<usize>() as f64;
                let b = next_given[1][t] as f64 / next_given[1].iter().sum::<usize>() as f64;
                (a - b).abs()
            })
            .sum::<f64>()
            / 2.0;
        assert!(tv > 0.1, "total variation {tv} too small — no bigram signal");
    }

    #[test]
    fn entropy_below_uniform() {
        let c = MarkovCorpus::new(256, 6);
        let h = c.unigram_entropy(&mut Rng::new(7));
        assert!(h < (256f64).ln(), "zipf should be below uniform entropy");
        assert!(h > 1.0);
    }
}
