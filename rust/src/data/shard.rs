//! Shard-aware synthetic batch streams for data-parallel training.
//!
//! Every batch is a pure function of `(seed, rank, step)` — no stream
//! state, no consumption order dependence — so a single process can
//! replay any rank's shard exactly. That purity is what lets the
//! distributed tests demand bit-equality: the single-process oracle
//! accumulates `shard_batch(rank, step)` gradients in rank order and
//! must land on the same floats the fleet exchanged over TCP.

use crate::sparse::dense::Matrix;
use crate::util::Rng;

use super::prefetch::Prefetcher;

/// Which slice of the synthetic distribution a worker owns. Two specs
/// with different ranks under the same seed draw disjoint streams; the
/// same spec always replays the same batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub rank: u32,
    pub nranks: u32,
    pub seed: u64,
}

/// Mix `(seed, rank, step)` into one RNG stream key. Odd multiplicative
/// constants (splitmix64's) spread consecutive steps and adjacent ranks
/// far apart in seed space.
fn mix(spec: &ShardSpec, step: u64) -> u64 {
    spec.seed
        ^ (spec.rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (step + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9)
}

/// The regression batch of shard `spec` at global step `step`:
/// `(x, target)` with the same shapes and scales `Model::train` draws
/// (`randn(1.0)` inputs, `randn(0.5)` targets).
pub fn shard_batch(spec: &ShardSpec, step: u64, rows: usize, in_dim: usize,
                   out_dim: usize) -> (Matrix, Matrix) {
    let mut rng = Rng::new(mix(spec, step));
    let x = Matrix::randn(rows, in_dim, 1.0, &mut rng);
    let target = Matrix::randn(rows, out_dim, 0.5, &mut rng);
    (x, target)
}

/// A worker's batch stream: [`shard_batch`] behind the background
/// [`Prefetcher`], so batch generation overlaps the allreduce wait.
/// `next()` yields steps `start_step, start_step + 1, ...` in order.
pub struct ShardStream {
    inner: Prefetcher<(Matrix, Matrix)>,
}

impl ShardStream {
    pub fn new(spec: ShardSpec, start_step: u64, depth: usize, rows: usize,
               in_dim: usize, out_dim: usize) -> Self {
        ShardStream {
            inner: Prefetcher::new(depth, move |i| {
                shard_batch(&spec, start_step + i as u64, rows, in_dim, out_dim)
            }),
        }
    }

    /// The next `(x, target)` batch (parks until prefetched).
    pub fn next(&self) -> (Matrix, Matrix) {
        self.inner.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn shard_batches_are_pure_and_rank_disjoint() {
        let a = ShardSpec { rank: 0, nranks: 2, seed: 7 };
        let b = ShardSpec { rank: 1, nranks: 2, seed: 7 };
        let (xa1, ta1) = shard_batch(&a, 5, 4, 8, 8);
        let (xa2, ta2) = shard_batch(&a, 5, 4, 8, 8);
        assert_eq!(bits(&xa1), bits(&xa2), "same (spec, step) must replay");
        assert_eq!(bits(&ta1), bits(&ta2));
        let (xb, _) = shard_batch(&b, 5, 4, 8, 8);
        assert_ne!(bits(&xa1), bits(&xb), "ranks must draw different data");
        let (xa_next, _) = shard_batch(&a, 6, 4, 8, 8);
        assert_ne!(bits(&xa1), bits(&xa_next), "steps must draw different data");
    }

    #[test]
    fn shard_stream_replays_shard_batch_in_step_order() {
        let spec = ShardSpec { rank: 1, nranks: 4, seed: 42 };
        let stream = ShardStream::new(spec, 10, 2, 3, 6, 5);
        for step in 10..14u64 {
            let (x, t) = stream.next();
            let (wx, wt) = shard_batch(&spec, step, 3, 6, 5);
            assert_eq!(bits(&x), bits(&wx), "step {step}");
            assert_eq!(bits(&t), bits(&wt), "step {step}");
        }
    }
}
