//! Clustered synthetic vision data.
//!
//! Each class k has a set of patch prototypes; an example is a sequence of
//! `seq` patches, each a noisy copy of its positional prototype, plus a
//! few "global" patches shared across positions — the clustering process
//! of paper Process 1/Theorem B.1, which makes the attention/mixing
//! structure matter: local+global+butterfly patterns can pool the signal,
//! and the task is linearly separable only after mixing.

use super::Batch;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct VisionDataset {
    pub n_classes: usize,
    pub seq: usize,
    pub dim: usize,
    pub noise: f32,
    /// `prototypes[class][position][dim]`
    prototypes: Vec<Vec<Vec<f32>>>,
}

impl VisionDataset {
    pub fn new(n_classes: usize, seq: usize, dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (dim as f32).sqrt();
        // class signal lives in a low-dim subspace + positional variation,
        // so mean-pooling raw patches is NOT sufficient: models must mix.
        let class_dirs: Vec<Vec<f32>> =
            (0..n_classes).map(|_| rng.normal_vec(dim, scale)).collect();
        let pos_dirs: Vec<Vec<f32>> = (0..seq).map(|_| rng.normal_vec(dim, scale)).collect();
        let prototypes = (0..n_classes)
            .map(|k| {
                (0..seq)
                    .map(|p| {
                        // sign flips per (class, position) encode the label in
                        // position-interaction structure
                        let flip = if (k + p) % 2 == 0 { 1.0 } else { -1.0 };
                        class_dirs[k]
                            .iter()
                            .zip(&pos_dirs[p])
                            .map(|(c, d)| c * flip + d)
                            .collect()
                    })
                    .collect()
            })
            .collect();
        VisionDataset { n_classes, seq, dim, noise, prototypes }
    }

    pub fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.sample_into(batch, rng, &mut x, &mut y);
        Batch { x, y, batch, seq: self.seq, dim: self.dim }
    }

    /// Fill caller-owned buffers (cleared first). The trainer's steady-
    /// state loop reuses its buffers across steps, so sampling stops
    /// allocating once the first batch has sized them.
    pub fn sample_into(&self, batch: usize, rng: &mut Rng,
                       x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(batch * self.seq * self.dim);
        y.reserve(batch);
        for _ in 0..batch {
            let k = rng.below(self.n_classes);
            y.push(k as i32);
            for p in 0..self.seq {
                for d in 0..self.dim {
                    x.push(self.prototypes[k][p][d] + rng.normal_f32() * self.noise);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_consistent() {
        let ds = VisionDataset::new(10, 16, 12, 0.3, 0);
        let mut rng = Rng::new(1);
        let b = ds.sample(4, &mut rng);
        assert_eq!(b.x.len(), 4 * 16 * 12);
        assert_eq!(b.y.len(), 4);
        assert!(b.y.iter().all(|&y| (0..10).contains(&(y as usize))));
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        let ds = VisionDataset::new(4, 8, 16, 0.1, 2);
        let mut rng = Rng::new(3);
        let b = ds.sample(32, &mut rng);
        // nearest-prototype classification should beat chance easily
        let mut correct = 0;
        for i in 0..b.batch {
            let ex = &b.x[i * b.seq * b.dim..(i + 1) * b.seq * b.dim];
            let mut best = (f32::INFINITY, 0usize);
            for k in 0..ds.n_classes {
                let mut d2 = 0.0f32;
                for p in 0..ds.seq {
                    for d in 0..ds.dim {
                        let diff = ex[p * ds.dim + d] - ds.prototypes[k][p][d];
                        d2 += diff * diff;
                    }
                }
                if d2 < best.0 {
                    best = (d2, k);
                }
            }
            if best.1 == b.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct as f64 / b.batch as f64 > 0.9, "{correct}/32");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = VisionDataset::new(3, 4, 8, 0.2, 7).sample(2, &mut Rng::new(9));
        let b = VisionDataset::new(3, 4, 8, 0.2, 7).sample(2, &mut Rng::new(9));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
