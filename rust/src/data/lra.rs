//! Synthetic Long-Range-Arena-style task suite (Fig 9 workload).
//!
//! Five tasks shaped after LRA's: each produces sequences of the
//! configured length whose label depends on *long-range* structure, so
//! attention sparsity patterns that cannot route distant information lose
//! accuracy while block-local patterns stay fast — the Fig 9 tradeoff.
//!
//! Features come out as [seq, dim] f32 so they feed the same vit-style
//! encoder artifacts as the vision data.

use super::Batch;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LraTask {
    /// nested max/mean reductions over digit tokens (ListOps-like)
    ListOps,
    /// byte-level "sentiment": label = majority of signed token groups
    Text,
    /// two concatenated halves; label = whether they share a key token
    Retrieval,
    /// flattened image: label = parity of bright quadrant count
    Image,
    /// pathfinder: label = whether a marked chain connects ends
    Pathfinder,
}

impl LraTask {
    pub fn all() -> [LraTask; 5] {
        [LraTask::ListOps, LraTask::Text, LraTask::Retrieval, LraTask::Image,
         LraTask::Pathfinder]
    }

    pub fn name(&self) -> &'static str {
        match self {
            LraTask::ListOps => "listops",
            LraTask::Text => "text",
            LraTask::Retrieval => "retrieval",
            LraTask::Image => "image",
            LraTask::Pathfinder => "pathfinder",
        }
    }

    /// Paper sequence lengths vary 1024–4096; ours are configurable.
    pub fn n_classes(&self) -> usize {
        match self {
            LraTask::ListOps => 8,
            _ => 2,
        }
    }
}

pub struct LraDataset {
    pub task: LraTask,
    pub seq: usize,
    pub dim: usize,
    /// token-embedding table, precomputed once at construction (it used
    /// to be rebuilt — 64 fresh Vecs — on every `sample` call)
    tbl: Vec<Vec<f32>>,
}

impl LraDataset {
    pub fn new(task: LraTask, seq: usize, dim: usize) -> Self {
        LraDataset { task, seq, dim, tbl: Self::table(task, dim, 64) }
    }

    /// Deterministic token-embedding table per task.
    fn table(task: LraTask, dim: usize, vocab: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(0xE_B_E_D ^ task.name().len() as u64);
        (0..vocab)
            .map(|_| rng.normal_vec(dim, 1.0 / (dim as f32).sqrt()))
            .collect()
    }

    fn embed_into(&self, tokens: &[usize], x: &mut Vec<f32>) {
        for &t in tokens {
            x.extend_from_slice(&self.tbl[t % self.tbl.len()]);
        }
    }

    pub fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.sample_into(batch, rng, &mut x, &mut y);
        Batch { x, y, batch, seq: self.seq, dim: self.dim }
    }

    /// Fill caller-owned buffers (cleared first). Token generation still
    /// allocates one small per-example token Vec; the embedding table and
    /// the big feature buffer no longer allocate per batch.
    pub fn sample_into(&self, batch: usize, rng: &mut Rng,
                       x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(batch * self.seq * self.dim);
        y.reserve(batch);
        for _ in 0..batch {
            let (tokens, label) = match self.task {
                LraTask::ListOps => self.gen_listops(rng),
                LraTask::Text => self.gen_text(rng),
                LraTask::Retrieval => self.gen_retrieval(rng),
                LraTask::Image => self.gen_image(rng),
                LraTask::Pathfinder => self.gen_pathfinder(rng),
            };
            self.embed_into(&tokens, x);
            y.push(label as i32);
        }
    }

    fn gen_listops(&self, rng: &mut Rng) -> (Vec<usize>, usize) {
        // groups of digits reduced by alternating max/min; the answer digit
        // appears early AND late, so long-range pooling is required.
        let mut toks = vec![0usize; self.seq];
        let mut acc = 0usize;
        let groups = 8;
        let glen = self.seq / groups;
        for g in 0..groups {
            let op_max = g % 2 == 0;
            let mut red = if op_max { 0 } else { 7 };
            for i in 0..glen {
                let d = rng.below(8);
                toks[g * glen + i] = 10 + d;
                red = if op_max { red.max(d) } else { red.min(d) };
            }
            acc = (acc + red) % 8;
        }
        (toks, acc)
    }

    fn gen_text(&self, rng: &mut Rng) -> (Vec<usize>, usize) {
        let mut score = 0i64;
        let toks: Vec<usize> = (0..self.seq)
            .map(|_| {
                let t = rng.below(40);
                if t < 8 {
                    score += 1;
                } else if t < 16 {
                    score -= 1;
                }
                t
            })
            .collect();
        (toks, (score > 0) as usize)
    }

    fn gen_retrieval(&self, rng: &mut Rng) -> (Vec<usize>, usize) {
        let half = self.seq / 2;
        let mut toks = vec![0usize; self.seq];
        for t in toks.iter_mut() {
            *t = 1 + rng.below(30);
        }
        let matched = rng.bool(0.5);
        let key = 40 + rng.below(8);
        toks[rng.below(half)] = key;
        if matched {
            toks[half + rng.below(half)] = key;
        } else {
            toks[half + rng.below(half)] = 40 + ((key - 40) + 1 + rng.below(6)) % 8 + 40 - 40;
        }
        (toks, matched as usize)
    }

    fn gen_image(&self, rng: &mut Rng) -> (Vec<usize>, usize) {
        // 4 quadrants of the flattened sequence; "bright" quadrant = mostly
        // high tokens; label = parity of bright count
        let q = self.seq / 4;
        let mut toks = vec![0usize; self.seq];
        let mut bright_count = 0;
        for qi in 0..4 {
            let bright = rng.bool(0.5);
            bright_count += bright as usize;
            for i in 0..q {
                toks[qi * q + i] = if bright { 32 + rng.below(8) } else { rng.below(8) };
            }
        }
        (toks, bright_count % 2)
    }

    fn gen_pathfinder(&self, rng: &mut Rng) -> (Vec<usize>, usize) {
        // a "path" is a chain of marker tokens at stride positions; with
        // probability 1/2 the chain is broken at a random midpoint.
        let mut toks: Vec<usize> = (0..self.seq).map(|_| rng.below(16)).collect();
        let stride = (self.seq / 16).max(1);
        let connected = rng.bool(0.5);
        let break_at = 4 + rng.below(8);
        for (hop, pos) in (0..self.seq).step_by(stride).enumerate() {
            if !connected && hop == break_at {
                continue;
            }
            toks[pos] = 50;
        }
        (toks, connected as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_batches() {
        for task in LraTask::all() {
            let ds = LraDataset::new(task, 128, 16);
            let mut rng = Rng::new(1);
            let b = ds.sample(4, &mut rng);
            assert_eq!(b.x.len(), 4 * 128 * 16, "{}", task.name());
            assert!(b
                .y
                .iter()
                .all(|&y| (y as usize) < task.n_classes()), "{}", task.name());
        }
    }

    #[test]
    fn labels_are_balanced_enough() {
        for task in [LraTask::Text, LraTask::Retrieval, LraTask::Pathfinder] {
            let ds = LraDataset::new(task, 256, 8);
            let mut rng = Rng::new(2);
            let b = ds.sample(200, &mut rng);
            let ones = b.y.iter().filter(|&&y| y == 1).count();
            assert!(ones > 40 && ones < 160, "{}: {ones}/200", task.name());
        }
    }

    #[test]
    fn listops_label_depends_on_far_tokens() {
        // flipping tokens in the LAST group must be able to change the label
        let ds = LraDataset::new(LraTask::ListOps, 64, 4);
        let mut any_diff = false;
        for seed in 0..20 {
            let mut r1 = Rng::new(seed);
            let (_, l1) = ds.gen_listops(&mut r1);
            let mut r2 = Rng::new(seed + 1000);
            let (_, l2) = ds.gen_listops(&mut r2);
            if l1 != l2 {
                any_diff = true;
            }
        }
        assert!(any_diff);
    }
}
