//! Threaded batch prefetching (the offline substitute for a tokio
//! pipeline). Batch synthesis is host work on the trainer's hot path;
//! overlapping it with device execution is the classic input-pipeline
//! optimisation (§Perf L3).
//!
//! The bounded queue is built on the engine's [`Doorbell`] primitive —
//! the same Condvar-wakeup pairing the resident worker pool parks on —
//! so both sides block exactly until the state they need exists: the
//! producer parks when the queue is full, the consumer when it is
//! empty, and `Drop` is one flag flip + join. No sleeps, no timeouts,
//! no drain loops.

use std::collections::VecDeque;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::sparse::exec::pool::Doorbell;

struct Shared<T> {
    q: VecDeque<T>,
    /// consumer dropped: the producer must exit
    stopped: bool,
    /// producer exited (normally or by panic): `next` must fail loudly
    /// instead of parking forever
    done: bool,
}

/// A prefetcher running a generator closure on a worker thread, keeping a
/// bounded queue of ready items.
pub struct Prefetcher<T: Send + 'static> {
    shared: Arc<Doorbell<Shared<T>>>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a worker producing items with `make` into a queue of `depth`.
    pub fn new<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut(usize) -> T + Send + 'static,
    {
        let depth = depth.max(1);
        let shared = Arc::new(Doorbell::new(Shared {
            q: VecDeque::with_capacity(depth),
            stopped: false,
            done: false,
        }));
        let bell = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            // flag `done` on every exit path, unwinds included, so a
            // panicking `make` turns into a loud `next` instead of a hang
            struct DoneGuard<T>(Arc<Doorbell<Shared<T>>>);
            impl<T> Drop for DoneGuard<T> {
                fn drop(&mut self) {
                    self.0.update(|s| s.done = true);
                }
            }
            let _guard = DoneGuard(Arc::clone(&bell));
            let mut i = 0usize;
            loop {
                let item = make(i); // synthesized OUTSIDE the lock (overlap)
                i += 1;
                let mut slot = Some(item);
                // park until there is room (backpressure) or we are told
                // to stop; the push itself rings the consumer's bell
                let stopped = bell.wait_until(|s| {
                    if s.stopped {
                        return Some(true);
                    }
                    if s.q.len() < depth {
                        s.q.push_back(slot.take().expect("pushed exactly once"));
                        return Some(false);
                    }
                    None
                });
                if stopped {
                    break;
                }
            }
        });
        Prefetcher { shared, handle: Some(handle) }
    }

    /// Get the next item (parks until one is ready; panics if the worker
    /// died).
    pub fn next(&self) -> T {
        self.shared
            .wait_until(|s| {
                if let Some(item) = s.q.pop_front() {
                    // the exit ring doubles as the producer's "room
                    // available" wakeup
                    return Some(Some(item));
                }
                if s.done {
                    return Some(None);
                }
                None
            })
            .expect("prefetch worker died")
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // one flag flip wakes a producer parked on a full queue; clearing
        // the queue frees its items eagerly
        self.shared.update(|s| {
            s.stopped = true;
            s.q.clear();
        });
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_in_order() {
        let p = Prefetcher::new(2, |i| i * 10);
        assert_eq!(p.next(), 0);
        assert_eq!(p.next(), 10);
        assert_eq!(p.next(), 20);
    }

    #[test]
    fn overlaps_production() {
        // items take 5ms to make; consuming 2 of them with a depth-2 queue
        // after a 25ms pause should be nearly free (already prefetched)
        let p = Prefetcher::new(2, |i| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            i
        });
        std::thread::sleep(std::time::Duration::from_millis(25));
        let t0 = std::time::Instant::now();
        let _ = (p.next(), p.next());
        assert!(t0.elapsed() < std::time::Duration::from_millis(8),
                "queue should have been warm: {:?}", t0.elapsed());
    }

    #[test]
    fn drop_terminates_worker() {
        let p = Prefetcher::new(1, |i| vec![i; 1000]);
        let _ = p.next();
        drop(p); // must not hang
    }

    #[test]
    fn dead_worker_fails_loudly_instead_of_hanging() {
        let p = Prefetcher::new(1, |i| {
            if i >= 2 {
                panic!("generator bug");
            }
            i
        });
        assert_eq!(p.next(), 0);
        assert_eq!(p.next(), 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.next()));
        assert!(r.is_err(), "next() after a producer panic must not park forever");
    }
}
