//! Threaded batch prefetching (std::mpsc; the offline substitute for a
//! tokio pipeline).  Batch synthesis is host work on the trainer's hot
//! path; overlapping it with device execution is the classic input-
//! pipeline optimisation (§Perf L3).

use std::sync::mpsc;
use std::thread::JoinHandle;

/// A prefetcher running a generator closure on a worker thread, keeping a
/// bounded queue of ready items.
pub struct Prefetcher<T: Send + 'static> {
    rx: mpsc::Receiver<T>,
    handle: Option<JoinHandle<()>>,
    stop: mpsc::Sender<()>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a worker producing items with `make` into a queue of `depth`.
    pub fn new<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut(usize) -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let handle = std::thread::spawn(move || {
            let mut i = 0usize;
            loop {
                if stop_rx.try_recv().is_ok() {
                    break;
                }
                let item = make(i);
                i += 1;
                // blocks when the queue is full (backpressure)
                if tx.send(item).is_err() {
                    break;
                }
            }
        });
        Prefetcher { rx, handle: Some(handle), stop: stop_tx }
    }

    /// Get the next item (blocks until available).
    pub fn next(&self) -> T {
        self.rx.recv().expect("prefetch worker died")
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        let _ = self.stop.send(());
        // drain so the worker unblocks from send, then join
        while self.rx.try_recv().is_ok() {}
        // one more recv attempt may be needed if worker was mid-send
        let _ = self.rx.recv_timeout(std::time::Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_in_order() {
        let p = Prefetcher::new(2, |i| i * 10);
        assert_eq!(p.next(), 0);
        assert_eq!(p.next(), 10);
        assert_eq!(p.next(), 20);
    }

    #[test]
    fn overlaps_production() {
        // items take 5ms to make; consuming 4 of them with a depth-2 queue
        // after a 15ms pause should be nearly free (already prefetched)
        let p = Prefetcher::new(2, |i| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            i
        });
        std::thread::sleep(std::time::Duration::from_millis(25));
        let t0 = std::time::Instant::now();
        let _ = (p.next(), p.next());
        assert!(t0.elapsed() < std::time::Duration::from_millis(8),
                "queue should have been warm: {:?}", t0.elapsed());
    }

    #[test]
    fn drop_terminates_worker() {
        let p = Prefetcher::new(1, |i| vec![i; 1000]);
        let _ = p.next();
        drop(p); // must not hang
    }
}
