//! Synthetic data substrates (DESIGN.md §Substitutions).
//!
//! The paper trains on CIFAR/ImageNet/WikiText-103/LRA; none is shippable
//! here, so each module generates a structured synthetic stand-in that
//! preserves the property the corresponding experiment measures:
//!
//! - [`vision`]: class-clustered patch sequences (the Theorem-B.1
//!   generative process): learnable by all models, with locality +
//!   global structure so pattern choice matters.
//! - [`corpus`]: Zipf unigram + Markov bigram token streams for the LM
//!   perplexity comparisons.
//! - [`lra`]: five long-sequence tasks shaped after the LRA suite.

pub mod corpus;
pub mod lra;
pub mod prefetch;
pub mod shard;
pub mod vision;

/// A batch of f32 features [batch, seq, dim] + integer labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    pub dim: usize,
}

/// A batch of token ids [batch, seq] with next-token targets [batch, seq].
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}
