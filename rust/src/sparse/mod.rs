//! Pure-Rust block-sparse compute substrate.
//!
//! The paper's Table 7 / Fig 11 microbenchmarks ran on Triton/V100; here
//! the measured testbed is this substrate — a cache-blocked dense GEMM and
//! a BSR (block-sparse-row) GEMM whose inner loops are written so that the
//! latency is governed by the number of *blocks* touched, mirroring the
//! Appendix-A cost model on a CPU (cache lines play the role of
//! coalesced GPU blocks).
//!
//! All multiply paths route through the parallel tiled execution engine
//! in [`exec`] (plan/executor split, scoped `std::thread` worker pool,
//! register-blocked micro-kernels); every operator keeps a serial
//! reference path as the correctness oracle.
//!
//! - [`dense`]        row-major matrix + panel-tiled parallel GEMM
//! - [`bsr`]          BSR matrix + GEMM, pattern-agnostic
//! - [`butterfly_mm`] butterfly product, flat multiply, low-rank composite
//! - [`attention`]    fused streaming block-sparse attention (`AttnPlan`)
//! - [`exec`]         the execution engine: plans, pool, kernel tiers
//!   (scalar/SIMD), workspace scratch arena

pub mod attention;
pub mod bsr;
pub mod butterfly_mm;
pub mod csr;
pub mod dense;
pub mod exec;

pub use attention::{AttnPlan, AttnStats};
pub use bsr::BsrMatrix;
pub use csr::CsrMatrix;
pub use dense::Matrix;
pub use exec::{Activation, Epilogue, GemmPlan, Workspace};
