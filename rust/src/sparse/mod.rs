//! Pure-Rust block-sparse compute substrate.
//!
//! The paper's Table 7 / Fig 11 microbenchmarks ran on Triton/V100; here
//! the measured testbed is this substrate — a cache-blocked dense GEMM and
//! a BSR (block-sparse-row) GEMM whose inner loops are written so that the
//! latency is governed by the number of *blocks* touched, mirroring the
//! Appendix-A cost model on a CPU (cache lines play the role of
//! coalesced GPU blocks).
//!
//! - [`dense`]        row-major matrix + cache-blocked GEMM reference
//! - [`bsr`]          BSR matrix + GEMM, pattern-agnostic
//! - [`butterfly_mm`] sequential butterfly product vs flat multiply

pub mod attention;
pub mod bsr;
pub mod butterfly_mm;
pub mod csr;
pub mod dense;

pub use bsr::BsrMatrix;
pub use csr::CsrMatrix;
pub use dense::Matrix;
