//! Block-sparse attention on the Rust substrate (measured counterpart of
//! the Pallas kernel; used by the Fig 7 / Fig 9 microbenches and the
//! Reformer-style baseline, whose per-batch mask makes AOT impossible —
//! exactly the paper's point about dynamic sparsity).
//!
//! Layout: q, k, v are [seq, d] row-major (single head; callers loop
//! heads).  The kernel walks only the visible key blocks of each query
//! block row with a streaming (online-softmax) accumulator — the same
//! algorithm as `kernels/attention.py`, so the two can be cross-checked.

use crate::patterns::BlockMask;
use crate::sparse::dense::Matrix;
use crate::sparse::exec::{self, pool};

/// Streaming block-sparse attention for one head.
/// `mask` is [seq/b, seq/b]; rows must be non-empty.
///
/// Parallelised over query block rows through the execution engine's
/// pool: block rows are partitioned into contiguous ranges weighted by
/// their visible key blocks (the nnz that governs the work), and each
/// scoped worker owns a disjoint `split_at_mut` slice of the output, so
/// the parallelism is race-free by construction.
pub fn block_sparse_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                              mask: &BlockMask, causal: bool) -> Matrix {
    let (seq, d) = (q.rows, q.cols);
    let nb = mask.rows;
    let b = seq / nb;
    assert_eq!(nb * b, seq);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Matrix::zeros(seq, d);

    let threads = exec::threads();
    // per query block row the work is ~2·(visible blocks)·b²·d flops for
    // the qk dots alone; weight the split by visible blocks and share the
    // engine-wide serial-fallback threshold
    let weights: Vec<usize> =
        (0..nb).map(|qb| mask.row_cols(qb).len().max(1)).collect();
    let flops = 2.0 * (weights.iter().sum::<usize>() * b * b * d) as f64;
    let ranges = if threads <= 1 || flops < exec::MIN_PAR_FLOPS {
        vec![0..nb]
    } else {
        pool::weighted_ranges(&weights, threads)
    };

    if ranges.len() == 1 {
        attention_rows(q, k, v, mask, causal, scale, b, 0..nb, &mut out.data);
        return out;
    }
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = out.data.as_mut_slice();
        for r in ranges {
            let chunk_len = (r.end - r.start) * b * d;
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(chunk_len);
            rest = tail;
            s.spawn(move || attention_rows(q, k, v, mask, causal, scale, b, r, mine));
        }
    });
    out
}

/// Streaming attention over the query block rows `qbs`; `out_chunk` holds
/// exactly those rows of the output.
#[allow(clippy::too_many_arguments)]
fn attention_rows(q: &Matrix, k: &Matrix, v: &Matrix, mask: &BlockMask,
                  causal: bool, scale: f32, b: usize,
                  qbs: std::ops::Range<usize>, out_chunk: &mut [f32]) {
    let d = q.cols;
    let mut scores = vec![0.0f32; b];
    let qb0 = qbs.start;
    for qb in qbs {
        // per-query-row streaming state
        let mut m = vec![f32::NEG_INFINITY; b];
        let mut l = vec![0.0f32; b];
        let mut acc = vec![0.0f32; b * d];
        for kb in mask.row_cols(qb) {
            if causal && kb > qb {
                continue;
            }
            for qi in 0..b {
                let qrow = q.row(qb * b + qi);
                let qpos = qb * b + qi;
                // scores for this key block
                let mut row_max = f32::NEG_INFINITY;
                for ki in 0..b {
                    let kpos = kb * b + ki;
                    let s = if causal && kpos > qpos {
                        f32::NEG_INFINITY
                    } else {
                        let krow = k.row(kpos);
                        let mut dot = 0.0f32;
                        for t in 0..d {
                            dot += qrow[t] * krow[t];
                        }
                        dot * scale
                    };
                    scores[ki] = s;
                    row_max = row_max.max(s);
                }
                if row_max == f32::NEG_INFINITY {
                    continue;
                }
                let m_new = m[qi].max(row_max);
                let alpha = if m[qi].is_finite() { (m[qi] - m_new).exp() } else { 0.0 };
                l[qi] *= alpha;
                let arow = &mut acc[qi * d..(qi + 1) * d];
                if alpha != 1.0 {
                    for t in 0..d {
                        arow[t] *= alpha;
                    }
                }
                for ki in 0..b {
                    if scores[ki] == f32::NEG_INFINITY {
                        continue;
                    }
                    let p = (scores[ki] - m_new).exp();
                    l[qi] += p;
                    let vrow = v.row(kb * b + ki);
                    for t in 0..d {
                        arow[t] += p * vrow[t];
                    }
                }
                m[qi] = m_new;
            }
        }
        for qi in 0..b {
            let r = (qb - qb0) * b + qi;
            let orow = &mut out_chunk[r * d..(r + 1) * d];
            let denom = l[qi].max(1e-30);
            let arow = &acc[qi * d..(qi + 1) * d];
            for t in 0..d {
                orow[t] = arow[t] / denom;
            }
        }
    }
}

/// Dense attention reference (oracle).
pub fn dense_attention(q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
    let (seq, d) = (q.rows, q.cols);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Matrix::zeros(seq, d);
    let mut row = vec![0.0f32; seq];
    for i in 0..seq {
        let qi = q.row(i);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..seq {
            row[j] = if causal && j > i {
                f32::NEG_INFINITY
            } else {
                let kj = k.row(j);
                let mut dot = 0.0;
                for t in 0..d {
                    dot += qi[t] * kj[t];
                }
                dot * scale
            };
            mx = mx.max(row[j]);
        }
        let mut z = 0.0f32;
        for j in 0..seq {
            if row[j].is_finite() {
                row[j] = (row[j] - mx).exp();
                z += row[j];
            } else {
                row[j] = 0.0;
            }
        }
        let orow = out.row_mut(i);
        for j in 0..seq {
            if row[j] == 0.0 {
                continue;
            }
            let p = row[j] / z;
            let vj = v.row(j);
            for t in 0..d {
                orow[t] += p * vj[t];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::baselines;
    use crate::util::Rng;

    fn qkv(seq: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (Matrix::randn(seq, d, 1.0, &mut rng),
         Matrix::randn(seq, d, 1.0, &mut rng),
         Matrix::randn(seq, d, 1.0, &mut rng))
    }

    #[test]
    fn full_mask_matches_dense() {
        let (q, k, v) = qkv(32, 8, 1);
        let mask = crate::patterns::BlockMask::ones(4, 4);
        let a = block_sparse_attention(&q, &k, &v, &mask, false);
        let b = dense_attention(&q, &k, &v, false);
        assert!(a.max_abs_diff(&b) < 1e-4, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn causal_full_mask_matches_dense_causal() {
        let (q, k, v) = qkv(32, 8, 2);
        let mask = crate::patterns::BlockMask::ones(4, 4);
        let a = block_sparse_attention(&q, &k, &v, &mask, true);
        let b = dense_attention(&q, &k, &v, true);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn sparse_mask_matches_masked_dense() {
        let (q, k, v) = qkv(32, 8, 3);
        let mask = baselines::pixelfly_attention_mask(4, 2, 1);
        let a = block_sparse_attention(&q, &k, &v, &mask, false);
        // masked-dense oracle: -inf outside visible blocks
        let seq = 32;
        let b = 8;
        let mut kk = k.clone();
        // build by zeroing via huge negative scores: emulate by computing
        // dense attention over a k whose invisible rows can't be seen from
        // each q row — do it directly instead:
        let scale = 1.0 / (8f32).sqrt();
        let mut want = Matrix::zeros(seq, 8);
        for i in 0..seq {
            let qb = i / b;
            let mut row = vec![f32::NEG_INFINITY; seq];
            let mut mx = f32::NEG_INFINITY;
            for j in 0..seq {
                if mask.get(qb, j / b) {
                    let mut dot = 0.0;
                    for t in 0..8 {
                        dot += q.get(i, t) * kk.get(j, t);
                    }
                    row[j] = dot * scale;
                    mx = mx.max(row[j]);
                }
            }
            let mut z = 0.0;
            for j in 0..seq {
                if row[j].is_finite() {
                    row[j] = (row[j] - mx).exp();
                    z += row[j];
                } else {
                    row[j] = 0.0;
                }
            }
            for j in 0..seq {
                if row[j] > 0.0 {
                    for t in 0..8 {
                        let w = want.get(i, t) + row[j] / z * v.get(j, t);
                        want.set(i, t, w);
                    }
                }
            }
        }
        kk.data.clear(); // silence unused-mut lint paths
        assert!(a.max_abs_diff(&want) < 1e-4, "{}", a.max_abs_diff(&want));
    }

    #[test]
    fn parallel_split_matches_dense() {
        // big enough to clear the parallel threshold, so the weighted
        // split + scoped workers actually run (when >1 core is available)
        let (q, k, v) = qkv(512, 16, 5);
        let mask = crate::patterns::BlockMask::ones(16, 16);
        let a = block_sparse_attention(&q, &k, &v, &mask, true);
        let b = dense_attention(&q, &k, &v, true);
        assert!(a.max_abs_diff(&b) < 1e-3, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn output_rows_are_convex_combinations() {
        let (q, k, _) = qkv(16, 4, 4);
        let v = Matrix::from_vec(16, 4, vec![1.0; 64]);
        let mask = baselines::pixelfly_attention_mask(4, 2, 0);
        let o = block_sparse_attention(&q, &k, &v, &mask, false);
        for x in &o.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }
}
