//! Fused streaming block-sparse attention on the Rust substrate (measured
//! counterpart of the Pallas kernel; used by the Fig 7 / Fig 9 benches and
//! the Reformer-style baseline, whose per-batch mask makes AOT impossible —
//! exactly the paper's point about dynamic sparsity).
//!
//! Layout: q, k, v are [seq, d] row-major (single head; callers loop
//! heads). The engine mirrors the BSR GEMM plan/executor split:
//!
//! - [`AttnPlan`] inverts the [`BlockMask`] once into per-query-block-row
//!   visible-key lists (causal-filtered at block level), partitions the
//!   block rows into chunks weighted by visible blocks, and carries a
//!   structure fingerprint; plans are cached process-wide by
//!   (mask, causal, threads), mirroring `BsrMatrix::plan`.
//! - [`AttnPlan::execute`] is the fused single-pass kernel: one `b×b`
//!   score tile + a running (max, denominator, output-accumulator) per
//!   query row — the online softmax of `kernels/attention.py` — so no
//!   `seq×seq` (or even per-row `seq`-length) score buffer ever exists.
//!   Scratch is O(b² + b·d) per worker, L1-resident, and checked out of a
//!   [`Workspace`] so the steady state is allocation-free.
//! - Chunks run as nnz-weighted tasks on the engine pool
//!   ([`pool::run_tasks_scratch`]): chunks partition the query block
//!   rows, so each worker owns a disjoint slice of the output by
//!   construction, and each participant's b²-scale scratch is pinned to
//!   the worker itself (resident workers own their workspace).
//! - The inner products / AXPYs route through the kernel dispatch tier
//!   ([`exec::simd`]): AVX2/NEON where available, scalar otherwise.
//!
//! [`AttnPlan::execute_materializing`] keeps the pre-fusion two-pass
//! kernel (per-row `seq`-length score buffer) as the memory-traffic
//! baseline the Fig 7 bench reports against, and [`dense_attention`] /
//! [`dense_attention_masked`] are the O(seq²) correctness oracles.

use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

use crate::patterns::BlockMask;
use crate::sparse::dense::Matrix;
use crate::sparse::exec::{self, pool, simd, Workspace};

/// Target chunks per worker; >1 so the pull-based cursor can rebalance.
const CHUNKS_PER_THREAD: usize = 4;

/// Plans cached process-wide (attention masks recur across layers/steps).
const PLAN_CACHE_CAP: usize = 32;

/// Reusable execution schedule for one (mask, causal, threads) attention
/// structure — the attention counterpart of [`exec::GemmPlan`].
#[derive(Debug)]
pub struct AttnPlan {
    nb: usize,
    causal: bool,
    threads: usize,
    fingerprint: u64,
    /// `row_ptr[qb]..row_ptr[qb+1]` indexes `kbs` for query block row qb
    row_ptr: Vec<usize>,
    /// visible key blocks per query block row, causal-filtered
    kbs: Vec<u32>,
    /// ranges over query block rows, balanced by visible-block weight
    chunks: Vec<Range<usize>>,
    /// reverse schedule (the same row-owned inversion trick as the GEMM
    /// plan): `kb_ptr[kb]..kb_ptr[kb+1]` indexes `qbs` — the query block
    /// rows that see key block `kb`. dK/dV rows are owned key-side, so
    /// the backward pass is race-free without atomics or replication.
    kb_ptr: Vec<usize>,
    qbs: Vec<u32>,
    /// ranges over key block rows, balanced by visible-block weight
    key_chunks: Vec<Range<usize>>,
    visible_blocks: usize,
}

/// Per-row softmax statistics the fused forward stashes for the
/// recompute backward: `m[i]` is the running max, `l[i]` the softmax
/// denominator of query row `i` (`l == 0` marks a fully masked row).
/// `O(seq)` floats — the whole price of never materialising `seq×seq`
/// probabilities for the backward pass. Buffers grow on first use and
/// are reused in place afterwards (steady-state zero-alloc).
#[derive(Clone, Debug, Default)]
pub struct AttnStats {
    pub m: Vec<f32>,
    pub l: Vec<f32>,
}

impl AttnStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, seq: usize) {
        self.m.resize(seq, 0.0);
        self.l.resize(seq, 0.0);
    }
}

/// Fingerprint of the mask support + causal flag (the schedule — and the
/// cache identity — depend on exactly these), through the engine-wide
/// FNV-1a helper shared with the GEMM plan.
fn mask_fingerprint(mask: &BlockMask, causal: bool) -> u64 {
    let set_bits = (0..mask.rows)
        .flat_map(|r| (0..mask.cols).map(move |c| (r, c)))
        .filter(|&(r, c)| mask.get(r, c))
        .map(|(r, c)| (r * mask.cols + c) as u64);
    exec::plan::fnv1a(
        [mask.rows as u64, mask.cols as u64, causal as u64]
            .into_iter()
            .chain(set_bits),
    )
}

impl AttnPlan {
    /// Build the schedule for `mask` targeting `threads` workers. Causal
    /// filtering happens here, at block granularity, so the executor only
    /// ever masks inside diagonal blocks.
    pub fn new(mask: &BlockMask, causal: bool, threads: usize) -> Self {
        assert_eq!(mask.rows, mask.cols, "attention masks are square over seq blocks");
        let nb = mask.rows;
        let threads = threads.max(1);
        let mut row_ptr = Vec::with_capacity(nb + 1);
        let mut kbs = Vec::new();
        row_ptr.push(0);
        for qb in 0..nb {
            for kb in 0..nb {
                if mask.get(qb, kb) && (!causal || kb <= qb) {
                    kbs.push(kb as u32);
                }
            }
            row_ptr.push(kbs.len());
        }
        let weights: Vec<usize> =
            (0..nb).map(|qb| (row_ptr[qb + 1] - row_ptr[qb]).max(1)).collect();
        let chunks = pool::weighted_ranges(&weights, threads * CHUNKS_PER_THREAD);

        // invert the visibility lists once for the backward pass: which
        // query block rows see each key block (counting sort, O(nnz))
        let mut kb_ptr = vec![0usize; nb + 1];
        for &kb in &kbs {
            kb_ptr[kb as usize + 1] += 1;
        }
        for kb in 0..nb {
            kb_ptr[kb + 1] += kb_ptr[kb];
        }
        let mut qbs = vec![0u32; kbs.len()];
        let mut cursor = kb_ptr.clone();
        for qb in 0..nb {
            for s in row_ptr[qb]..row_ptr[qb + 1] {
                let kb = kbs[s] as usize;
                qbs[cursor[kb]] = qb as u32;
                cursor[kb] += 1;
            }
        }
        let key_weights: Vec<usize> =
            (0..nb).map(|kb| (kb_ptr[kb + 1] - kb_ptr[kb]).max(1)).collect();
        let key_chunks = pool::weighted_ranges(&key_weights, threads * CHUNKS_PER_THREAD);

        AttnPlan {
            nb,
            causal,
            threads,
            fingerprint: mask_fingerprint(mask, causal),
            visible_blocks: kbs.len(),
            row_ptr,
            kbs,
            chunks,
            kb_ptr,
            qbs,
            key_chunks,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn causal(&self) -> bool {
        self.causal
    }

    /// Side length of the block grid this plan was built over (`nb`);
    /// `seq / nb` recovers the block size for a given sequence.
    pub fn grid_blocks(&self) -> usize {
        self.nb
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Visible (query block, key block) pairs after causal filtering —
    /// the nnz that governs both work and the flop count.
    pub fn visible_blocks(&self) -> usize {
        self.visible_blocks
    }

    /// Flops of one execution at block size `b`, head dim `d`
    /// (qk^T and p·v are 2·b²·d each per visible block).
    pub fn flops(&self, b: usize, d: usize) -> f64 {
        (self.visible_blocks * 4 * b * b * d) as f64
    }

    /// Per-worker scratch elements at block size `b`, head dim `d`: one
    /// b×b score tile + running max + denominator + b×d accumulator.
    /// Crucially independent of `seq` — the bench harness asserts this is
    /// the whole scratch footprint.
    pub fn scratch_elems(b: usize, d: usize) -> usize {
        b * b + 2 * b + b * d
    }

    /// Per-worker scratch elements of the recompute backward: one b×b
    /// probability tile (scores are recomputed from Q·Kᵀ + the stored
    /// stats, never stored at `seq` scale). The shared `O(seq)` row of
    /// `D = dot(dO_i, O_i)` values comes on top, once, not per worker.
    pub fn backward_scratch_elems(b: usize) -> usize {
        b * b
    }

    fn workers_for(&self, b: usize, d: usize) -> usize {
        if self.threads <= 1 || self.flops(b, d) < exec::par_threshold_flops() {
            1
        } else {
            self.threads.min(self.chunks.len()).max(1)
        }
    }

    /// Validate q/k/v/out shapes against the plan grid; returns (b, d).
    fn check_shapes(&self, q: &Matrix, k: &Matrix, v: &Matrix, out: &Matrix)
                    -> (usize, usize) {
        let (seq, d) = (q.rows, q.cols);
        assert_eq!((k.rows, k.cols), (seq, d));
        assert_eq!((v.rows, v.cols), (seq, d));
        assert_eq!((out.rows, out.cols), (seq, d));
        assert_eq!(seq % self.nb, 0, "seq must be divisible by the mask grid");
        (seq / self.nb, d)
    }

    /// Shared executor skeleton for both kernels: runs
    /// `f(qb, out_rows, scratch)` over every query block row as chunk
    /// tasks on the pool, each participant carrying `per` floats of
    /// private scratch — resident workers draw theirs from their own
    /// pinned workspace, the caller from `ws`
    /// ([`pool::run_tasks_scratch`]). The unsafe disjoint-write argument
    /// lives here, once.
    fn run_block_rows<F>(&self, out: &mut Matrix, b: usize, d: usize, per: usize,
                         ws: &mut Workspace, f: F)
    where
        F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
    {
        let workers = self.workers_for(b, d);
        let base = pool::SyncPtr(out.data.as_mut_ptr());
        pool::run_tasks_scratch(self.chunks.len(), workers, per, ws, |scratch, c| {
            // capture the whole wrapper (not the raw-pointer field) so
            // the closure stays Sync under edition-2021 precise capture
            let base = &base;
            for qb in self.chunks[c].clone() {
                // Safety: chunks partition 0..nb, so this task owns
                // output rows qb*b..(qb+1)*b exclusively; bounds
                // follow from the caller's shape asserts.
                let orows = unsafe {
                    std::slice::from_raw_parts_mut(base.0.add(qb * b * d), b * d)
                };
                f(qb, orows, scratch);
            }
        });
    }

    /// Fused single-pass execution: `out = softmax(q·kᵀ/√d ⊙ mask)·v`.
    /// Scratch comes from `ws` (zero-alloc once warm).
    pub fn execute(&self, q: &Matrix, k: &Matrix, v: &Matrix, out: &mut Matrix,
                   ws: &mut Workspace) {
        self.execute_impl(q, k, v, out, ws, None);
    }

    /// Fused forward that additionally stashes the per-row softmax
    /// statistics `(max, denom)` into `stats` — the `O(seq)` state the
    /// Flash-style [`Self::backward`] needs to recompute probability
    /// tiles instead of storing them. Costs two extra scalar writes per
    /// query row over [`Self::execute`]; numerics are identical.
    pub fn execute_stats(&self, q: &Matrix, k: &Matrix, v: &Matrix, out: &mut Matrix,
                         stats: &mut AttnStats, ws: &mut Workspace) {
        stats.ensure(q.rows);
        let ptrs = (stats.m.as_mut_ptr(), stats.l.as_mut_ptr());
        self.execute_impl(q, k, v, out, ws, Some(ptrs));
    }

    fn execute_impl(&self, q: &Matrix, k: &Matrix, v: &Matrix, out: &mut Matrix,
                    ws: &mut Workspace, stats: Option<(*mut f32, *mut f32)>) {
        let (b, d) = self.check_shapes(q, k, v, out);
        let scale = 1.0 / (d as f32).sqrt();
        // resolve the kernel tier once; the inner loops call the
        // pre-resolved primitives
        let tier = simd::active_tier();
        let sp: Option<(pool::SyncPtr<f32>, pool::SyncPtr<f32>)> =
            stats.map(|(m, l)| (pool::SyncPtr(m), pool::SyncPtr(l)));
        self.run_block_rows(out, b, d, Self::scratch_elems(b, d), ws,
                            |qb, orows, scratch| {
            let sp = &sp;
            self.fused_block_row(tier, q, k, v, scale, b, d, qb, orows, scratch,
                                 sp.as_ref().map(|(m, l)| (m.0, l.0)));
        });
    }

    /// One query block row, streaming over its visible key blocks with an
    /// online-softmax accumulator. `scratch` is `scratch_elems(b, d)`
    /// floats; `out_rows` is exactly this block row of the output. When
    /// `stats` carries the (m, l) base pointers, the final per-row max
    /// and denominator are stashed there for the recompute backward.
    #[allow(clippy::too_many_arguments)]
    fn fused_block_row(&self, tier: simd::Tier, q: &Matrix, k: &Matrix, v: &Matrix,
                       scale: f32, b: usize, d: usize, qb: usize,
                       out_rows: &mut [f32], scratch: &mut [f32],
                       stats: Option<(*mut f32, *mut f32)>) {
        let (scores, rest) = scratch.split_at_mut(b * b);
        let (m, rest) = rest.split_at_mut(b);
        let (l, acc_all) = rest.split_at_mut(b);
        let acc = &mut acc_all[..b * d];
        m.fill(f32::NEG_INFINITY);
        l.fill(0.0);
        acc.fill(0.0);
        for &kb in &self.kbs[self.row_ptr[qb]..self.row_ptr[qb + 1]] {
            let kb = kb as usize;
            // score tile S = (Q_qb · K_kbᵀ)·scale — b×b, L1-resident
            for qi in 0..b {
                let qrow = q.row(qb * b + qi);
                let srow = &mut scores[qi * b..(qi + 1) * b];
                for (ki, s) in srow.iter_mut().enumerate() {
                    *s = simd::dot_with(tier, qrow, k.row(kb * b + ki)) * scale;
                }
                if self.causal && kb == qb {
                    // inside the diagonal block, kpos > qpos ⇔ ki > qi
                    for s in srow[qi + 1..].iter_mut() {
                        *s = f32::NEG_INFINITY;
                    }
                }
            }
            // online-softmax update per query row
            for qi in 0..b {
                let srow = &scores[qi * b..(qi + 1) * b];
                let row_max = srow.iter().fold(f32::NEG_INFINITY, |a, &s| a.max(s));
                if row_max == f32::NEG_INFINITY {
                    continue;
                }
                let m_new = m[qi].max(row_max);
                // exp(-inf - finite) = 0, so a fresh row rescales cleanly
                let alpha = (m[qi] - m_new).exp();
                l[qi] *= alpha;
                let arow = &mut acc[qi * d..(qi + 1) * d];
                if alpha != 1.0 {
                    simd::scale_with(tier, arow, alpha);
                }
                for (ki, &s) in srow.iter().enumerate() {
                    if s == f32::NEG_INFINITY {
                        continue;
                    }
                    let p = (s - m_new).exp();
                    l[qi] += p;
                    simd::axpy_with(tier, p, v.row(kb * b + ki), arow);
                }
                m[qi] = m_new;
            }
        }
        if let Some((mp, lp)) = stats {
            // Safety: this task exclusively owns query rows
            // qb*b..(qb+1)*b of the stats vectors (same ownership
            // argument as out_rows); both were sized to seq by the
            // caller.
            unsafe {
                for qi in 0..b {
                    *mp.add(qb * b + qi) = m[qi];
                    *lp.add(qb * b + qi) = l[qi];
                }
            }
        }
        for qi in 0..b {
            let inv = 1.0 / l[qi].max(1e-30);
            let arow = &acc[qi * d..(qi + 1) * d];
            let orow = &mut out_rows[qi * d..(qi + 1) * d];
            for (o, &a) in orow.iter_mut().zip(arow) {
                *o = a * inv;
            }
        }
    }

    /// The visible key blocks of query block row `qb` (causal-filtered
    /// at plan-build time) — the incremental decode path streams exactly
    /// this list.
    pub fn visible_key_blocks(&self, qb: usize) -> &[u32] {
        &self.kbs[self.row_ptr[qb]..self.row_ptr[qb + 1]]
    }

    /// Single-query fused attention against cached K/V slabs — the
    /// incremental decode counterpart of [`Self::execute`]. Replays
    /// `fused_block_row` (same visible-block order, same diagonal
    /// masking, same online-softmax update sequence) for ONE query row
    /// at sequence position `pos`, so a token decoded against the cache
    /// is bit-identical to the same row of a full prefill — O(visible
    /// keys · d) instead of O(seq² · d) per generated token.
    ///
    /// `kcache`/`vcache` are `[max_seq, d]` row-major slabs of one cache
    /// slot; rows at positions `> pos` are never read (stale data there
    /// is fine), because the plan is causal and the diagonal block masks
    /// `ki > qi` before the reduction. `out` doubles as the accumulator;
    /// `scores` is caller scratch of at least `max_seq / grid_blocks`
    /// floats.
    pub fn decode_query(&self, q: &[f32], kcache: &[f32], vcache: &[f32],
                        pos: usize, out: &mut [f32], scores: &mut [f32]) {
        assert!(self.causal, "incremental decode requires a causal plan");
        let d = q.len();
        assert_eq!(out.len(), d);
        assert_eq!(kcache.len(), vcache.len());
        assert_eq!(kcache.len() % d, 0);
        let max_seq = kcache.len() / d;
        assert_eq!(max_seq % self.nb, 0, "cache rows must be divisible by the \
                                          mask grid");
        let b = max_seq / self.nb;
        assert!(pos < max_seq);
        assert!(scores.len() >= b, "need one score per key row of a block");
        let scale = 1.0 / (d as f32).sqrt();
        let tier = simd::active_tier();
        let qb = pos / b;
        let qi = pos - qb * b;
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        out.fill(0.0);
        for &kb in &self.kbs[self.row_ptr[qb]..self.row_ptr[qb + 1]] {
            let kb = kb as usize;
            let srow = &mut scores[..b];
            for (ki, s) in srow.iter_mut().enumerate() {
                let krow = &kcache[(kb * b + ki) * d..(kb * b + ki + 1) * d];
                *s = simd::dot_with(tier, q, krow) * scale;
            }
            if kb == qb {
                // inside the diagonal block, kpos > pos ⇔ ki > qi
                for s in srow[qi + 1..].iter_mut() {
                    *s = f32::NEG_INFINITY;
                }
            }
            let row_max = srow.iter().fold(f32::NEG_INFINITY, |a, &s| a.max(s));
            if row_max == f32::NEG_INFINITY {
                continue;
            }
            let m_new = m.max(row_max);
            let alpha = (m - m_new).exp();
            l *= alpha;
            if alpha != 1.0 {
                simd::scale_with(tier, out, alpha);
            }
            for (ki, &s) in srow.iter().enumerate() {
                if s == f32::NEG_INFINITY {
                    continue;
                }
                let p = (s - m_new).exp();
                l += p;
                let vrow = &vcache[(kb * b + ki) * d..(kb * b + ki + 1) * d];
                simd::axpy_with(tier, p, vrow, out);
            }
            m = m_new;
        }
        let inv = 1.0 / l.max(1e-30);
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    /// The pre-fusion two-pass kernel: per query row, materialise a
    /// `seq`-length score buffer over the visible blocks, then softmax,
    /// then the weighted V pass. Kept as the memory-traffic baseline the
    /// Fig 7 bench compares the fused path against (same schedule, same
    /// parallelism — the delta is purely the materialisation).
    pub fn execute_materializing(&self, q: &Matrix, k: &Matrix, v: &Matrix,
                                 out: &mut Matrix, ws: &mut Workspace) {
        let (b, d) = self.check_shapes(q, k, v, out);
        let seq = q.rows;
        let scale = 1.0 / (d as f32).sqrt();
        let tier = simd::active_tier();
        self.run_block_rows(out, b, d, seq, ws, |qb, orows, scratch| {
            self.two_pass_block_row(tier, q, k, v, scale, b, d, seq, qb, orows, scratch);
        });
    }

    #[allow(clippy::too_many_arguments)]
    fn two_pass_block_row(&self, tier: simd::Tier, q: &Matrix, k: &Matrix, v: &Matrix,
                          scale: f32, b: usize, d: usize, seq: usize, qb: usize,
                          out_rows: &mut [f32], scores: &mut [f32]) {
        let kbs = &self.kbs[self.row_ptr[qb]..self.row_ptr[qb + 1]];
        for qi in 0..b {
            let qpos = qb * b + qi;
            let qrow = q.row(qpos);
            let orow = &mut out_rows[qi * d..(qi + 1) * d];
            orow.fill(0.0);
            // pass 1: materialise the full seq-length score row (the
            // traffic the fused kernel exists to avoid)
            scores.fill(f32::NEG_INFINITY);
            let mut mx = f32::NEG_INFINITY;
            for &kb in kbs {
                let kb = kb as usize;
                for ki in 0..b {
                    let kpos = kb * b + ki;
                    if self.causal && kpos > qpos {
                        continue;
                    }
                    let s = simd::dot_with(tier, qrow, k.row(kpos)) * scale;
                    scores[kpos] = s;
                    mx = mx.max(s);
                }
            }
            if mx == f32::NEG_INFINITY {
                continue;
            }
            // pass 2: softmax + weighted V
            let mut z = 0.0f32;
            for s in scores.iter_mut() {
                if s.is_finite() {
                    *s = (*s - mx).exp();
                    z += *s;
                } else {
                    *s = 0.0;
                }
            }
            let inv = 1.0 / z.max(1e-30);
            for (j, &p) in scores.iter().enumerate() {
                if p > 0.0 {
                    simd::axpy_with(tier, p * inv, v.row(j), orow);
                }
            }
        }
    }

    /// Flash-style recompute backward of the fused kernel:
    /// given `o = execute_stats(q, k, v, …)`, its stashed per-row
    /// `(max, denom)` stats and the upstream gradient `dout`, computes
    /// `dq`, `dk`, `dv` touching only the visible blocks.
    ///
    /// Probability tiles are *recomputed* one `b×b` tile at a time from
    /// `Q·Kᵀ` plus the stats — the `seq×seq` probability matrix never
    /// exists, matching the forward's memory contract. Two phases, both
    /// race-free by ownership:
    ///
    /// 1. **dQ** over the query-side schedule (each task owns its query
    ///    rows): `dS = P ⊙ (dO·Vᵀ − D)`, `dQ += scale·dS·K`, with
    ///    `D_i = dot(dO_i, O_i)` precomputed once into an `O(seq)` row.
    /// 2. **dK/dV** over the *inverted* key-side schedule (each task owns
    ///    its key rows): the same tiles are recomputed transposed-role,
    ///    `dV += Pᵀ·dO`, `dK += scale·dSᵀ·Q`.
    ///
    /// Scratch: one b×b tile per worker + the shared D row — asserted
    /// O(block²), never O(seq²), by the fig1 bench.
    #[allow(clippy::too_many_arguments)]
    pub fn backward(&self, q: &Matrix, k: &Matrix, v: &Matrix, o: &Matrix,
                    dout: &Matrix, stats: &AttnStats,
                    dq: &mut Matrix, dk: &mut Matrix, dv: &mut Matrix,
                    ws: &mut Workspace) {
        let (b, d) = self.check_shapes(q, k, v, o);
        let seq = q.rows;
        for (name, m) in [("dout", &*dout), ("dq", &*dq), ("dk", &*dk), ("dv", &*dv)] {
            assert_eq!((m.rows, m.cols), (seq, d), "{name} shape");
        }
        assert_eq!(stats.m.len(), seq, "stats not from this forward (run execute_stats)");
        assert_eq!(stats.l.len(), seq);
        let scale = 1.0 / (d as f32).sqrt();
        let tier = simd::active_tier();

        // D_i = dot(dO_i, O_i) = Σ_j P_ij·dP_ij: one O(seq·d) serial pass
        // into workspace scratch, shared read-only by both phases
        let mut dvec = ws.take(seq);
        for i in 0..seq {
            dvec[i] = simd::dot_with(tier, dout.row(i), o.row(i));
        }

        let per = Self::backward_scratch_elems(b);
        self.run_block_rows(dq, b, d, per, ws, |qb, dq_rows, scratch| {
            self.backward_q_block_row(tier, q, k, v, dout, stats, &dvec, scale,
                                      b, d, qb, dq_rows, scratch);
        });
        self.run_key_rows(dk, dv, b, d, per, ws, |kb, dk_rows, dv_rows, scratch| {
            self.backward_k_block_row(tier, q, k, v, dout, stats, &dvec, scale,
                                      b, d, kb, dk_rows, dv_rows, scratch);
        });
        ws.give(dvec);
    }

    /// Recompute the probability tile P[qi, ki] of (query block `qb`,
    /// key block `kb`) from Q·Kᵀ and the stored stats:
    /// `P = exp(scale·s − m_row) / l_row`, with the causal diagonal
    /// masked exactly like the forward. Rows with `l == 0` (fully
    /// masked) come out all-zero.
    #[allow(clippy::too_many_arguments)]
    fn prob_tile(&self, tier: simd::Tier, q: &Matrix, k: &Matrix, stats: &AttnStats,
                 scale: f32, b: usize, qb: usize, kb: usize, p: &mut [f32]) {
        for qi in 0..b {
            let qpos = qb * b + qi;
            let prow = &mut p[qi * b..(qi + 1) * b];
            let l = stats.l[qpos];
            if l == 0.0 {
                prow.fill(0.0);
                continue;
            }
            let inv_l = 1.0 / l;
            let m = stats.m[qpos];
            let qrow = q.row(qpos);
            // inside the diagonal block, kpos > qpos ⇔ ki > qi
            let lim = if self.causal && kb == qb { qi + 1 } else { b };
            for (ki, pv) in prow[..lim].iter_mut().enumerate() {
                let s = simd::dot_with(tier, qrow, k.row(kb * b + ki)) * scale;
                *pv = (s - m).exp() * inv_l;
            }
            prow[lim..].fill(0.0);
        }
    }

    /// Phase 1: dQ rows of one query block row (exclusively owned).
    #[allow(clippy::too_many_arguments)]
    fn backward_q_block_row(&self, tier: simd::Tier, q: &Matrix, k: &Matrix,
                            v: &Matrix, dout: &Matrix, stats: &AttnStats,
                            dvec: &[f32], scale: f32, b: usize, d: usize,
                            qb: usize, dq_rows: &mut [f32], scratch: &mut [f32]) {
        let p_tile = &mut scratch[..b * b];
        dq_rows.fill(0.0);
        for &kb in &self.kbs[self.row_ptr[qb]..self.row_ptr[qb + 1]] {
            let kb = kb as usize;
            self.prob_tile(tier, q, k, stats, scale, b, qb, kb, p_tile);
            for qi in 0..b {
                let qpos = qb * b + qi;
                if stats.l[qpos] == 0.0 {
                    continue;
                }
                let dorow = dout.row(qpos);
                let prow = &p_tile[qi * b..(qi + 1) * b];
                let dqrow = &mut dq_rows[qi * d..(qi + 1) * d];
                for (ki, &pv) in prow.iter().enumerate() {
                    if pv == 0.0 {
                        continue;
                    }
                    let kpos = kb * b + ki;
                    // dS = P ⊙ (dP − D), dP[qi,ki] = dot(dO_qi, V_ki)
                    let ds = pv * (simd::dot_with(tier, dorow, v.row(kpos)) - dvec[qpos]);
                    simd::axpy_with(tier, scale * ds, k.row(kpos), dqrow);
                }
            }
        }
    }

    /// Phase 2: dK/dV rows of one key block row (exclusively owned via
    /// the inverted schedule).
    #[allow(clippy::too_many_arguments)]
    fn backward_k_block_row(&self, tier: simd::Tier, q: &Matrix, k: &Matrix,
                            v: &Matrix, dout: &Matrix, stats: &AttnStats,
                            dvec: &[f32], scale: f32, b: usize, d: usize, kb: usize,
                            dk_rows: &mut [f32], dv_rows: &mut [f32],
                            scratch: &mut [f32]) {
        let p_tile = &mut scratch[..b * b];
        dk_rows.fill(0.0);
        dv_rows.fill(0.0);
        for &qb in &self.qbs[self.kb_ptr[kb]..self.kb_ptr[kb + 1]] {
            let qb = qb as usize;
            self.prob_tile(tier, q, k, stats, scale, b, qb, kb, p_tile);
            for qi in 0..b {
                let qpos = qb * b + qi;
                if stats.l[qpos] == 0.0 {
                    continue;
                }
                let dorow = dout.row(qpos);
                let qrow = q.row(qpos);
                let prow = &p_tile[qi * b..(qi + 1) * b];
                for (ki, &pv) in prow.iter().enumerate() {
                    if pv == 0.0 {
                        continue;
                    }
                    let kpos = kb * b + ki;
                    let ds = pv * (simd::dot_with(tier, dorow, v.row(kpos)) - dvec[qpos]);
                    simd::axpy_with(tier, pv, dorow, &mut dv_rows[ki * d..(ki + 1) * d]);
                    simd::axpy_with(tier, scale * ds, qrow, &mut dk_rows[ki * d..(ki + 1) * d]);
                }
            }
        }
    }

    /// Key-side twin of [`Self::run_block_rows`]: hands each task the
    /// dK and dV row slices of the key block rows its chunk owns, plus
    /// the participant's private scratch. Chunks partition 0..nb over
    /// `key_chunks`, so the disjoint-write argument is identical.
    fn run_key_rows<F>(&self, dk: &mut Matrix, dv: &mut Matrix, b: usize, d: usize,
                       per: usize, ws: &mut Workspace, f: F)
    where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        let workers = self.workers_for(b, d);
        let dk_base = pool::SyncPtr(dk.data.as_mut_ptr());
        let dv_base = pool::SyncPtr(dv.data.as_mut_ptr());
        pool::run_tasks_scratch(self.key_chunks.len(), workers, per, ws,
                                |scratch, c| {
            let dk_base = &dk_base;
            let dv_base = &dv_base;
            for kb in self.key_chunks[c].clone() {
                // Safety: key chunks partition 0..nb, so this task
                // owns dk/dv rows kb*b..(kb+1)*b exclusively; bounds
                // follow from the caller's shape asserts.
                let dk_rows = unsafe {
                    std::slice::from_raw_parts_mut(dk_base.0.add(kb * b * d), b * d)
                };
                let dv_rows = unsafe {
                    std::slice::from_raw_parts_mut(dv_base.0.add(kb * b * d), b * d)
                };
                f(kb, dk_rows, dv_rows, scratch);
            }
        });
    }
}

fn plan_cache() -> &'static Mutex<Vec<Arc<AttnPlan>>> {
    static CACHE: OnceLock<Mutex<Vec<Arc<AttnPlan>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(Vec::new()))
}

/// Fetch (or build and cache) the plan for this structure — the attention
/// analogue of the `BsrMatrix` plan cache, keyed by the mask fingerprint,
/// causal flag and thread count.
pub fn plan_for(mask: &BlockMask, causal: bool, threads: usize) -> Arc<AttnPlan> {
    let threads = threads.max(1);
    let fp = mask_fingerprint(mask, causal);
    let mut cache = plan_cache().lock().unwrap();
    if let Some(p) = cache
        .iter()
        .find(|p| p.fingerprint == fp && p.causal == causal && p.threads == threads
                  && p.nb == mask.rows)
    {
        return Arc::clone(p);
    }
    let p = Arc::new(AttnPlan::new(mask, causal, threads));
    cache.push(Arc::clone(&p));
    if cache.len() > PLAN_CACHE_CAP {
        cache.remove(0);
    }
    p
}

/// Fused streaming block-sparse attention for one head (allocating
/// wrapper: plans from the process cache, scratch from the thread-local
/// workspace, so even this path is zero-alloc in steady state apart from
/// the output itself).
pub fn block_sparse_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                              mask: &BlockMask, causal: bool) -> Matrix {
    let mut out = Matrix::zeros(q.rows, q.cols);
    block_sparse_attention_into(q, k, v, mask, causal, &mut out);
    out
}

/// Fused attention into a caller-owned output (scratch from the
/// thread-local workspace).
pub fn block_sparse_attention_into(q: &Matrix, k: &Matrix, v: &Matrix,
                                   mask: &BlockMask, causal: bool,
                                   out: &mut Matrix) {
    let plan = plan_for(mask, causal, exec::threads());
    exec::workspace::with_thread_workspace(|ws| plan.execute(q, k, v, out, ws));
}

/// Dense attention reference (oracle).
pub fn dense_attention(q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
    dense_attention_impl(q, k, v, None, causal)
}

/// Masked dense attention reference: softmax over exactly the positions
/// the block mask admits. The O(seq²) oracle the fused engine is tested
/// against on sparse masks (rows with no visible key get a zero output,
/// matching the streaming kernel's convention).
pub fn dense_attention_masked(q: &Matrix, k: &Matrix, v: &Matrix,
                              mask: &BlockMask, causal: bool) -> Matrix {
    dense_attention_impl(q, k, v, Some(mask), causal)
}

fn dense_attention_impl(q: &Matrix, k: &Matrix, v: &Matrix,
                        mask: Option<&BlockMask>, causal: bool) -> Matrix {
    let (seq, d) = (q.rows, q.cols);
    let b = mask.map(|m| {
        assert_eq!(m.rows, m.cols, "attention masks are square over seq blocks");
        assert_eq!(seq % m.rows, 0);
        seq / m.rows
    });
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Matrix::zeros(seq, d);
    let mut row = vec![0.0f32; seq];
    for i in 0..seq {
        let qi = q.row(i);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..seq {
            let visible = !(causal && j > i)
                && mask.map_or(true, |m| m.get(i / b.unwrap(), j / b.unwrap()));
            row[j] = if !visible {
                f32::NEG_INFINITY
            } else {
                let kj = k.row(j);
                let mut dot = 0.0;
                for t in 0..d {
                    dot += qi[t] * kj[t];
                }
                dot * scale
            };
            mx = mx.max(row[j]);
        }
        if mx == f32::NEG_INFINITY {
            continue; // fully masked row: zero output
        }
        let mut z = 0.0f32;
        for j in 0..seq {
            if row[j].is_finite() {
                row[j] = (row[j] - mx).exp();
                z += row[j];
            } else {
                row[j] = 0.0;
            }
        }
        let orow = out.row_mut(i);
        for j in 0..seq {
            if row[j] == 0.0 {
                continue;
            }
            let p = row[j] / z;
            let vj = v.row(j);
            for t in 0..d {
                orow[t] += p * vj[t];
            }
        }
    }
    out
}

/// Dense backward oracle for masked attention (O(seq²), tests only):
/// textbook softmax-attention gradients `dV = Pᵀ·dO`,
/// `dS = P ⊙ (dO·Vᵀ − rowsum(P ⊙ dO·Vᵀ))`, `dQ = scale·dS·K`,
/// `dK = scale·dSᵀ·Q`, over exactly the positions the block mask (and
/// the causal flag) admit. The engine backward is tested against this.
pub fn dense_attention_backward_masked(q: &Matrix, k: &Matrix, v: &Matrix,
                                       dout: &Matrix, mask: &BlockMask,
                                       causal: bool) -> (Matrix, Matrix, Matrix) {
    let (seq, d) = (q.rows, q.cols);
    assert_eq!((k.rows, k.cols), (seq, d));
    assert_eq!((v.rows, v.cols), (seq, d));
    assert_eq!((dout.rows, dout.cols), (seq, d));
    assert_eq!(mask.rows, mask.cols, "attention masks are square over seq blocks");
    assert_eq!(seq % mask.rows, 0);
    let b = seq / mask.rows;
    let scale = 1.0 / (d as f32).sqrt();
    let mut dq = Matrix::zeros(seq, d);
    let mut dk = Matrix::zeros(seq, d);
    let mut dv = Matrix::zeros(seq, d);
    let mut s = vec![0.0f32; seq];
    let mut dp = vec![0.0f32; seq];
    for i in 0..seq {
        let qi = q.row(i);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..seq {
            let visible = !(causal && j > i) && mask.get(i / b, j / b);
            s[j] = if !visible {
                f32::NEG_INFINITY
            } else {
                let kj = k.row(j);
                let mut dot = 0.0;
                for t in 0..d {
                    dot += qi[t] * kj[t];
                }
                dot * scale
            };
            mx = mx.max(s[j]);
        }
        if mx == f32::NEG_INFINITY {
            continue; // fully masked row: zero output, zero gradient
        }
        let mut z = 0.0f32;
        for sj in s.iter_mut() {
            if sj.is_finite() {
                *sj = (*sj - mx).exp();
                z += *sj;
            } else {
                *sj = 0.0;
            }
        }
        for sj in s.iter_mut() {
            *sj /= z; // s now holds P row i
        }
        let doi = dout.row(i);
        let mut dsum = 0.0f32; // D_i = Σ_j P_ij·dP_ij
        for j in 0..seq {
            dp[j] = if s[j] > 0.0 {
                let vj = v.row(j);
                let mut dot = 0.0;
                for t in 0..d {
                    dot += doi[t] * vj[t];
                }
                dot
            } else {
                0.0
            };
            dsum += s[j] * dp[j];
        }
        for j in 0..seq {
            if s[j] == 0.0 {
                continue;
            }
            let ds = s[j] * (dp[j] - dsum);
            let kj = k.row(j);
            for t in 0..d {
                dq.data[i * d + t] += scale * ds * kj[t];
            }
            let qrow = q.row(i);
            let doi = dout.row(i);
            for t in 0..d {
                dk.data[j * d + t] += scale * ds * qrow[t];
                dv.data[j * d + t] += s[j] * doi[t];
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::baselines;
    use crate::util::Rng;

    fn qkv(seq: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (Matrix::randn(seq, d, 1.0, &mut rng),
         Matrix::randn(seq, d, 1.0, &mut rng),
         Matrix::randn(seq, d, 1.0, &mut rng))
    }

    #[test]
    fn full_mask_matches_dense() {
        let (q, k, v) = qkv(32, 8, 1);
        let mask = crate::patterns::BlockMask::ones(4, 4);
        let a = block_sparse_attention(&q, &k, &v, &mask, false);
        let b = dense_attention(&q, &k, &v, false);
        assert!(a.max_abs_diff(&b) < 1e-4, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn causal_full_mask_matches_dense_causal() {
        let (q, k, v) = qkv(32, 8, 2);
        let mask = crate::patterns::BlockMask::ones(4, 4);
        let a = block_sparse_attention(&q, &k, &v, &mask, true);
        let b = dense_attention(&q, &k, &v, true);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn sparse_mask_matches_masked_dense() {
        let (q, k, v) = qkv(32, 8, 3);
        let mask = baselines::pixelfly_attention_mask(4, 2, 1);
        let a = block_sparse_attention(&q, &k, &v, &mask, false);
        let want = dense_attention_masked(&q, &k, &v, &mask, false);
        assert!(a.max_abs_diff(&want) < 1e-4, "{}", a.max_abs_diff(&want));
    }

    #[test]
    fn causal_sparse_mask_matches_masked_dense() {
        let (q, k, v) = qkv(64, 8, 6);
        let mask = baselines::pixelfly_attention_mask(8, 4, 1);
        let a = block_sparse_attention(&q, &k, &v, &mask, true);
        let want = dense_attention_masked(&q, &k, &v, &mask, true);
        assert!(a.max_abs_diff(&want) < 1e-4, "{}", a.max_abs_diff(&want));
    }

    #[test]
    fn parallel_split_matches_dense() {
        // big enough to clear the parallel threshold, so the chunked
        // executor actually fans out (when >1 core is available)
        let (q, k, v) = qkv(512, 16, 5);
        let mask = crate::patterns::BlockMask::ones(16, 16);
        let a = block_sparse_attention(&q, &k, &v, &mask, true);
        let b = dense_attention(&q, &k, &v, true);
        assert!(a.max_abs_diff(&b) < 1e-3, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn materializing_path_matches_fused() {
        let (q, k, v) = qkv(64, 8, 7);
        let mask = baselines::pixelfly_attention_mask(8, 2, 1);
        for causal in [false, true] {
            let plan = AttnPlan::new(&mask, causal, 2);
            let mut ws = Workspace::new();
            let mut fused = Matrix::zeros(64, 8);
            plan.execute(&q, &k, &v, &mut fused, &mut ws);
            let mut two_pass = Matrix::zeros(64, 8);
            plan.execute_materializing(&q, &k, &v, &mut two_pass, &mut ws);
            assert!(fused.max_abs_diff(&two_pass) < 1e-4,
                    "causal={causal}: {}", fused.max_abs_diff(&two_pass));
        }
    }

    #[test]
    fn output_rows_are_convex_combinations() {
        let (q, k, _) = qkv(16, 4, 4);
        let v = Matrix::from_vec(16, 4, vec![1.0; 64]);
        let mask = baselines::pixelfly_attention_mask(4, 2, 0);
        let o = block_sparse_attention(&q, &k, &v, &mask, false);
        for x in &o.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_mask_rows_produce_zero_output() {
        let (q, k, v) = qkv(32, 8, 8);
        let mut mask = crate::patterns::BlockMask::zeros(4, 4);
        mask.set(0, 0, true); // only the first block row sees anything
        let a = block_sparse_attention(&q, &k, &v, &mask, false);
        let want = dense_attention_masked(&q, &k, &v, &mask, false);
        assert!(a.max_abs_diff(&want) < 1e-4);
        assert!(a.data[8 * 8..].iter().all(|&x| x == 0.0),
                "masked-out rows must be zero");
    }

    #[test]
    fn steady_state_is_zero_alloc_and_scratch_is_block_bounded() {
        let (q, k, v) = qkv(128, 16, 9);
        let mask = crate::patterns::BlockMask::ones(8, 8); // b = 16
        let plan = AttnPlan::new(&mask, false, 2);
        let mut out = Matrix::zeros(128, 16);
        let mut ws = Workspace::new();
        plan.execute(&q, &k, &v, &mut out, &mut ws);
        let warm = ws.alloc_events();
        for _ in 0..3 {
            plan.execute(&q, &k, &v, &mut out, &mut ws);
        }
        assert_eq!(ws.alloc_events(), warm, "hot path must not allocate");
        // scratch is O(workers · (b² + b·d)), never O(seq²) or O(seq)/row
        let bound = 2 * AttnPlan::scratch_elems(16, 16) * 4;
        assert!(ws.peak_bytes() <= bound,
                "peak {} > bound {bound}", ws.peak_bytes());
    }

    #[test]
    fn execute_stats_matches_execute_and_flags_masked_rows() {
        let (q, k, v) = qkv(64, 8, 10);
        let mut mask = crate::patterns::BlockMask::zeros(4, 4);
        mask.set(0, 0, true);
        mask.set(1, 0, true);
        mask.set(3, 2, true);
        let plan = AttnPlan::new(&mask, false, 2);
        let mut ws = Workspace::new();
        let mut plain = Matrix::zeros(64, 8);
        plan.execute(&q, &k, &v, &mut plain, &mut ws);
        let mut out = Matrix::zeros(64, 8);
        let mut stats = AttnStats::new();
        plan.execute_stats(&q, &k, &v, &mut out, &mut stats, &mut ws);
        assert!(out.max_abs_diff(&plain) < 1e-6, "stats variant must not change numerics");
        // visible rows have a positive denominator, masked rows l == 0
        for i in 0..64 {
            let visible = i < 32 || i >= 48; // block rows 0,1,3 see keys
            if visible {
                assert!(stats.l[i] > 0.0, "row {i} should be live");
                assert!(stats.m[i].is_finite());
            } else {
                assert_eq!(stats.l[i], 0.0, "row {i} is fully masked");
            }
        }
    }

    fn engine_backward(plan: &AttnPlan, q: &Matrix, k: &Matrix, v: &Matrix,
                       dout: &Matrix) -> (Matrix, Matrix, Matrix) {
        let (seq, d) = (q.rows, q.cols);
        let mut ws = Workspace::new();
        let mut o = Matrix::zeros(seq, d);
        let mut stats = AttnStats::new();
        plan.execute_stats(q, k, v, &mut o, &mut stats, &mut ws);
        let mut dq = Matrix::zeros(seq, d);
        let mut dk = Matrix::zeros(seq, d);
        let mut dv = Matrix::zeros(seq, d);
        plan.backward(q, k, v, &o, dout, &stats, &mut dq, &mut dk, &mut dv, &mut ws);
        (dq, dk, dv)
    }

    #[test]
    fn backward_matches_dense_oracle_full_mask() {
        let (q, k, v) = qkv(32, 8, 11);
        let dout = Matrix::randn(32, 8, 1.0, &mut Rng::new(12));
        let mask = crate::patterns::BlockMask::ones(4, 4);
        for causal in [false, true] {
            let (wdq, wdk, wdv) =
                dense_attention_backward_masked(&q, &k, &v, &dout, &mask, causal);
            for threads in [1usize, 4] {
                let plan = AttnPlan::new(&mask, causal, threads);
                let (dq, dk, dv) = engine_backward(&plan, &q, &k, &v, &dout);
                assert!(dq.max_abs_diff(&wdq) < 1e-3,
                        "dq causal={causal} threads={threads}: {}", dq.max_abs_diff(&wdq));
                assert!(dk.max_abs_diff(&wdk) < 1e-3,
                        "dk causal={causal} threads={threads}: {}", dk.max_abs_diff(&wdk));
                assert!(dv.max_abs_diff(&wdv) < 1e-3,
                        "dv causal={causal} threads={threads}: {}", dv.max_abs_diff(&wdv));
            }
        }
    }

    #[test]
    fn backward_matches_dense_oracle_sparse_mask_with_empty_rows() {
        let (q, k, v) = qkv(64, 16, 13);
        let dout = Matrix::randn(64, 16, 1.0, &mut Rng::new(14));
        let mut mask = baselines::pixelfly_attention_mask(4, 2, 1);
        // punch out block row 2 entirely: masked query rows AND a key
        // block seen by fewer query blocks
        for j in 0..4 {
            mask.set(2, j, false);
        }
        for causal in [false, true] {
            let (wdq, wdk, wdv) =
                dense_attention_backward_masked(&q, &k, &v, &dout, &mask, causal);
            let plan = AttnPlan::new(&mask, causal, 3);
            let (dq, dk, dv) = engine_backward(&plan, &q, &k, &v, &dout);
            assert!(dq.max_abs_diff(&wdq) < 1e-3, "dq causal={causal}: {}",
                    dq.max_abs_diff(&wdq));
            assert!(dk.max_abs_diff(&wdk) < 1e-3, "dk causal={causal}: {}",
                    dk.max_abs_diff(&wdk));
            assert!(dv.max_abs_diff(&wdv) < 1e-3, "dv causal={causal}: {}",
                    dv.max_abs_diff(&wdv));
            // masked-out query rows get zero dq
            assert!(dq.data[2 * 16 * 16..3 * 16 * 16].iter().all(|&x| x == 0.0),
                    "masked query rows must have zero gradient");
        }
    }

    #[test]
    fn backward_steady_state_is_zero_alloc_and_block_bounded() {
        let (q, k, v) = qkv(128, 16, 15);
        let dout = Matrix::randn(128, 16, 1.0, &mut Rng::new(16));
        let mask = crate::patterns::BlockMask::ones(8, 8); // b = 16
        let plan = AttnPlan::new(&mask, false, 2);
        let mut ws = Workspace::new();
        let mut o = Matrix::zeros(128, 16);
        let mut stats = AttnStats::new();
        plan.execute_stats(&q, &k, &v, &mut o, &mut stats, &mut ws);
        let mut dq = Matrix::zeros(128, 16);
        let mut dk = Matrix::zeros(128, 16);
        let mut dv = Matrix::zeros(128, 16);
        plan.backward(&q, &k, &v, &o, &dout, &stats, &mut dq, &mut dk, &mut dv, &mut ws);
        let warm = ws.alloc_events();
        for _ in 0..3 {
            plan.execute_stats(&q, &k, &v, &mut o, &mut stats, &mut ws);
            plan.backward(&q, &k, &v, &o, &dout, &stats, &mut dq, &mut dk, &mut dv,
                          &mut ws);
        }
        assert_eq!(ws.alloc_events(), warm, "backward hot path must not allocate");
        // scratch: forward tiles + backward tile per worker + the O(seq)
        // D row — nothing anywhere near seq×seq
        assert!(ws.peak_bytes() < 128 * 128 * 4,
                "peak {} suggests a seq×seq buffer", ws.peak_bytes());
    }

    #[test]
    fn decode_query_matches_fused_prefill_bitwise() {
        // the serving-path guarantee: a token decoded against the cache
        // is BIT-identical to the same row of a full causal prefill
        // (same block order, same masking, same accumulation sequence)
        let (seq, d) = (64usize, 8usize);
        let (q, k, v) = qkv(seq, d, 21);
        let mask = baselines::pixelfly_attention_mask(8, 4, 1);
        let plan = AttnPlan::new(&mask, true, 1);
        let mut ws = Workspace::new();
        let mut full = Matrix::zeros(seq, d);
        plan.execute(&q, &k, &v, &mut full, &mut ws);
        let b = seq / plan.grid_blocks();
        let mut out = vec![0.0f32; d];
        let mut scores = vec![0.0f32; b];
        for pos in 0..seq {
            plan.decode_query(q.row(pos), &k.data, &v.data, pos, &mut out,
                              &mut scores);
            for (t, (&a, &w)) in out.iter().zip(full.row(pos)).enumerate() {
                assert_eq!(a.to_bits(), w.to_bits(),
                           "pos {pos} dim {t}: decode {a} vs prefill {w}");
            }
        }
    }

    #[test]
    fn decode_query_ignores_stale_rows_past_pos() {
        // rows beyond pos hold garbage in a reused cache slot; the
        // causal single-query kernel must never read them
        let (seq, d) = (32usize, 8usize);
        let (q, k, v) = qkv(seq, d, 22);
        let mask = crate::patterns::BlockMask::ones(4, 4);
        let plan = AttnPlan::new(&mask, true, 1);
        let pos = 9; // mid second block: diagonal masking + stale tail
        let mut clean = vec![0.0f32; d];
        let mut scores = vec![0.0f32; seq / 4];
        plan.decode_query(q.row(pos), &k.data, &v.data, pos, &mut clean,
                          &mut scores);
        let (mut ks, mut vs) = (k.clone(), v.clone());
        for m in [&mut ks, &mut vs] {
            for r in pos + 1..seq {
                m.row_mut(r).fill(1e30); // poison everything past pos
            }
        }
        let mut dirty = vec![0.0f32; d];
        plan.decode_query(q.row(pos), &ks.data, &vs.data, pos, &mut dirty,
                          &mut scores);
        assert_eq!(clean, dirty, "stale cache rows past pos leaked in");
    }

    #[test]
    fn plan_cache_reuses_identical_structures() {
        let mask = baselines::pixelfly_attention_mask(8, 2, 1);
        let p1 = plan_for(&mask, true, 3);
        let p2 = plan_for(&mask, true, 3);
        assert!(Arc::ptr_eq(&p1, &p2), "same structure must hit the cache");
        let p3 = plan_for(&mask, false, 3);
        assert!(!Arc::ptr_eq(&p1, &p3), "causal flag is part of the key");
        let p4 = plan_for(&mask, true, 5);
        assert!(!Arc::ptr_eq(&p1, &p4), "thread count is part of the key");
    }

    #[test]
    fn causal_plan_filters_blocks_above_diagonal() {
        let mask = crate::patterns::BlockMask::ones(6, 6);
        let causal = AttnPlan::new(&mask, true, 1);
        let full = AttnPlan::new(&mask, false, 1);
        assert_eq!(causal.visible_blocks(), 6 * 7 / 2);
        assert_eq!(full.visible_blocks(), 36);
        assert!(causal.flops(16, 8) < full.flops(16, 8));
    }
}
