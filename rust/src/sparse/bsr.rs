//! BSR (block-sparse-row) matrix + GEMM on the Rust substrate.
//!
//! Storage mirrors the Pallas kernel's convention (block_sparse.py):
//! nonzero b x b blocks stored contiguously per block row, with a column
//! index per block.  `matmul` computes y = x * W touching only stored
//! blocks — the Table 7 measurement target: latency tracks the number of
//! blocks touched (the block cover), not the nominal density.

use std::sync::{Arc, Mutex};

use crate::patterns::BlockMask;
use crate::sparse::dense::{self, Matrix};
use crate::sparse::exec::quant::{self, QuantBlocks};
use crate::sparse::exec::{self, plan::structure_fingerprint, GemmPlan};
use crate::util::Rng;

/// Block-sparse-row matrix of logical shape [nbr*b, nbc*b].
#[derive(Debug)]
pub struct BsrMatrix {
    pub nbr: usize,
    pub nbc: usize,
    pub block: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes cols/blocks of block row i
    pub row_ptr: Vec<usize>,
    /// block column index per stored block
    pub cols: Vec<usize>,
    /// stored blocks, each b*b row-major, concatenated
    pub blocks: Vec<f32>,
    /// bf16 shadow of `blocks`, present only while the bf16 training tier
    /// is engaged for THIS matrix (see [`Self::refresh_bf16`]); `blocks`
    /// stays the f32 master the optimizer sweeps
    pub blocks_bf16: Option<Vec<u16>>,
    /// int8 quantized shadow + per-block scales, created once by
    /// quantize-at-freeze ([`Self::quantize_int8`]); when present the
    /// forward executor reads it instead of `blocks`
    pub qblocks: Option<QuantBlocks>,
    /// lazily built engine schedule reused across `matmul_into` calls,
    /// refreshed whenever the effective thread count changes OR the
    /// structure fingerprint no longer matches — so mutating
    /// `row_ptr`/`cols` after the first multiply transparently replans
    /// instead of executing a stale schedule (block *values* may change
    /// freely and never trigger a replan)
    plan_cache: Mutex<Option<Arc<GemmPlan>>>,
}

impl Clone for BsrMatrix {
    fn clone(&self) -> Self {
        BsrMatrix {
            nbr: self.nbr,
            nbc: self.nbc,
            block: self.block,
            row_ptr: self.row_ptr.clone(),
            cols: self.cols.clone(),
            blocks: self.blocks.clone(),
            blocks_bf16: self.blocks_bf16.clone(),
            qblocks: self.qblocks.clone(),
            // structure is identical, so the schedule stays valid
            plan_cache: Mutex::new(self.plan_cache.lock().unwrap().clone()),
        }
    }
}

impl BsrMatrix {
    pub fn rows(&self) -> usize {
        self.nbr * self.block
    }

    pub fn cols_elems(&self) -> usize {
        self.nbc * self.block
    }

    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz_blocks() as f64 / (self.nbr * self.nbc) as f64
    }

    /// Build from a block mask with values drawn N(0, scale^2).
    pub fn random(mask: &BlockMask, block: usize, scale: f32, rng: &mut Rng) -> Self {
        let (nbr, nbc) = (mask.rows, mask.cols);
        let mut row_ptr = Vec::with_capacity(nbr + 1);
        let mut cols = Vec::new();
        row_ptr.push(0);
        for i in 0..nbr {
            for j in 0..nbc {
                if mask.get(i, j) {
                    cols.push(j);
                }
            }
            row_ptr.push(cols.len());
        }
        let blocks = rng.normal_vec(cols.len() * block * block, scale);
        BsrMatrix {
            nbr,
            nbc,
            block,
            row_ptr,
            cols,
            blocks,
            blocks_bf16: None,
            qblocks: None,
            plan_cache: Mutex::new(None),
        }
    }

    /// Build from a dense matrix, keeping only blocks in the mask.
    pub fn from_dense(w: &Matrix, mask: &BlockMask, block: usize) -> Self {
        assert_eq!(w.rows, mask.rows * block);
        assert_eq!(w.cols, mask.cols * block);
        let mut out = Self::random(mask, block, 0.0, &mut Rng::new(0));
        for i in 0..out.nbr {
            for s in out.row_ptr[i]..out.row_ptr[i + 1] {
                let j = out.cols[s];
                let base = s * block * block;
                for r in 0..block {
                    for c in 0..block {
                        out.blocks[base + r * block + c] =
                            w.get(i * block + r, j * block + c);
                    }
                }
            }
        }
        out
    }

    /// Materialise dense (tests / inspection).
    pub fn to_dense(&self) -> Matrix {
        let b = self.block;
        let mut w = Matrix::zeros(self.rows(), self.cols_elems());
        for i in 0..self.nbr {
            for s in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[s];
                let base = s * b * b;
                for r in 0..b {
                    for c in 0..b {
                        w.set(i * b + r, j * b + c, self.blocks[base + r * b + c]);
                    }
                }
            }
        }
        w
    }

    /// y = x * W (x: [m, nbr*b]) touching only stored blocks.
    ///
    /// Routed through the parallel tiled engine ([`crate::sparse::exec`]):
    /// a [`GemmPlan`] partitions the output block columns into
    /// nnz-weighted chunks and the scoped worker pool executes them with
    /// register-blocked micro-kernels. Thread count comes from
    /// [`exec::threads`] (CLI `--threads` / `PIXELFLY_THREADS` / auto);
    /// small problems stay on the serial path inside the plan.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.cols_elems());
        self.matmul_into(x, &mut y);
        y
    }

    /// Fetch (or build and re-cache) the lazily cached plan. Reused by
    /// every engine path — forward, fused-epilogue forward, and both
    /// backward executors ride the SAME cached schedule, keyed by the
    /// same structure fingerprint.
    ///
    /// Rebuilt when the thread configuration changes or the structure
    /// fingerprint no longer matches (the cache used to key on thread
    /// count alone, silently trusting the pattern). The fingerprint is
    /// O(nnz) integer hashing, negligible next to the multiply; the
    /// executors re-check it in debug builds. The Arc is cloned out so
    /// concurrent multiplies never hold the lock across the kernel.
    fn cached_plan(&self) -> Arc<GemmPlan> {
        let threads = exec::threads();
        let fp = structure_fingerprint(self);
        let mut guard = self.plan_cache.lock().unwrap();
        match guard.as_ref() {
            Some(p) if p.threads() == threads && p.fingerprint() == fp => Arc::clone(p),
            _ => {
                let p = Arc::new(GemmPlan::new(self, threads));
                *guard = Some(Arc::clone(&p));
                p
            }
        }
    }

    pub fn matmul_into(&self, x: &Matrix, y: &mut Matrix) {
        self.cached_plan().execute(self, x, y);
    }

    /// y = act(x · W + bias) with the epilogue fused into the engine's
    /// output sweep (see [`GemmPlan::execute_fused`]); `pre` stashes the
    /// pre-activation when the activation's backward needs it.
    pub fn matmul_fused_into(&self, x: &Matrix, y: &mut Matrix,
                             epi: &exec::Epilogue, pre: Option<&mut Matrix>) {
        self.cached_plan().execute_fused(self, x, y, epi, pre);
    }

    /// dX = dY · Wᵀ through the transpose-free backward schedule of the
    /// cached plan ([`GemmPlan::execute_dx`]): the BSR row structure is
    /// read as Wᵀ's rows, and the stored blocks are consumed untransposed
    /// — no `Wᵀ` (and no per-block transpose) is ever materialised.
    pub fn matmul_dx_into(&self, dy: &Matrix, dx: &mut Matrix) {
        self.cached_plan().execute_dx(self, dy, dx);
    }

    /// dW = Xᵀ · dY scatter-accumulated into exactly the stored-block
    /// pattern ([`GemmPlan::execute_dw`]). `dw` mirrors `self.blocks`
    /// slot for slot (the pattern-frozen gradient of a fixed-structure
    /// butterfly layer — fill-in cannot exist by construction).
    pub fn matmul_dw_into(&self, x: &Matrix, dy: &Matrix, dw: &mut [f32]) {
        self.cached_plan().execute_dw(self, x, dy, dw);
    }

    /// Engage (or refresh) the bf16 weight shadow IF the global precision
    /// tier is bf16; otherwise drop it. The tier is opt-in per matrix:
    /// a `BsrMatrix` that never sees this call runs bit-exact f32 even
    /// under `PIXELFLY_PREC=bf16` — layers and the training driver call
    /// it, raw kernel tests do not.
    pub fn refresh_bf16(&mut self) {
        if quant::precision() == quant::Precision::Bf16 {
            let shadow = self.blocks_bf16.get_or_insert_with(Vec::new);
            quant::pack_bf16_into(&self.blocks, shadow);
        } else {
            self.blocks_bf16 = None;
        }
    }

    /// Repack the bf16 shadow from the f32 master ONLY when the shadow is
    /// already engaged — the cheap per-step call sites (post-optimizer
    /// sweeps) use this so matrices outside the tier pay nothing.
    pub fn repack_bf16(&mut self) {
        if let Some(shadow) = self.blocks_bf16.as_mut() {
            quant::pack_bf16_into(&self.blocks, shadow);
        }
    }

    /// Quantize-at-freeze: convert the stored blocks once to int8 + one
    /// symmetric scale per block. The f32 master is retained (dX/dW and
    /// any non-quantized path still read it); the forward executor
    /// prefers the quantized payload whenever it is present.
    pub fn quantize_int8(&mut self) {
        self.qblocks = Some(quant::quantize_blocks(&self.blocks, self.block));
    }

    /// Drop every reduced-precision shadow, returning this matrix to the
    /// pure-f32 path.
    pub fn drop_precision_shadows(&mut self) {
        self.blocks_bf16 = None;
        self.qblocks = None;
    }

    /// Build a reusable execution plan for this matrix's structure.
    /// Callers multiplying many batches against a fixed pattern should
    /// plan once and [`Self::matmul_with_plan`] per batch.
    pub fn plan(&self, threads: usize) -> GemmPlan {
        GemmPlan::new(self, threads)
    }

    /// y = x * W through a prebuilt plan (must match this structure).
    pub fn matmul_with_plan(&self, plan: &GemmPlan, x: &Matrix, y: &mut Matrix) {
        plan.execute(self, x, y);
    }

    /// Single-threaded scalar reference path (the pre-engine kernel):
    /// stored block outer, batch row inner. Kept as the correctness
    /// oracle for the engine proptests and the serial baseline the
    /// Table 7 bench reports speedups against.
    pub fn matmul_serial_into(&self, x: &Matrix, y: &mut Matrix) {
        let b = self.block;
        assert_eq!(x.cols, self.rows());
        assert_eq!((y.rows, y.cols), (x.rows, self.cols_elems()));
        y.data.fill(0.0);
        let m = x.rows;
        // Loop order (perf pass, EXPERIMENTS.md §Perf L3 iter-1): stored
        // block OUTER, batch row inner — each b x b weight block stays hot
        // in L1 across the whole batch panel instead of being re-streamed
        // per row; the innermost c-loop over a contiguous y segment
        // vectorises.
        for i in 0..self.nbr {
            let (s0, s1) = (self.row_ptr[i], self.row_ptr[i + 1]);
            for s in s0..s1 {
                let j = self.cols[s];
                let blk = &self.blocks[s * b * b..(s + 1) * b * b];
                for r in 0..m {
                    let xrow = &x.row(r)[i * b..(i + 1) * b];
                    let ycols = &mut y.row_mut(r)[j * b..(j + 1) * b];
                    // no zero-skip branch: activations are dense, and the
                    // branch costs more than the multiply (perf iter-2);
                    // zipped chunk iteration elides bounds checks (iter-3)
                    for (&xv, wrow) in xrow.iter().zip(blk.chunks_exact(b)) {
                        for (yc, &wc) in ycols.iter_mut().zip(wrow) {
                            *yc += xv * wc;
                        }
                    }
                }
            }
        }
    }

    /// Single-threaded scalar reference for dX = dY · Wᵀ, mirroring the
    /// forward convention (stored block outer, batch row inner): the
    /// correctness oracle for [`Self::matmul_dx_into`] in the gradcheck
    /// proptests. Reads stored blocks untransposed, like the engine.
    pub fn matmul_dx_serial_into(&self, dy: &Matrix, dx: &mut Matrix) {
        let b = self.block;
        assert_eq!(dy.cols, self.cols_elems());
        assert_eq!((dx.rows, dx.cols), (dy.rows, self.rows()));
        dx.data.fill(0.0);
        let m = dy.rows;
        for i in 0..self.nbr {
            for s in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[s];
                let blk = &self.blocks[s * b * b..(s + 1) * b * b];
                for r in 0..m {
                    let dyrow = &dy.row(r)[j * b..(j + 1) * b];
                    let dxrow = &mut dx.row_mut(r)[i * b..(i + 1) * b];
                    // dx[c] += Σ_k dy[k] · blk[c, k]: block rows are the
                    // contiguous dot operands of the transpose product
                    for (dxc, wrow) in dxrow.iter_mut().zip(blk.chunks_exact(b)) {
                        let mut acc = 0.0f32;
                        for (dv, wv) in dyrow.iter().zip(wrow) {
                            acc += *dv * *wv;
                        }
                        *dxc += acc;
                    }
                }
            }
        }
    }

    /// Single-threaded scalar reference for dW = Xᵀ · dY restricted to
    /// the stored pattern: the oracle for [`Self::matmul_dw_into`]. `dw`
    /// mirrors `self.blocks` slot for slot.
    pub fn matmul_dw_serial_into(&self, x: &Matrix, dy: &Matrix, dw: &mut [f32]) {
        let b = self.block;
        assert_eq!(x.cols, self.rows());
        assert_eq!(dy.cols, self.cols_elems());
        assert_eq!(x.rows, dy.rows);
        assert_eq!(dw.len(), self.blocks.len());
        dw.fill(0.0);
        let m = x.rows;
        for i in 0..self.nbr {
            for s in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[s];
                let blk = &mut dw[s * b * b..(s + 1) * b * b];
                for r in 0..m {
                    let xrow = &x.row(r)[i * b..(i + 1) * b];
                    let dyrow = &dy.row(r)[j * b..(j + 1) * b];
                    for (&xv, wrow) in xrow.iter().zip(blk.chunks_exact_mut(b)) {
                        for (wc, &dv) in wrow.iter_mut().zip(dyrow) {
                            *wc += xv * dv;
                        }
                    }
                }
            }
        }
    }

    /// Transpose (pattern and blocks).
    pub fn transpose(&self) -> BsrMatrix {
        let b = self.block;
        // count per new block row (old col)
        let mut counts = vec![0usize; self.nbc];
        for &j in &self.cols {
            counts[j] += 1;
        }
        let mut row_ptr = vec![0usize; self.nbc + 1];
        for j in 0..self.nbc {
            row_ptr[j + 1] = row_ptr[j] + counts[j];
        }
        let mut cols = vec![0usize; self.cols.len()];
        let mut blocks = vec![0.0f32; self.blocks.len()];
        let mut cursor = row_ptr.clone();
        for i in 0..self.nbr {
            for s in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[s];
                let d = cursor[j];
                cursor[j] += 1;
                cols[d] = i;
                // each stored block transposes through the shared
                // cache-blocked tile kernel (dense::transpose_into)
                let src = &self.blocks[s * b * b..(s + 1) * b * b];
                let dst = &mut blocks[d * b * b..(d + 1) * b * b];
                dense::transpose_into(src, b, b, dst);
            }
        }
        BsrMatrix {
            nbr: self.nbc,
            nbc: self.nbr,
            block: b,
            row_ptr,
            cols,
            blocks,
            blocks_bf16: None,
            qblocks: None,
            plan_cache: Mutex::new(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::{baselines, flat_butterfly_mask};
    use crate::sparse::dense::matmul_blocked;

    #[test]
    fn bsr_matmul_matches_dense() {
        let mut rng = Rng::new(21);
        let mask = flat_butterfly_mask(8, 4);
        let w = BsrMatrix::random(&mask, 4, 0.5, &mut rng);
        let x = Matrix::randn(10, 32, 1.0, &mut rng);
        let y = w.matmul(&x);
        let yref = matmul_blocked(&x, &w.to_dense());
        assert!(y.max_abs_diff(&yref) < 1e-4);
    }

    #[test]
    fn rectangular_bsr() {
        let mut rng = Rng::new(22);
        let mask = baselines::random_mask(4, 8, 0.3, &mut rng);
        let w = BsrMatrix::random(&mask, 4, 0.5, &mut rng);
        let x = Matrix::randn(6, 16, 1.0, &mut rng);
        let y = w.matmul(&x);
        assert_eq!((y.rows, y.cols), (6, 32));
        let yref = matmul_blocked(&x, &w.to_dense());
        assert!(y.max_abs_diff(&yref) < 1e-4);
    }

    #[test]
    fn from_dense_roundtrip() {
        let mut rng = Rng::new(23);
        let mask = flat_butterfly_mask(4, 2);
        let a = BsrMatrix::random(&mask, 4, 1.0, &mut rng);
        let b = BsrMatrix::from_dense(&a.to_dense(), &mask, 4);
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-7);
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Rng::new(24);
        let mask = baselines::bigbird_mask(8, 1, 1, 2, &mut rng);
        let w = BsrMatrix::random(&mask, 4, 1.0, &mut rng);
        let t = w.transpose();
        assert!(t.to_dense().max_abs_diff(&w.to_dense().transpose()) < 1e-7);
    }

    #[test]
    fn engine_path_matches_serial_reference() {
        let mut rng = Rng::new(25);
        let mask = baselines::random_mask(6, 5, 0.4, &mut rng);
        let w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let x = Matrix::randn(21, w.rows(), 1.0, &mut rng);
        let mut serial = Matrix::zeros(21, w.cols_elems());
        w.matmul_serial_into(&x, &mut serial);
        let y = w.matmul(&x);
        assert!(y.max_abs_diff(&serial) < 1e-4);
        let plan = w.plan(8);
        let mut yp = Matrix::zeros(21, w.cols_elems());
        w.matmul_with_plan(&plan, &x, &mut yp);
        assert!(yp.max_abs_diff(&serial) < 1e-4);
    }

    #[test]
    fn plan_cache_replans_after_structure_mutation() {
        // regression: the cache used to key on thread count only, so a
        // post-multiply structure edit executed a stale schedule (caught
        // only by the executor's loud fingerprint panic); matmul_into now
        // detects the mutated fingerprint and transparently replans
        let mut rng = Rng::new(26);
        let mask = BlockMask::ones(3, 3);
        let mut w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let x = Matrix::randn(5, w.rows(), 1.0, &mut rng);
        let _ = w.matmul(&x); // caches a plan for the original structure
        // swap two column indices in block row 0: same shape/nnz, new pattern
        let s = w.row_ptr[0];
        w.cols.swap(s, s + 1);
        let mut want = Matrix::zeros(5, w.cols_elems());
        w.matmul_serial_into(&x, &mut want);
        let y = w.matmul(&x); // must replan, not run the stale schedule
        assert!(y.max_abs_diff(&want) < 1e-4, "{}", y.max_abs_diff(&want));
    }

    #[test]
    fn backward_engine_matches_serial_references() {
        let mut rng = Rng::new(27);
        let mask = baselines::random_mask(5, 6, 0.4, &mut rng);
        let w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let x = Matrix::randn(17, w.rows(), 1.0, &mut rng);
        let dy = Matrix::randn(17, w.cols_elems(), 1.0, &mut rng);
        // dX
        let mut want_dx = Matrix::zeros(17, w.rows());
        w.matmul_dx_serial_into(&dy, &mut want_dx);
        let mut dx = Matrix::zeros(17, w.rows());
        w.matmul_dx_into(&dy, &mut dx);
        assert!(dx.max_abs_diff(&want_dx) < 1e-4, "{}", dx.max_abs_diff(&want_dx));
        // dW
        let mut want_dw = vec![0.0f32; w.blocks.len()];
        w.matmul_dw_serial_into(&x, &dy, &mut want_dw);
        let mut dw = vec![0.0f32; w.blocks.len()];
        w.matmul_dw_into(&x, &dy, &mut dw);
        let diff = dw
            .iter()
            .zip(&want_dw)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "{diff}");
    }

    #[test]
    fn serial_backward_matches_dense_transpose_math() {
        let mut rng = Rng::new(28);
        let mask = flat_butterfly_mask(6, 4);
        let w = BsrMatrix::random(&mask, 4, 0.5, &mut rng);
        let x = Matrix::randn(9, w.rows(), 1.0, &mut rng);
        let dy = Matrix::randn(9, w.cols_elems(), 1.0, &mut rng);
        let wd = w.to_dense();
        // dX = dY·Wᵀ (dense transpose lives only in the test)
        let mut dx = Matrix::zeros(9, w.rows());
        w.matmul_dx_serial_into(&dy, &mut dx);
        let want_dx = matmul_blocked(&dy, &wd.transpose());
        assert!(dx.max_abs_diff(&want_dx) < 1e-4, "{}", dx.max_abs_diff(&want_dx));
        // dW = Xᵀ·dY on the stored pattern
        let mut dw = vec![0.0f32; w.blocks.len()];
        w.matmul_dw_serial_into(&x, &dy, &mut dw);
        let dwd = matmul_blocked(&x.transpose(), &dy);
        let b = w.block;
        for i in 0..w.nbr {
            for s in w.row_ptr[i]..w.row_ptr[i + 1] {
                let j = w.cols[s];
                for r in 0..b {
                    for c in 0..b {
                        let got = dw[s * b * b + r * b + c];
                        let want = dwd.get(i * b + r, j * b + c);
                        assert!((got - want).abs() < 1e-4, "slot {s} ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn backward_shares_the_forward_plan_cache() {
        // one cached plan serves forward, fused forward, dX and dW; a
        // structure edit between calls must transparently replan for the
        // backward paths exactly like it does for the forward path
        let mut rng = Rng::new(29);
        let mask = BlockMask::ones(3, 3);
        let mut w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let dy = Matrix::randn(5, w.cols_elems(), 1.0, &mut rng);
        let mut dx = Matrix::zeros(5, w.rows());
        w.matmul_dx_into(&dy, &mut dx); // caches a plan
        let s = w.row_ptr[0];
        w.cols.swap(s, s + 1); // same shape/nnz, new pattern
        let mut want = Matrix::zeros(5, w.rows());
        w.matmul_dx_serial_into(&dy, &mut want);
        w.matmul_dx_into(&dy, &mut dx); // must replan
        assert!(dx.max_abs_diff(&want) < 1e-4, "{}", dx.max_abs_diff(&want));
    }

    #[test]
    fn fused_wrapper_matches_unfused_plus_epilogue() {
        use crate::sparse::exec::{Activation, Epilogue};
        let mut rng = Rng::new(30);
        let mask = baselines::random_mask(4, 4, 0.6, &mut rng);
        let w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let x = Matrix::randn(7, w.rows(), 1.0, &mut rng);
        let bias = rng.normal_vec(w.cols_elems(), 1.0);
        let z = w.matmul(&x);
        let mut want = Matrix::zeros(7, w.cols_elems());
        for r in 0..7 {
            for c in 0..w.cols_elems() {
                want.set(r, c, Activation::Relu.apply(z.get(r, c) + bias[c]));
            }
        }
        let mut y = Matrix::zeros(7, w.cols_elems());
        w.matmul_fused_into(&x, &mut y,
                            &Epilogue { bias: Some(&bias), act: Activation::Relu },
                            None);
        assert!(y.max_abs_diff(&want) < 1e-4, "{}", y.max_abs_diff(&want));
    }

    #[test]
    fn density_counts_blocks() {
        let mask = flat_butterfly_mask(16, 4);
        let w = BsrMatrix::random(&mask, 8, 1.0, &mut Rng::new(0));
        assert_eq!(w.nnz_blocks(), mask.nnz());
        assert!((w.density() - mask.density()).abs() < 1e-12);
    }
}
