//! Reduced-precision subsystem: the storage tiers behind the precision
//! dispatch axis (`--precision {f32,bf16,int8}` / `PIXELFLY_PREC`).
//!
//! Two tiers live under the f32 engine:
//!
//! - **bf16 training tier**: weight blocks and activation panels are
//!   stored as bf16 (the top 16 bits of an f32, rounded to nearest-even)
//!   and widened lane-wise in registers inside the panel kernels; every
//!   accumulator stays f32. The BSR master weights remain f32 for the
//!   optimizer sweep (`exec::sgd_momentum` semantics are unchanged) — a
//!   packed u16 shadow rides alongside the payload and is repacked after
//!   each update ([`crate::sparse::BsrMatrix::repack_bf16`]).
//! - **int8 inference tier**: at freeze time (`into_inference` /
//!   `into_decode`) each stored `b×b` block is quantized symmetrically to
//!   int8 with one f32 scale per block (`scale = max|w| / 127`). The dot
//!   kernels stream the int8 payload directly — lanes are widened in
//!   registers, accumulated in f32, and multiplied by the block scale
//!   once per block; no dequantized copy of `W` is ever materialised.
//!
//! Tier resolution mirrors the kernel/pool axes: explicit
//! [`set_precision`] (the CLI's `--precision`), else `PIXELFLY_PREC`,
//! else f32. The tier is *engaged* per matrix by packing its shadow
//! (layer constructors and the training driver call
//! `refresh_bf16`/`quantize_int8`); a matrix without a shadow always runs
//! the bit-exact f32 path regardless of the global selection, which keeps
//! every existing oracle test byte-identical when the tier is off.

use std::cell::RefCell;
use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::simd;
use crate::sparse::dense::Matrix;

/// User-facing precision selection (CLI `--precision` / `PIXELFLY_PREC`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 storage everywhere (the default; bit-exact legacy path).
    F32,
    /// bf16-stored weights + activation panels, f32 accumulate (training).
    Bf16,
    /// Per-block symmetric int8 weights, f32 accumulate (inference freeze).
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
        }
    }
}

/// 0 = no override; 1..=3 encode `Precision`.
static PREC_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// `PIXELFLY_PREC` resolved once (env reads off the hot path).
static ENV_PREC: OnceLock<Precision> = OnceLock::new();

/// Override the precision tier for this process (the CLI's
/// `--precision`). Callers toggling temporarily (benches, tests) should
/// snapshot [`precision`] first and restore it.
pub fn set_precision(p: Precision) {
    let v = match p {
        Precision::F32 => 1,
        Precision::Bf16 => 2,
        Precision::Int8 => 3,
    };
    PREC_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Effective selection: `set_precision` override, else `PIXELFLY_PREC`,
/// else f32.
pub fn precision() -> Precision {
    match PREC_OVERRIDE.load(Ordering::Relaxed) {
        1 => Precision::F32,
        2 => Precision::Bf16,
        3 => Precision::Int8,
        _ => *ENV_PREC.get_or_init(|| {
            std::env::var("PIXELFLY_PREC")
                .ok()
                .and_then(|s| Precision::parse(&s))
                .unwrap_or(Precision::F32)
        }),
    }
}

/// Active precision name for reports: `"f32"`, `"bf16"`, or `"int8"`.
pub fn precision_name() -> &'static str {
    precision().name()
}

// ---------------------------------------------------------------------
// bf16 pack/unpack
// ---------------------------------------------------------------------

/// f32 → bf16 (top 16 bits) with round-to-nearest-even; NaN stays NaN.
#[inline]
pub fn bf16_from_f32(v: f32) -> u16 {
    let x = v.to_bits();
    if x & 0x7fff_ffff > 0x7f80_0000 {
        // NaN: truncate but force a mantissa bit so it stays a NaN
        return ((x >> 16) | 0x0040) as u16;
    }
    let round = 0x7fff + ((x >> 16) & 1);
    (x.wrapping_add(round) >> 16) as u16
}

/// bf16 → f32: the stored bits are exactly the f32 top half.
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Pack `src` into `dst` as bf16, reusing `dst`'s capacity.
pub fn pack_bf16_into(src: &[f32], dst: &mut Vec<u16>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| bf16_from_f32(v)));
}

/// Round-trip `v` through bf16 storage (tests and error-bound benches).
#[inline]
pub fn bf16_round(v: f32) -> f32 {
    bf16_to_f32(bf16_from_f32(v))
}

// ---------------------------------------------------------------------
// Thread-local u16 scratch (bf16 activation panels)
// ---------------------------------------------------------------------

/// Cap on retained scratch buffers per thread (mirrors the f32
/// workspace's bounded free list).
const MAX_FREE_U16: usize = 8;

thread_local! {
    static U16_POOL: RefCell<Vec<Vec<u16>>> = RefCell::new(Vec::new());
}

/// Check out a u16 buffer of length `len` from the thread-local pool.
/// Steady state is allocation-free: a returned buffer whose capacity
/// already covers `len` is resized in place.
pub fn take_u16(len: usize) -> Vec<u16> {
    U16_POOL.with(|p| {
        let mut pool = p.borrow_mut();
        // best fit: smallest capacity that covers the request
        let mut best: Option<usize> = None;
        for (i, b) in pool.iter().enumerate() {
            if b.capacity() >= len
                && best.map_or(true, |j| b.capacity() < pool[j].capacity())
            {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut buf = pool.swap_remove(i);
                buf.resize(len, 0);
                buf
            }
            None => vec![0u16; len],
        }
    })
}

/// Return a buffer checked out with [`take_u16`].
pub fn give_u16(buf: Vec<u16>) {
    U16_POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.len() < MAX_FREE_U16 {
            pool.push(buf);
        }
    });
}

/// Row-major bf16 matrix view over a packed u16 buffer (the activation
/// panel operand of the bf16 kernels).
#[derive(Clone, Copy)]
pub struct Bf16Panel<'a> {
    pub data: &'a [u16],
    pub rows: usize,
    pub cols: usize,
}

impl<'a> Bf16Panel<'a> {
    #[inline]
    pub fn row(&self, r: usize) -> &'a [u16] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

// ---------------------------------------------------------------------
// bf16 panel kernel twins (scalar tier + SIMD dispatch)
// ---------------------------------------------------------------------

/// bf16 twin of [`super::micro::block_panel`]: `y[r, jc..jc+b] +=
/// bf16(x)[r, ic..ic+b] · bf16(blk)` with f32 accumulation.
///
/// # Safety
/// Same ownership/bounds contract as `micro::block_panel`; additionally
/// `blk.len() == b * b` in u16 elements.
pub unsafe fn block_panel_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    rows: Range<usize>,
    blk: &[u16],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(blk.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if simd::try_block_panel_bf16(b, x, ic, rows.clone(), blk, y, ldy, jc) {
        return;
    }
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for (k, wrow) in blk.chunks_exact(b).enumerate() {
            let a = bf16_to_f32(xr[k]);
            for (yc, &wc) in yr.iter_mut().zip(wrow) {
                *yc += a * bf16_to_f32(wc);
            }
        }
    }
}

/// bf16 twin of [`super::micro::block_panel_t`] (`dX = dY·Wᵀ`): the
/// stored bf16 block rows are the dot operands, f32 accumulation.
///
/// # Safety
/// Same contract as [`block_panel_bf16`].
pub unsafe fn block_panel_t_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    rows: Range<usize>,
    blk: &[u16],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(blk.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if simd::try_block_panel_t_bf16(b, x, ic, rows.clone(), blk, y, ldy, jc) {
        return;
    }
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for (c, wrow) in blk.chunks_exact(b).enumerate() {
            let mut acc = 0.0f32;
            for (&xv, &wv) in xr.iter().zip(wrow) {
                acc += bf16_to_f32(xv) * bf16_to_f32(wv);
            }
            yr[c] += acc;
        }
    }
}

/// bf16 twin of [`super::micro::scatter_block`] (`dW = Xᵀ·dY`): both
/// operand panels are bf16, the gradient block accumulates in f32.
pub fn scatter_block_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    dy: &Bf16Panel,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) {
    assert_eq!(blk.len(), b * b);
    assert!(ic + b <= x.cols && jc + b <= dy.cols);
    assert!(rows.end <= x.rows && rows.end <= dy.rows);
    // Safety: the asserts above establish the bounds contract.
    if unsafe { simd::try_scatter_block_bf16(b, x, ic, dy, jc, rows.clone(), blk) } {
        return;
    }
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let dr = &dy.row(r)[jc..jc + b];
        for (k, wrow) in blk.chunks_exact_mut(b).enumerate() {
            let a = bf16_to_f32(xr[k]);
            for (wc, &dv) in wrow.iter_mut().zip(dr) {
                *wc += a * bf16_to_f32(dv);
            }
        }
    }
}

// ---------------------------------------------------------------------
// int8 per-block symmetric quantization + dot kernel
// ---------------------------------------------------------------------

/// Per-block int8 quantized twin of a BSR payload: `data` mirrors the
/// f32 `blocks` slot for slot (each `b*b` run is one block), `scales`
/// holds one f32 per stored block (`w ≈ q · scale`).
#[derive(Clone, Debug)]
pub struct QuantBlocks {
    pub block: usize,
    pub data: Vec<i8>,
    pub scales: Vec<f32>,
}

/// Symmetric per-block quantization: `scale = max|w| / 127` per `b×b`
/// block, `q = round(w / scale)` clamped to `[-127, 127]`. An all-zero
/// block stores scale 0 and zeros (exact).
pub fn quantize_blocks(blocks: &[f32], b: usize) -> QuantBlocks {
    assert_eq!(blocks.len() % (b * b), 0);
    let n_blocks = blocks.len() / (b * b);
    let mut data = vec![0i8; blocks.len()];
    let mut scales = vec![0.0f32; n_blocks];
    for s in 0..n_blocks {
        let blk = &blocks[s * b * b..(s + 1) * b * b];
        let maxabs = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if maxabs == 0.0 {
            continue;
        }
        let scale = maxabs / 127.0;
        let inv = 1.0 / scale;
        scales[s] = scale;
        let q = &mut data[s * b * b..(s + 1) * b * b];
        for (qi, &v) in q.iter_mut().zip(blk) {
            *qi = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
    QuantBlocks { block: b, data, scales }
}

/// Dequantize one stored block into `out` (tests / round-trip checks).
pub fn dequantize_block(q: &QuantBlocks, s: usize, out: &mut [f32]) {
    let bb = q.block * q.block;
    assert_eq!(out.len(), bb);
    let scale = q.scales[s];
    for (o, &qi) in out.iter_mut().zip(&q.data[s * bb..(s + 1) * bb]) {
        *o = qi as f32 * scale;
    }
}

/// int8 forward panel kernel: `y[r, jc..jc+b] += scale · (x[r, ic..ic+b]
/// · q)` — int8 lanes widened in registers, f32 accumulate, exactly one
/// scale multiply per block per row strip.
///
/// # Safety
/// Same ownership/bounds contract as `micro::block_panel`; `q.len() ==
/// b * b`.
pub unsafe fn block_panel_i8(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    q: &[i8],
    scale: f32,
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(q.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if scale == 0.0 {
        return; // all-zero block: nothing to accumulate
    }
    if simd::try_block_panel_i8(b, x, ic, rows.clone(), q, scale, y, ldy, jc) {
        return;
    }
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for c in 0..b {
            let mut acc = 0.0f32;
            for (k, &xv) in xr.iter().enumerate() {
                acc += xv * q[k * b + c] as f32;
            }
            yr[c] += scale * acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn precision_parses() {
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse(" BF16 "), Some(Precision::Bf16));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::Bf16.name(), "bf16");
    }

    #[test]
    fn bf16_roundtrip_error_is_bounded() {
        let mut rng = Rng::new(91);
        for &v in rng.normal_vec(1000, 2.0).iter() {
            let r = bf16_round(v);
            // 8 explicit mantissa bits: relative error ≤ 2^-8 = 1/256
            assert!((r - v).abs() <= v.abs() / 256.0 + 1e-30, "{v} -> {r}");
        }
        // exact values survive exactly
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0] {
            assert_eq!(bf16_round(v), v);
        }
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next
        // representable value; RNE picks the even mantissa (1.0)
        let half_up = f32::from_bits(0x3f80_0100);
        assert_eq!(bf16_round(half_up), 1.0);
        // just above the midpoint rounds up
        let above = f32::from_bits(0x3f80_0101);
        assert_eq!(bf16_from_f32(above), 0x3f81);
    }

    #[test]
    fn int8_roundtrip_is_within_half_a_step() {
        let mut rng = Rng::new(92);
        let b = 16usize;
        let blocks = rng.normal_vec(3 * b * b, 1.5);
        let q = quantize_blocks(&blocks, b);
        let mut out = vec![0.0f32; b * b];
        for s in 0..3 {
            dequantize_block(&q, s, &mut out);
            let blk = &blocks[s * b * b..(s + 1) * b * b];
            let maxabs = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = maxabs / 254.0 + 1e-6; // half a quantization step
            for (got, want) in out.iter().zip(blk) {
                assert!((got - want).abs() <= bound, "{got} vs {want} (±{bound})");
            }
        }
    }

    #[test]
    fn int8_zero_block_is_exact() {
        let q = quantize_blocks(&vec![0.0f32; 64], 8);
        assert_eq!(q.scales[0], 0.0);
        assert!(q.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn bf16_panel_kernel_matches_f32_within_storage_error() {
        let mut rng = Rng::new(93);
        for b in [8usize, 16, 32] {
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blkf = rng.normal_vec(b * b, 0.5);
            // f32 reference on bf16-rounded operands = exact expectation
            let xr: Vec<f32> = x.data.iter().map(|&v| bf16_round(v)).collect();
            let wr: Vec<f32> = blkf.iter().map(|&v| bf16_round(v)).collect();
            let mut want = Matrix::zeros(7, 2 * b);
            for r in 0..7 {
                for k in 0..b {
                    let a = xr[r * x.cols + b + k];
                    for c in 0..b {
                        let v = want.get(r, b + c) + a * wr[k * b + c];
                        want.set(r, b + c, v);
                    }
                }
            }
            let mut xq = Vec::new();
            pack_bf16_into(&x.data, &mut xq);
            let xp = Bf16Panel { data: &xq, rows: x.rows, cols: x.cols };
            let mut wq = Vec::new();
            pack_bf16_into(&blkf, &mut wq);
            let mut y = Matrix::zeros(7, 2 * b);
            let ldy = y.cols;
            unsafe {
                block_panel_bf16(b, &xp, b, 0..7, &wq, y.data.as_mut_ptr(), ldy, b);
            }
            // f32 accumulation over bf16 operands: only tiny fp reassociation
            assert!(y.max_abs_diff(&want) < 1e-3, "b={b}: {}", y.max_abs_diff(&want));
        }
    }

    #[test]
    fn bf16_transpose_and_scatter_match_their_f32_twins_loosely() {
        let mut rng = Rng::new(94);
        let b = 16usize;
        let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
        let dy = Matrix::randn(7, 2 * b, 1.0, &mut rng);
        let blkf = rng.normal_vec(b * b, 0.5);
        let mut xq = Vec::new();
        pack_bf16_into(&x.data, &mut xq);
        let xp = Bf16Panel { data: &xq, rows: x.rows, cols: x.cols };
        let mut dq = Vec::new();
        pack_bf16_into(&dy.data, &mut dq);
        let dp = Bf16Panel { data: &dq, rows: dy.rows, cols: dy.cols };
        let mut wq = Vec::new();
        pack_bf16_into(&blkf, &mut wq);
        // transpose panel vs f32 twin: storage error only (≤ ~2^-8 rel)
        let mut got = Matrix::zeros(7, 2 * b);
        let mut want = Matrix::zeros(7, 2 * b);
        let ld = got.cols;
        unsafe {
            block_panel_t_bf16(b, &xp, b, 0..7, &wq, got.data.as_mut_ptr(), ld, b);
            super::super::micro::block_panel_t(
                b, &x, b, 0..7, &blkf, want.data.as_mut_ptr(), ld, b,
            );
        }
        assert!(got.max_abs_diff(&want) < 0.3, "{}", got.max_abs_diff(&want));
        assert!(got.max_abs_diff(&want) > 0.0); // the tier actually engaged
        // scatter vs f32 twin
        let mut gblk = vec![0.0f32; b * b];
        let mut wblk = vec![0.0f32; b * b];
        scatter_block_bf16(b, &xp, b, &dp, b, 0..7, &mut gblk);
        super::super::micro::scatter_block(b, &x, b, &dy, b, 0..7, &mut wblk);
        let diff = gblk
            .iter()
            .zip(&wblk)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 0.3, "{diff}");
    }

    #[test]
    fn int8_panel_kernel_matches_dequantized_reference() {
        let mut rng = Rng::new(95);
        for b in [8usize, 16, 32] {
            let x = Matrix::randn(5, 3 * b, 1.0, &mut rng);
            let blkf = rng.normal_vec(b * b, 0.5);
            let q = quantize_blocks(&blkf, b);
            let mut deq = vec![0.0f32; b * b];
            dequantize_block(&q, 0, &mut deq);
            // reference: f32 kernel over the dequantized block
            let mut want = Matrix::zeros(5, 2 * b);
            let ld = want.cols;
            unsafe {
                super::super::micro::block_panel(
                    b, &x, b, 0..5, &deq, want.data.as_mut_ptr(), ld, b,
                );
            }
            let mut y = Matrix::zeros(5, 2 * b);
            unsafe {
                block_panel_i8(
                    b, &x, b, 0..5, &q.data[..b * b], q.scales[0],
                    y.data.as_mut_ptr(), ld, b,
                );
            }
            assert!(y.max_abs_diff(&want) < 1e-3, "b={b}: {}", y.max_abs_diff(&want));
        }
    }

    #[test]
    fn u16_scratch_reuses_capacity() {
        let a = take_u16(1024);
        let cap = a.capacity();
        give_u16(a);
        let b = take_u16(512);
        assert!(b.capacity() >= 512);
        assert_eq!(b.capacity(), cap); // best-fit returned the same buffer
        give_u16(b);
    }
}
