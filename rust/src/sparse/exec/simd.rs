//! SIMD microkernel dispatch tier: runtime-detected AVX2+FMA (x86_64) and
//! NEON (aarch64) kernels behind the portable scalar tier.
//!
//! Selection (mirrors the thread-count resolution in `exec`): explicit
//! [`set_kernel`] (the CLI's `--kernel {auto,scalar,simd}`), else the
//! `PIXELFLY_KERNEL` env var, else `auto`. `auto` and `simd` both resolve
//! to the best tier the host supports — the difference is intent: `simd`
//! is a request (benches use it to name the tier they measured), `auto`
//! is the default. When no vector unit is available every choice resolves
//! to the const-specialised scalar kernels in [`super::micro`], so the
//! substrate's numerics never depend on the host. [`kernel_name`] reports
//! the active tier (`scalar`/`avx2`/`neon`) for `TrainReport` and bench
//! notes.
//!
//! Three kernel families live here:
//! - `block_panel` and its backward siblings `block_panel_t` (dX = dY·Wᵀ,
//!   dot-formulated against the untransposed block rows) and
//!   `scatter_block` (dW = Xᵀ·dY rank-panel scatter into one stored
//!   block) — same contracts as the [`super::micro`] scalar tier;
//! - `dot` / `axpy` / `scale`: the vector primitives the fused streaming
//!   attention kernel (forward and backward) is built from;
//! - `sgd_momentum`: the fused optimizer sweep (`m = μ·m + g;
//!   w -= lr·m`) the training step runs over stored blocks.
//!
//! Feature detection runs once per process (`OnceLock`). Per-call
//! dispatch costs one relaxed atomic load plus (on the no-override path)
//! two initialized-`OnceLock` loads — fine per `b×b` panel, too much per
//! 64-element dot inside attention's innermost loops, so hot loops
//! resolve [`active_tier`] once and call the `*_with(tier, …)` variants.

use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::quant::Bf16Panel;
use crate::sparse::dense::Matrix;

/// User-facing kernel selection (CLI `--kernel` / `PIXELFLY_KERNEL`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// Best available tier (the default).
    Auto,
    /// Force the portable scalar kernels.
    Scalar,
    /// Request the SIMD tier (falls back to scalar when unavailable).
    Simd,
}

impl KernelChoice {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelChoice::Auto),
            "scalar" => Some(KernelChoice::Scalar),
            "simd" => Some(KernelChoice::Simd),
            _ => None,
        }
    }
}

/// The resolved kernel tier actually executing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Scalar,
    Avx2,
    Neon,
}

/// 0 = no override; 1..=3 encode `KernelChoice`.
static CHOICE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// `PIXELFLY_KERNEL` resolved once (env reads off the hot path).
static ENV_CHOICE: OnceLock<KernelChoice> = OnceLock::new();

/// Hardware detection resolved once.
static DETECTED: OnceLock<Option<Tier>> = OnceLock::new();

/// Override the kernel tier selection for this process. Callers that
/// toggle temporarily (the tier benches) should snapshot
/// [`kernel_choice`] first and restore it, so an operator's
/// `PIXELFLY_KERNEL`-derived choice round-trips.
pub fn set_kernel(c: KernelChoice) {
    let v = match c {
        KernelChoice::Auto => 1,
        KernelChoice::Scalar => 2,
        KernelChoice::Simd => 3,
    };
    CHOICE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Effective selection: `set_kernel` override, else `PIXELFLY_KERNEL`,
/// else `Auto`.
pub fn kernel_choice() -> KernelChoice {
    match CHOICE_OVERRIDE.load(Ordering::Relaxed) {
        1 => KernelChoice::Auto,
        2 => KernelChoice::Scalar,
        3 => KernelChoice::Simd,
        _ => *ENV_CHOICE.get_or_init(|| {
            std::env::var("PIXELFLY_KERNEL")
                .ok()
                .and_then(|s| KernelChoice::parse(&s))
                .unwrap_or(KernelChoice::Auto)
        }),
    }
}

fn detect() -> Option<Tier> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Some(Tier::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(Tier::Neon);
        }
    }
    None
}

/// The SIMD tier this host supports, if any (detection cached).
pub fn simd_tier() -> Option<Tier> {
    *DETECTED.get_or_init(detect)
}

/// Whether a SIMD tier exists on this host.
pub fn simd_available() -> bool {
    simd_tier().is_some()
}

/// The tier that executes under the current selection.
pub fn active_tier() -> Tier {
    match kernel_choice() {
        KernelChoice::Scalar => Tier::Scalar,
        KernelChoice::Auto | KernelChoice::Simd => simd_tier().unwrap_or(Tier::Scalar),
    }
}

/// Active tier name for reports: `"scalar"`, `"avx2"`, or `"neon"`.
pub fn kernel_name() -> &'static str {
    match active_tier() {
        Tier::Scalar => "scalar",
        Tier::Avx2 => "avx2",
        Tier::Neon => "neon",
    }
}

/// Dispatch the BSR panel kernel to the active SIMD tier. Returns `false`
/// when no SIMD kernel applies (tier scalar, or `b` not a lane multiple);
/// the caller then runs the scalar kernel.
///
/// # Safety
/// Same contract as [`super::micro::block_panel`].
#[allow(clippy::too_many_arguments)]
#[allow(unused_variables)]
pub unsafe fn try_block_panel(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::block_panel(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::block_panel(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        _ => false,
    }
}

/// Dispatch the transpose panel kernel (`y += x · blkᵀ`) to the active
/// SIMD tier. Returns `false` when no SIMD kernel applies.
///
/// # Safety
/// Same contract as [`super::micro::block_panel`].
#[allow(clippy::too_many_arguments)]
#[allow(unused_variables)]
pub unsafe fn try_block_panel_t(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::block_panel_t(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::block_panel_t(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        _ => false,
    }
}

/// Dispatch the dW scatter kernel to the active SIMD tier. Returns
/// `false` when no SIMD kernel applies.
///
/// # Safety
/// `blk.len() == b*b`, `ic + b <= x.cols`, `jc + b <= dy.cols`, and
/// `rows.end <= x.rows.min(dy.rows)` (the arch kernels load unchecked).
#[allow(unused_variables)]
pub unsafe fn try_scatter_block(
    b: usize,
    x: &Matrix,
    ic: usize,
    dy: &Matrix,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::scatter_block(b, x, ic, dy, jc, rows, blk);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::scatter_block(b, x, ic, dy, jc, rows, blk);
            true
        }
        _ => false,
    }
}

// ---------------------------------------------------------------------
// Reduced-precision kernel twins (bf16 / int8) — see `super::quant` for
// the storage formats and the scalar fallbacks these dispatch in front of
// ---------------------------------------------------------------------

/// Dispatch the bf16 forward panel kernel to the active SIMD tier.
/// Returns `false` when no SIMD kernel applies (the caller runs the
/// scalar twin in `quant`).
///
/// # Safety
/// Same contract as [`super::quant::block_panel_bf16`].
#[allow(clippy::too_many_arguments)]
#[allow(unused_variables)]
pub unsafe fn try_block_panel_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    rows: Range<usize>,
    blk: &[u16],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::block_panel_bf16(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::block_panel_bf16(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        _ => false,
    }
}

/// Dispatch the bf16 transpose panel kernel (`dX = dY·Wᵀ`) to the active
/// SIMD tier. Returns `false` when no SIMD kernel applies.
///
/// # Safety
/// Same contract as [`super::quant::block_panel_t_bf16`].
#[allow(clippy::too_many_arguments)]
#[allow(unused_variables)]
pub unsafe fn try_block_panel_t_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    rows: Range<usize>,
    blk: &[u16],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::block_panel_t_bf16(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::block_panel_t_bf16(b, x, ic, rows, blk, y, ldy, jc);
            true
        }
        _ => false,
    }
}

/// Dispatch the bf16 dW scatter kernel to the active SIMD tier. Returns
/// `false` when no SIMD kernel applies.
///
/// # Safety
/// Same bounds contract as [`try_scatter_block`], with bf16 operand
/// panels.
#[allow(unused_variables)]
pub unsafe fn try_scatter_block_bf16(
    b: usize,
    x: &Bf16Panel,
    ic: usize,
    dy: &Bf16Panel,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::scatter_block_bf16(b, x, ic, dy, jc, rows, blk);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 4 == 0 => {
            neon::scatter_block_bf16(b, x, ic, dy, jc, rows, blk);
            true
        }
        _ => false,
    }
}

/// Dispatch the int8 forward panel kernel (weights int8 + one scale per
/// block, f32 activations and accumulators) to the active SIMD tier.
/// Returns `false` when no SIMD kernel applies.
///
/// # Safety
/// Same contract as [`super::quant::block_panel_i8`].
#[allow(clippy::too_many_arguments)]
#[allow(unused_variables)]
pub unsafe fn try_block_panel_i8(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    q: &[i8],
    scale: f32,
    y: *mut f32,
    ldy: usize,
    jc: usize,
) -> bool {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if b % 8 == 0 => {
            avx2::block_panel_i8(b, x, ic, rows, q, scale, y, ldy, jc);
            true
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if b % 8 == 0 => {
            neon::block_panel_i8(b, x, ic, rows, q, scale, y, ldy, jc);
            true
        }
        _ => false,
    }
}

// ---------------------------------------------------------------------
// Vector primitives (attention kernel building blocks)
// ---------------------------------------------------------------------

/// `Σ a[i]·b[i]` on a pre-resolved tier. `tier` must come from
/// [`active_tier`]/[`simd_tier`] on this host (crate-internal so that
/// invariant stays local); hot loops resolve once and reuse.
#[inline]
pub(crate) fn dot_with(tier: Tier, a: &[f32], b: &[f32]) -> f32 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// `y[i] += alpha · x[i]` on a pre-resolved tier (see [`dot_with`]).
#[inline]
pub(crate) fn axpy_with(tier: Tier, alpha: f32, x: &[f32], y: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::axpy(alpha, x, y) },
        _ => axpy_scalar(alpha, x, y),
    }
}

/// `y[i] *= alpha` on a pre-resolved tier (see [`dot_with`]).
#[inline]
pub(crate) fn scale_with(tier: Tier, y: &mut [f32], alpha: f32) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { avx2::scale(y, alpha) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::scale(y, alpha) },
        _ => scale_scalar(y, alpha),
    }
}

/// `Σ a[i]·b[i]` over `min(len)` elements, on the active tier.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(active_tier(), a, b)
}

/// `y[i] += alpha · x[i]`, on the active tier.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_with(active_tier(), alpha, x, y)
}

/// `y[i] *= alpha`, on the active tier.
#[inline]
pub fn scale(y: &mut [f32], alpha: f32) {
    scale_with(active_tier(), y, alpha)
}

/// Portable reference for [`dot`] (4 partial sums so the scalar tier
/// still pipelines).
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; 4];
    let mut i = 0;
    while i + 4 <= n {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Portable reference for [`axpy`].
pub fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += alpha * *xv;
    }
}

/// Portable reference for [`scale`].
pub fn scale_scalar(y: &mut [f32], alpha: f32) {
    for yv in y.iter_mut() {
        *yv *= alpha;
    }
}

/// Fused SGD-with-momentum sweep on a pre-resolved tier (see
/// [`dot_with`]): `m[i] = momentum·m[i] + g[i]; w[i] -= lr·m[i]` over
/// `min(len)` elements — one pass, two FMAs per element, no temporary.
#[inline]
pub(crate) fn sgd_momentum_with(tier: Tier, w: &mut [f32], g: &[f32], m: &mut [f32],
                                lr: f32, momentum: f32) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { avx2::sgd_momentum(w, g, m, lr, momentum) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::sgd_momentum(w, g, m, lr, momentum) },
        _ => sgd_momentum_scalar(w, g, m, lr, momentum),
    }
}

/// Fused SGD-with-momentum sweep on the active tier.
#[inline]
pub fn sgd_momentum(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32, momentum: f32) {
    sgd_momentum_with(active_tier(), w, g, m, lr, momentum)
}

/// Portable reference for [`sgd_momentum`].
pub fn sgd_momentum_scalar(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32,
                           momentum: f32) {
    let n = w.len().min(g.len()).min(m.len());
    for i in 0..n {
        m[i] = momentum * m[i] + g[i];
        w[i] -= lr * m[i];
    }
}

// ---------------------------------------------------------------------
// AVX2 + FMA (8-lane f32)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    //! AVX2+FMA kernels. Every fn is `unsafe`: the caller must have
    //! verified `avx2` and `fma` at runtime (see [`super::simd_tier`]).

    use super::super::quant::{bf16_to_f32, Bf16Panel};
    use super::Range;
    use crate::sparse::dense::Matrix;
    use std::arch::x86_64::*;

    /// # Safety
    /// Same contract as `micro::block_panel`, plus `b % 8 == 0` and
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        blk: &[f32],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            panel_rows4(b, xp.add(r * ldx + ic), ldx, wp, y.add(r * ldy + jc), ldy);
            r += 4;
        }
        while r < rows.end {
            panel_row1(b, xp.add(r * ldx + ic), wp, y.add(r * ldy + jc));
            r += 1;
        }
    }

    /// Four activation rows share one sweep over the weight block; output
    /// columns are processed in strips of 16 (two ymm accumulators per
    /// row) with an 8-wide tail, so b ∈ {8, 16, 24, 32, 40, 48, …} all
    /// stay in registers.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn panel_rows4(b: usize, x0: *const f32, ldx: usize, w: *const f32, y0: *mut f32, ldy: usize) {
        let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
        let (y1, y2, y3) = (y0.add(ldy), y0.add(2 * ldy), y0.add(3 * ldy));
        let mut c = 0usize;
        while c + 16 <= b {
            let mut a00 = _mm256_loadu_ps(y0.add(c));
            let mut a01 = _mm256_loadu_ps(y0.add(c + 8));
            let mut a10 = _mm256_loadu_ps(y1.add(c));
            let mut a11 = _mm256_loadu_ps(y1.add(c + 8));
            let mut a20 = _mm256_loadu_ps(y2.add(c));
            let mut a21 = _mm256_loadu_ps(y2.add(c + 8));
            let mut a30 = _mm256_loadu_ps(y3.add(c));
            let mut a31 = _mm256_loadu_ps(y3.add(c + 8));
            for k in 0..b {
                let w0 = _mm256_loadu_ps(w.add(k * b + c));
                let w1 = _mm256_loadu_ps(w.add(k * b + c + 8));
                let s0 = _mm256_set1_ps(*x0.add(k));
                a00 = _mm256_fmadd_ps(s0, w0, a00);
                a01 = _mm256_fmadd_ps(s0, w1, a01);
                let s1 = _mm256_set1_ps(*x1.add(k));
                a10 = _mm256_fmadd_ps(s1, w0, a10);
                a11 = _mm256_fmadd_ps(s1, w1, a11);
                let s2 = _mm256_set1_ps(*x2.add(k));
                a20 = _mm256_fmadd_ps(s2, w0, a20);
                a21 = _mm256_fmadd_ps(s2, w1, a21);
                let s3 = _mm256_set1_ps(*x3.add(k));
                a30 = _mm256_fmadd_ps(s3, w0, a30);
                a31 = _mm256_fmadd_ps(s3, w1, a31);
            }
            _mm256_storeu_ps(y0.add(c), a00);
            _mm256_storeu_ps(y0.add(c + 8), a01);
            _mm256_storeu_ps(y1.add(c), a10);
            _mm256_storeu_ps(y1.add(c + 8), a11);
            _mm256_storeu_ps(y2.add(c), a20);
            _mm256_storeu_ps(y2.add(c + 8), a21);
            _mm256_storeu_ps(y3.add(c), a30);
            _mm256_storeu_ps(y3.add(c + 8), a31);
            c += 16;
        }
        while c + 8 <= b {
            let mut a0 = _mm256_loadu_ps(y0.add(c));
            let mut a1 = _mm256_loadu_ps(y1.add(c));
            let mut a2 = _mm256_loadu_ps(y2.add(c));
            let mut a3 = _mm256_loadu_ps(y3.add(c));
            for k in 0..b {
                let wv = _mm256_loadu_ps(w.add(k * b + c));
                a0 = _mm256_fmadd_ps(_mm256_set1_ps(*x0.add(k)), wv, a0);
                a1 = _mm256_fmadd_ps(_mm256_set1_ps(*x1.add(k)), wv, a1);
                a2 = _mm256_fmadd_ps(_mm256_set1_ps(*x2.add(k)), wv, a2);
                a3 = _mm256_fmadd_ps(_mm256_set1_ps(*x3.add(k)), wv, a3);
            }
            _mm256_storeu_ps(y0.add(c), a0);
            _mm256_storeu_ps(y1.add(c), a1);
            _mm256_storeu_ps(y2.add(c), a2);
            _mm256_storeu_ps(y3.add(c), a3);
            c += 8;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn panel_row1(b: usize, x0: *const f32, w: *const f32, y0: *mut f32) {
        let mut c = 0usize;
        while c + 16 <= b {
            let mut a0 = _mm256_loadu_ps(y0.add(c));
            let mut a1 = _mm256_loadu_ps(y0.add(c + 8));
            for k in 0..b {
                let s = _mm256_set1_ps(*x0.add(k));
                a0 = _mm256_fmadd_ps(s, _mm256_loadu_ps(w.add(k * b + c)), a0);
                a1 = _mm256_fmadd_ps(s, _mm256_loadu_ps(w.add(k * b + c + 8)), a1);
            }
            _mm256_storeu_ps(y0.add(c), a0);
            _mm256_storeu_ps(y0.add(c + 8), a1);
            c += 16;
        }
        while c + 8 <= b {
            let mut a0 = _mm256_loadu_ps(y0.add(c));
            for k in 0..b {
                let s = _mm256_set1_ps(*x0.add(k));
                a0 = _mm256_fmadd_ps(s, _mm256_loadu_ps(w.add(k * b + c)), a0);
            }
            _mm256_storeu_ps(y0.add(c), a0);
            c += 8;
        }
    }

    /// # Safety
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps(acc, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        let mut out = _mm_cvtss_f32(s);
        while i < n {
            out += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        out
    }

    /// # Safety
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let a = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let yv = _mm256_fmadd_ps(a, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), yv);
            i += 8;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 present.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(y: &mut [f32], alpha: f32) {
        let n = y.len();
        let a = _mm256_set1_ps(alpha);
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(yp.add(i), _mm256_mul_ps(a, _mm256_loadu_ps(yp.add(i))));
            i += 8;
        }
        while i < n {
            *yp.add(i) *= alpha;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Transpose panel kernel `y += x · blkᵀ`: per output column `c` the
    /// stored block row `c` is a contiguous dot operand, so the transpose
    /// costs nothing — four activation rows share each weight-row load
    /// and reduce with one horizontal sum per (row, column) pair.
    ///
    /// # Safety
    /// Same contract as `micro::block_panel`, plus `b % 8 == 0` and
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_t(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        blk: &[f32],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            t_rows4(b, xp.add(r * ldx + ic), ldx, wp, y.add(r * ldy + jc), ldy);
            r += 4;
        }
        while r < rows.end {
            t_row1(b, xp.add(r * ldx + ic), wp, y.add(r * ldy + jc));
            r += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn t_rows4(b: usize, x0: *const f32, ldx: usize, w: *const f32, y0: *mut f32, ldy: usize) {
        let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
        let (y1, y2, y3) = (y0.add(ldy), y0.add(2 * ldy), y0.add(3 * ldy));
        for c in 0..b {
            let wrow = w.add(c * b);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut k = 0usize;
            while k < b {
                let wv = _mm256_loadu_ps(wrow.add(k));
                a0 = _mm256_fmadd_ps(_mm256_loadu_ps(x0.add(k)), wv, a0);
                a1 = _mm256_fmadd_ps(_mm256_loadu_ps(x1.add(k)), wv, a1);
                a2 = _mm256_fmadd_ps(_mm256_loadu_ps(x2.add(k)), wv, a2);
                a3 = _mm256_fmadd_ps(_mm256_loadu_ps(x3.add(k)), wv, a3);
                k += 8;
            }
            *y0.add(c) += hsum(a0);
            *y1.add(c) += hsum(a1);
            *y2.add(c) += hsum(a2);
            *y3.add(c) += hsum(a3);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn t_row1(b: usize, x0: *const f32, w: *const f32, y0: *mut f32) {
        for c in 0..b {
            let wrow = w.add(c * b);
            let mut acc = _mm256_setzero_ps();
            let mut k = 0usize;
            while k < b {
                acc = _mm256_fmadd_ps(
                    _mm256_loadu_ps(x0.add(k)),
                    _mm256_loadu_ps(wrow.add(k)),
                    acc,
                );
                k += 8;
            }
            *y0.add(c) += hsum(acc);
        }
    }

    /// dW scatter kernel: `blk[k, c] += Σ_r x[r, ic+k] · dy[r, jc+c]`.
    /// Four batch rows share one load/store sweep over the gradient
    /// block, so each `blk` row round-trips memory once per four rank-1
    /// updates.
    ///
    /// # Safety
    /// `blk.len() == b*b` with `b % 8 == 0`; `ic + b <= x.cols`,
    /// `jc + b <= dy.cols`, `rows.end <= x.rows.min(dy.rows)`; AVX2+FMA
    /// present.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scatter_block(
        b: usize,
        x: &Matrix,
        ic: usize,
        dy: &Matrix,
        jc: usize,
        rows: Range<usize>,
        blk: &mut [f32],
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let dp = dy.data.as_ptr();
        let (ldx, ldd) = (x.cols, dy.cols);
        let wp = blk.as_mut_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            let x0 = xp.add(r * ldx + ic);
            let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
            let d0 = dp.add(r * ldd + jc);
            let (d1, d2, d3) = (d0.add(ldd), d0.add(2 * ldd), d0.add(3 * ldd));
            for k in 0..b {
                let wrow = wp.add(k * b);
                let s0 = _mm256_set1_ps(*x0.add(k));
                let s1 = _mm256_set1_ps(*x1.add(k));
                let s2 = _mm256_set1_ps(*x2.add(k));
                let s3 = _mm256_set1_ps(*x3.add(k));
                let mut c = 0usize;
                while c < b {
                    let mut acc = _mm256_loadu_ps(wrow.add(c));
                    acc = _mm256_fmadd_ps(s0, _mm256_loadu_ps(d0.add(c)), acc);
                    acc = _mm256_fmadd_ps(s1, _mm256_loadu_ps(d1.add(c)), acc);
                    acc = _mm256_fmadd_ps(s2, _mm256_loadu_ps(d2.add(c)), acc);
                    acc = _mm256_fmadd_ps(s3, _mm256_loadu_ps(d3.add(c)), acc);
                    _mm256_storeu_ps(wrow.add(c), acc);
                    c += 8;
                }
            }
            r += 4;
        }
        while r < rows.end {
            let x0 = xp.add(r * ldx + ic);
            let d0 = dp.add(r * ldd + jc);
            for k in 0..b {
                let wrow = wp.add(k * b);
                let s0 = _mm256_set1_ps(*x0.add(k));
                let mut c = 0usize;
                while c < b {
                    let acc = _mm256_fmadd_ps(
                        s0,
                        _mm256_loadu_ps(d0.add(c)),
                        _mm256_loadu_ps(wrow.add(c)),
                    );
                    _mm256_storeu_ps(wrow.add(c), acc);
                    c += 8;
                }
            }
            r += 1;
        }
    }

    /// Fused SGD-with-momentum sweep (`m = μ·m + g; w -= lr·m`).
    ///
    /// # Safety
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sgd_momentum(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32,
                               momentum: f32) {
        let n = w.len().min(g.len()).min(m.len());
        let vmu = _mm256_set1_ps(momentum);
        let vlr = _mm256_set1_ps(lr);
        let wp = w.as_mut_ptr();
        let gp = g.as_ptr();
        let mp = m.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let mv = _mm256_fmadd_ps(vmu, _mm256_loadu_ps(mp.add(i)), _mm256_loadu_ps(gp.add(i)));
            _mm256_storeu_ps(mp.add(i), mv);
            let wv = _mm256_fnmadd_ps(vlr, mv, _mm256_loadu_ps(wp.add(i)));
            _mm256_storeu_ps(wp.add(i), wv);
            i += 8;
        }
        while i < n {
            let mv = momentum * *mp.add(i) + *gp.add(i);
            *mp.add(i) = mv;
            *wp.add(i) -= lr * mv;
            i += 1;
        }
    }

    // -----------------------------------------------------------------
    // Reduced-precision twins: bf16 operands widen through a 16-bit left
    // shift (bf16 IS the f32 top half), int8 weights sign-extend and
    // convert — all in registers, every accumulator f32.
    // -----------------------------------------------------------------

    /// Widen 8 bf16 lanes to f32.
    ///
    /// # Safety
    /// `p` valid for 8 u16 reads; AVX2 present.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_bf16_8(p: *const u16) -> __m256 {
        let v = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(v)))
    }

    /// Widen 8 int8 lanes to f32.
    ///
    /// # Safety
    /// `p` valid for 8 i8 reads; AVX2 present.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_i8_8(p: *const i8) -> __m256 {
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i)))
    }

    /// bf16 forward panel kernel (see `quant::block_panel_bf16`).
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_bf16`, plus `b % 8 == 0` and
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        rows: Range<usize>,
        blk: &[u16],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            let mut c = 0usize;
            while c + 8 <= b {
                let mut acc = _mm256_loadu_ps(y0.add(c));
                for k in 0..b {
                    let s = _mm256_set1_ps(bf16_to_f32(*x0.add(k)));
                    acc = _mm256_fmadd_ps(s, load_bf16_8(wp.add(k * b + c)), acc);
                }
                _mm256_storeu_ps(y0.add(c), acc);
                c += 8;
            }
        }
    }

    /// bf16 transpose panel kernel (see `quant::block_panel_t_bf16`).
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_t_bf16`, plus `b % 8 == 0`
    /// and AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_t_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        rows: Range<usize>,
        blk: &[u16],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            for c in 0..b {
                let wrow = wp.add(c * b);
                let mut acc = _mm256_setzero_ps();
                let mut k = 0usize;
                while k < b {
                    acc = _mm256_fmadd_ps(
                        load_bf16_8(x0.add(k)),
                        load_bf16_8(wrow.add(k)),
                        acc,
                    );
                    k += 8;
                }
                *y0.add(c) += hsum(acc);
            }
        }
    }

    /// bf16 dW scatter kernel: bf16 operand panels, f32 gradient block.
    ///
    /// # Safety
    /// Same bounds contract as [`scatter_block`] with `b % 8 == 0`,
    /// bf16 panels, AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scatter_block_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        dy: &Bf16Panel,
        jc: usize,
        rows: Range<usize>,
        blk: &mut [f32],
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let dp = dy.data.as_ptr();
        let (ldx, ldd) = (x.cols, dy.cols);
        let wp = blk.as_mut_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let d0 = dp.add(r * ldd + jc);
            for k in 0..b {
                let wrow = wp.add(k * b);
                let s0 = _mm256_set1_ps(bf16_to_f32(*x0.add(k)));
                let mut c = 0usize;
                while c < b {
                    let acc = _mm256_fmadd_ps(
                        s0,
                        load_bf16_8(d0.add(c)),
                        _mm256_loadu_ps(wrow.add(c)),
                    );
                    _mm256_storeu_ps(wrow.add(c), acc);
                    c += 8;
                }
            }
        }
    }

    /// int8 forward panel kernel: int8 weight lanes widen in registers,
    /// f32 accumulate, one scale multiply per block per output strip.
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_i8`, plus `b % 8 == 0` and
    /// AVX2+FMA present.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_i8(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        q: &[i8],
        scale: f32,
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(q.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let qp = q.as_ptr();
        let vs = _mm256_set1_ps(scale);
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            let mut c = 0usize;
            while c + 8 <= b {
                let mut acc = _mm256_setzero_ps();
                for k in 0..b {
                    let s = _mm256_set1_ps(*x0.add(k));
                    acc = _mm256_fmadd_ps(s, load_i8_8(qp.add(k * b + c)), acc);
                }
                let yv = _mm256_fmadd_ps(vs, acc, _mm256_loadu_ps(y0.add(c)));
                _mm256_storeu_ps(y0.add(c), yv);
                c += 8;
            }
        }
    }
}

// ---------------------------------------------------------------------
// NEON (4-lane f32)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
pub mod neon {
    //! NEON kernels. Every fn is `unsafe`: the caller must have verified
    //! `neon` at runtime (see [`super::simd_tier`]).

    use super::super::quant::{bf16_to_f32, Bf16Panel};
    use super::Range;
    use crate::sparse::dense::Matrix;
    use std::arch::aarch64::*;

    /// # Safety
    /// Same contract as `micro::block_panel`, plus `b % 4 == 0` and NEON
    /// present.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        blk: &[f32],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            panel_rows4(b, xp.add(r * ldx + ic), ldx, wp, y.add(r * ldy + jc), ldy);
            r += 4;
        }
        while r < rows.end {
            panel_row1(b, xp.add(r * ldx + ic), wp, y.add(r * ldy + jc));
            r += 1;
        }
    }

    /// Four activation rows share one sweep over the weight block; output
    /// columns in strips of 8 (two q-register accumulators per row) with
    /// a 4-wide tail.
    #[target_feature(enable = "neon")]
    unsafe fn panel_rows4(b: usize, x0: *const f32, ldx: usize, w: *const f32, y0: *mut f32, ldy: usize) {
        let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
        let (y1, y2, y3) = (y0.add(ldy), y0.add(2 * ldy), y0.add(3 * ldy));
        let mut c = 0usize;
        while c + 8 <= b {
            let mut a00 = vld1q_f32(y0.add(c));
            let mut a01 = vld1q_f32(y0.add(c + 4));
            let mut a10 = vld1q_f32(y1.add(c));
            let mut a11 = vld1q_f32(y1.add(c + 4));
            let mut a20 = vld1q_f32(y2.add(c));
            let mut a21 = vld1q_f32(y2.add(c + 4));
            let mut a30 = vld1q_f32(y3.add(c));
            let mut a31 = vld1q_f32(y3.add(c + 4));
            for k in 0..b {
                let w0 = vld1q_f32(w.add(k * b + c));
                let w1 = vld1q_f32(w.add(k * b + c + 4));
                let s0 = *x0.add(k);
                a00 = vfmaq_n_f32(a00, w0, s0);
                a01 = vfmaq_n_f32(a01, w1, s0);
                let s1 = *x1.add(k);
                a10 = vfmaq_n_f32(a10, w0, s1);
                a11 = vfmaq_n_f32(a11, w1, s1);
                let s2 = *x2.add(k);
                a20 = vfmaq_n_f32(a20, w0, s2);
                a21 = vfmaq_n_f32(a21, w1, s2);
                let s3 = *x3.add(k);
                a30 = vfmaq_n_f32(a30, w0, s3);
                a31 = vfmaq_n_f32(a31, w1, s3);
            }
            vst1q_f32(y0.add(c), a00);
            vst1q_f32(y0.add(c + 4), a01);
            vst1q_f32(y1.add(c), a10);
            vst1q_f32(y1.add(c + 4), a11);
            vst1q_f32(y2.add(c), a20);
            vst1q_f32(y2.add(c + 4), a21);
            vst1q_f32(y3.add(c), a30);
            vst1q_f32(y3.add(c + 4), a31);
            c += 8;
        }
        while c + 4 <= b {
            let mut a0 = vld1q_f32(y0.add(c));
            let mut a1 = vld1q_f32(y1.add(c));
            let mut a2 = vld1q_f32(y2.add(c));
            let mut a3 = vld1q_f32(y3.add(c));
            for k in 0..b {
                let wv = vld1q_f32(w.add(k * b + c));
                a0 = vfmaq_n_f32(a0, wv, *x0.add(k));
                a1 = vfmaq_n_f32(a1, wv, *x1.add(k));
                a2 = vfmaq_n_f32(a2, wv, *x2.add(k));
                a3 = vfmaq_n_f32(a3, wv, *x3.add(k));
            }
            vst1q_f32(y0.add(c), a0);
            vst1q_f32(y1.add(c), a1);
            vst1q_f32(y2.add(c), a2);
            vst1q_f32(y3.add(c), a3);
            c += 4;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn panel_row1(b: usize, x0: *const f32, w: *const f32, y0: *mut f32) {
        let mut c = 0usize;
        while c + 8 <= b {
            let mut a0 = vld1q_f32(y0.add(c));
            let mut a1 = vld1q_f32(y0.add(c + 4));
            for k in 0..b {
                let s = *x0.add(k);
                a0 = vfmaq_n_f32(a0, vld1q_f32(w.add(k * b + c)), s);
                a1 = vfmaq_n_f32(a1, vld1q_f32(w.add(k * b + c + 4)), s);
            }
            vst1q_f32(y0.add(c), a0);
            vst1q_f32(y0.add(c + 4), a1);
            c += 8;
        }
        while c + 4 <= b {
            let mut a0 = vld1q_f32(y0.add(c));
            for k in 0..b {
                a0 = vfmaq_n_f32(a0, vld1q_f32(w.add(k * b + c)), *x0.add(k));
            }
            vst1q_f32(y0.add(c), a0);
            c += 4;
        }
    }

    /// # Safety
    /// NEON present.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            i += 4;
        }
        let mut out = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            out += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        out
    }

    /// # Safety
    /// NEON present.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let yv = vfmaq_n_f32(vld1q_f32(yp.add(i)), vld1q_f32(xp.add(i)), alpha);
            vst1q_f32(yp.add(i), yv);
            i += 4;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// NEON present.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale(y: &mut [f32], alpha: f32) {
        let n = y.len();
        let a = vdupq_n_f32(alpha);
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(yp.add(i), vmulq_f32(a, vld1q_f32(yp.add(i))));
            i += 4;
        }
        while i < n {
            *yp.add(i) *= alpha;
            i += 1;
        }
    }

    /// Transpose panel kernel `y += x · blkᵀ` (see the AVX2 twin): the
    /// stored block rows are contiguous dot operands, one `vaddvq`
    /// horizontal sum per (row, column) pair.
    ///
    /// # Safety
    /// Same contract as `micro::block_panel`, plus `b % 4 == 0` and NEON
    /// present.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_t(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        blk: &[f32],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            let x0 = xp.add(r * ldx + ic);
            let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
            let y0 = y.add(r * ldy + jc);
            let (y1, y2, y3) = (y0.add(ldy), y0.add(2 * ldy), y0.add(3 * ldy));
            for c in 0..b {
                let wrow = wp.add(c * b);
                let mut a0 = vdupq_n_f32(0.0);
                let mut a1 = vdupq_n_f32(0.0);
                let mut a2 = vdupq_n_f32(0.0);
                let mut a3 = vdupq_n_f32(0.0);
                let mut k = 0usize;
                while k < b {
                    let wv = vld1q_f32(wrow.add(k));
                    a0 = vfmaq_f32(a0, vld1q_f32(x0.add(k)), wv);
                    a1 = vfmaq_f32(a1, vld1q_f32(x1.add(k)), wv);
                    a2 = vfmaq_f32(a2, vld1q_f32(x2.add(k)), wv);
                    a3 = vfmaq_f32(a3, vld1q_f32(x3.add(k)), wv);
                    k += 4;
                }
                *y0.add(c) += vaddvq_f32(a0);
                *y1.add(c) += vaddvq_f32(a1);
                *y2.add(c) += vaddvq_f32(a2);
                *y3.add(c) += vaddvq_f32(a3);
            }
            r += 4;
        }
        while r < rows.end {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            for c in 0..b {
                let wrow = wp.add(c * b);
                let mut acc = vdupq_n_f32(0.0);
                let mut k = 0usize;
                while k < b {
                    acc = vfmaq_f32(acc, vld1q_f32(x0.add(k)), vld1q_f32(wrow.add(k)));
                    k += 4;
                }
                *y0.add(c) += vaddvq_f32(acc);
            }
            r += 1;
        }
    }

    /// dW scatter kernel (see the AVX2 twin).
    ///
    /// # Safety
    /// `blk.len() == b*b` with `b % 4 == 0`; `ic + b <= x.cols`,
    /// `jc + b <= dy.cols`, `rows.end <= x.rows.min(dy.rows)`; NEON
    /// present.
    #[target_feature(enable = "neon")]
    pub unsafe fn scatter_block(
        b: usize,
        x: &Matrix,
        ic: usize,
        dy: &Matrix,
        jc: usize,
        rows: Range<usize>,
        blk: &mut [f32],
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let dp = dy.data.as_ptr();
        let (ldx, ldd) = (x.cols, dy.cols);
        let wp = blk.as_mut_ptr();
        let mut r = rows.start;
        while r + 4 <= rows.end {
            let x0 = xp.add(r * ldx + ic);
            let (x1, x2, x3) = (x0.add(ldx), x0.add(2 * ldx), x0.add(3 * ldx));
            let d0 = dp.add(r * ldd + jc);
            let (d1, d2, d3) = (d0.add(ldd), d0.add(2 * ldd), d0.add(3 * ldd));
            for k in 0..b {
                let wrow = wp.add(k * b);
                let (s0, s1, s2, s3) =
                    (*x0.add(k), *x1.add(k), *x2.add(k), *x3.add(k));
                let mut c = 0usize;
                while c < b {
                    let mut acc = vld1q_f32(wrow.add(c));
                    acc = vfmaq_n_f32(acc, vld1q_f32(d0.add(c)), s0);
                    acc = vfmaq_n_f32(acc, vld1q_f32(d1.add(c)), s1);
                    acc = vfmaq_n_f32(acc, vld1q_f32(d2.add(c)), s2);
                    acc = vfmaq_n_f32(acc, vld1q_f32(d3.add(c)), s3);
                    vst1q_f32(wrow.add(c), acc);
                    c += 4;
                }
            }
            r += 4;
        }
        while r < rows.end {
            let x0 = xp.add(r * ldx + ic);
            let d0 = dp.add(r * ldd + jc);
            for k in 0..b {
                let wrow = wp.add(k * b);
                let s0 = *x0.add(k);
                let mut c = 0usize;
                while c < b {
                    let acc =
                        vfmaq_n_f32(vld1q_f32(wrow.add(c)), vld1q_f32(d0.add(c)), s0);
                    vst1q_f32(wrow.add(c), acc);
                    c += 4;
                }
            }
            r += 1;
        }
    }

    /// Fused SGD-with-momentum sweep (`m = μ·m + g; w -= lr·m`).
    ///
    /// # Safety
    /// NEON present.
    #[target_feature(enable = "neon")]
    pub unsafe fn sgd_momentum(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32,
                               momentum: f32) {
        let n = w.len().min(g.len()).min(m.len());
        let wp = w.as_mut_ptr();
        let gp = g.as_ptr();
        let mp = m.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let mv = vfmaq_n_f32(vld1q_f32(gp.add(i)), vld1q_f32(mp.add(i)), momentum);
            vst1q_f32(mp.add(i), mv);
            // w -= lr·m as an FMA with the negated rate (avoids relying on
            // the fused-subtract intrinsic)
            let wv = vfmaq_n_f32(vld1q_f32(wp.add(i)), mv, -lr);
            vst1q_f32(wp.add(i), wv);
            i += 4;
        }
        while i < n {
            let mv = momentum * *mp.add(i) + *gp.add(i);
            *mp.add(i) = mv;
            *wp.add(i) -= lr * mv;
            i += 1;
        }
    }

    // -----------------------------------------------------------------
    // Reduced-precision twins: bf16 widens through a 16-bit left shift
    // (bf16 IS the f32 top half), int8 sign-extends through the vmovl
    // chain — all in registers, every accumulator f32.
    // -----------------------------------------------------------------

    /// Widen 4 bf16 lanes to f32.
    ///
    /// # Safety
    /// `p` valid for 4 u16 reads; NEON present.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn load_bf16_4(p: *const u16) -> float32x4_t {
        vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vld1_u16(p))))
    }

    /// bf16 forward panel kernel (see `quant::block_panel_bf16`).
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_bf16`, plus `b % 4 == 0` and
    /// NEON present.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        rows: Range<usize>,
        blk: &[u16],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            let mut c = 0usize;
            while c + 4 <= b {
                let mut acc = vld1q_f32(y0.add(c));
                for k in 0..b {
                    let s = bf16_to_f32(*x0.add(k));
                    acc = vfmaq_n_f32(acc, load_bf16_4(wp.add(k * b + c)), s);
                }
                vst1q_f32(y0.add(c), acc);
                c += 4;
            }
        }
    }

    /// bf16 transpose panel kernel (see `quant::block_panel_t_bf16`).
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_t_bf16`, plus `b % 4 == 0`
    /// and NEON present.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_t_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        rows: Range<usize>,
        blk: &[u16],
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let wp = blk.as_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            for c in 0..b {
                let wrow = wp.add(c * b);
                let mut acc = vdupq_n_f32(0.0);
                let mut k = 0usize;
                while k < b {
                    acc = vfmaq_f32(acc, load_bf16_4(x0.add(k)), load_bf16_4(wrow.add(k)));
                    k += 4;
                }
                *y0.add(c) += vaddvq_f32(acc);
            }
        }
    }

    /// bf16 dW scatter kernel: bf16 operand panels, f32 gradient block.
    ///
    /// # Safety
    /// Same bounds contract as [`scatter_block`] with `b % 4 == 0`,
    /// bf16 panels, NEON present.
    #[target_feature(enable = "neon")]
    pub unsafe fn scatter_block_bf16(
        b: usize,
        x: &Bf16Panel,
        ic: usize,
        dy: &Bf16Panel,
        jc: usize,
        rows: Range<usize>,
        blk: &mut [f32],
    ) {
        debug_assert_eq!(b % 4, 0);
        debug_assert_eq!(blk.len(), b * b);
        let xp = x.data.as_ptr();
        let dp = dy.data.as_ptr();
        let (ldx, ldd) = (x.cols, dy.cols);
        let wp = blk.as_mut_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let d0 = dp.add(r * ldd + jc);
            for k in 0..b {
                let wrow = wp.add(k * b);
                let s0 = bf16_to_f32(*x0.add(k));
                let mut c = 0usize;
                while c < b {
                    let acc =
                        vfmaq_n_f32(vld1q_f32(wrow.add(c)), load_bf16_4(d0.add(c)), s0);
                    vst1q_f32(wrow.add(c), acc);
                    c += 4;
                }
            }
        }
    }

    /// int8 forward panel kernel: int8 weight lanes widen in registers,
    /// f32 accumulate, one scale multiply per block per output strip.
    ///
    /// # Safety
    /// Same contract as `quant::block_panel_i8`, plus `b % 8 == 0` and
    /// NEON present.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn block_panel_i8(
        b: usize,
        x: &Matrix,
        ic: usize,
        rows: Range<usize>,
        q: &[i8],
        scale: f32,
        y: *mut f32,
        ldy: usize,
        jc: usize,
    ) {
        debug_assert_eq!(b % 8, 0);
        debug_assert_eq!(q.len(), b * b);
        let xp = x.data.as_ptr();
        let ldx = x.cols;
        let qp = q.as_ptr();
        for r in rows {
            let x0 = xp.add(r * ldx + ic);
            let y0 = y.add(r * ldy + jc);
            let mut c = 0usize;
            while c + 8 <= b {
                let mut a0 = vdupq_n_f32(0.0);
                let mut a1 = vdupq_n_f32(0.0);
                for k in 0..b {
                    let w16 = vmovl_s8(vld1_s8(qp.add(k * b + c)));
                    let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16)));
                    let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16)));
                    let xv = *x0.add(k);
                    a0 = vfmaq_n_f32(a0, lo, xv);
                    a1 = vfmaq_n_f32(a1, hi, xv);
                }
                vst1q_f32(y0.add(c), vfmaq_n_f32(vld1q_f32(y0.add(c)), a0, scale));
                vst1q_f32(
                    y0.add(c + 4),
                    vfmaq_n_f32(vld1q_f32(y0.add(c + 4)), a1, scale),
                );
                c += 8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn choice_parses() {
        assert_eq!(KernelChoice::parse("auto"), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::parse(" SIMD "), Some(KernelChoice::Simd));
        assert_eq!(KernelChoice::parse("scalar"), Some(KernelChoice::Scalar));
        assert_eq!(KernelChoice::parse("avx512"), None);
    }

    #[test]
    fn kernel_name_is_consistent_with_tier() {
        // whatever the host, the reported name matches the resolved tier
        let name = kernel_name();
        match active_tier() {
            Tier::Scalar => assert_eq!(name, "scalar"),
            Tier::Avx2 => assert_eq!(name, "avx2"),
            Tier::Neon => assert_eq!(name, "neon"),
        }
    }

    #[test]
    fn scalar_primitives_agree_with_naive() {
        let mut rng = Rng::new(42);
        let a = rng.normal_vec(37, 1.0);
        let b = rng.normal_vec(37, 1.0);
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot_scalar(&a, &b) - naive).abs() < 1e-3);
        let mut y = b.clone();
        axpy_scalar(0.5, &a, &mut y);
        for i in 0..37 {
            assert!((y[i] - (b[i] + 0.5 * a[i])).abs() < 1e-5);
        }
        scale_scalar(&mut y, 2.0);
        for i in 0..37 {
            assert!((y[i] - 2.0 * (b[i] + 0.5 * a[i])).abs() < 1e-4);
        }
    }

    // SIMD-vs-scalar parity, exercised directly against the arch kernels
    // (no global kernel-choice mutation, so tests stay race-free).
    #[test]
    fn simd_primitives_match_scalar_when_available() {
        if simd_tier().is_none() {
            return; // host has no vector unit; the scalar tier is the tier
        }
        let mut rng = Rng::new(43);
        for n in [1usize, 4, 7, 8, 16, 33, 64, 100] {
            let a = rng.normal_vec(n, 1.0);
            let b = rng.normal_vec(n, 1.0);
            let want = dot_scalar(&a, &b);
            let got = dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (n as f32).sqrt(), "dot n={n}");
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.7, &a, &mut y1);
            axpy_scalar(0.7, &a, &mut y2);
            for i in 0..n {
                assert!((y1[i] - y2[i]).abs() < 1e-4, "axpy n={n} i={i}");
            }
            scale(&mut y1, 0.3);
            scale_scalar(&mut y2, 0.3);
            for i in 0..n {
                assert!((y1[i] - y2[i]).abs() < 1e-4, "scale n={n} i={i}");
            }
        }
    }

    #[test]
    fn sgd_momentum_matches_scalar_and_hand_math() {
        let mut rng = Rng::new(44);
        for n in [1usize, 4, 7, 8, 16, 33, 100] {
            let w0 = rng.normal_vec(n, 1.0);
            let g = rng.normal_vec(n, 1.0);
            let m0 = rng.normal_vec(n, 1.0);
            // hand math
            let mut wh = w0.clone();
            let mut mh = m0.clone();
            for i in 0..n {
                mh[i] = 0.9 * mh[i] + g[i];
                wh[i] -= 0.01 * mh[i];
            }
            // scalar tier
            let mut ws = w0.clone();
            let mut ms = m0.clone();
            sgd_momentum_scalar(&mut ws, &g, &mut ms, 0.01, 0.9);
            for i in 0..n {
                assert!((ws[i] - wh[i]).abs() < 1e-6, "scalar w n={n} i={i}");
                assert!((ms[i] - mh[i]).abs() < 1e-6, "scalar m n={n} i={i}");
            }
            // active tier (SIMD where available)
            let mut wv = w0.clone();
            let mut mv = m0.clone();
            sgd_momentum(&mut wv, &g, &mut mv, 0.01, 0.9);
            for i in 0..n {
                assert!((wv[i] - wh[i]).abs() < 1e-5, "simd w n={n} i={i}");
                assert!((mv[i] - mh[i]).abs() < 1e-5, "simd m n={n} i={i}");
            }
        }
    }

    #[test]
    fn simd_block_panel_t_matches_scalar_reference() {
        if simd_tier().is_none() {
            return;
        }
        use crate::sparse::dense::Matrix;
        for b in [8usize, 16, 32, 48] {
            let mut rng = Rng::new(500 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blk = rng.normal_vec(b * b, 0.5);
            let mut got = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut want = got.clone();
            // scalar reference: y[r, c] += dot(x-seg, blk row c)
            for r in 0..7 {
                for c in 0..b {
                    let mut acc = want.get(r, b + c);
                    for k in 0..b {
                        acc += x.get(r, b + k) * blk[c * b + k];
                    }
                    want.set(r, b + c, acc);
                }
            }
            let ldy = got.cols;
            let handled = unsafe {
                try_block_panel_t(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
            };
            if !handled {
                #[cfg(target_arch = "x86_64")]
                unsafe {
                    avx2::block_panel_t(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
                };
                #[cfg(target_arch = "aarch64")]
                unsafe {
                    neon::block_panel_t(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
                };
            }
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "b={b}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn simd_scatter_block_matches_scalar_reference() {
        if simd_tier().is_none() {
            return;
        }
        use crate::sparse::dense::Matrix;
        for b in [8usize, 16, 32, 48] {
            let mut rng = Rng::new(600 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let dy = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut got = rng.normal_vec(b * b, 0.5);
            let mut want = got.clone();
            for r in 0..7 {
                for k in 0..b {
                    for c in 0..b {
                        want[k * b + c] += x.get(r, b + k) * dy.get(r, b + c);
                    }
                }
            }
            let handled = unsafe {
                try_scatter_block(b, &x, b, &dy, b, 0..7, &mut got)
            };
            if !handled {
                #[cfg(target_arch = "x86_64")]
                unsafe {
                    avx2::scatter_block(b, &x, b, &dy, b, 0..7, &mut got)
                };
                #[cfg(target_arch = "aarch64")]
                unsafe {
                    neon::scatter_block(b, &x, b, &dy, b, 0..7, &mut got)
                };
            }
            let diff = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-3, "b={b}: {diff}");
        }
    }

    #[test]
    fn simd_block_panel_matches_scalar_reference() {
        if simd_tier().is_none() {
            return;
        }
        use crate::sparse::dense::Matrix;
        for b in [8usize, 16, 32, 48] {
            let mut rng = Rng::new(200 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blk = rng.normal_vec(b * b, 0.5);
            let mut got = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut want = got.clone();
            // scalar reference: plain triple loop
            for r in 0..7 {
                for k in 0..b {
                    let a = x.get(r, b + k);
                    for c in 0..b {
                        let v = want.get(r, b + c) + a * blk[k * b + c];
                        want.set(r, b + c, v);
                    }
                }
            }
            let ldy = got.cols;
            let handled = unsafe {
                try_block_panel(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
            };
            // under choice=scalar this returns false — run the arch kernel
            // directly so the parity check always executes where possible
            if !handled {
                #[cfg(target_arch = "x86_64")]
                unsafe {
                    avx2::block_panel(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
                };
                #[cfg(target_arch = "aarch64")]
                unsafe {
                    neon::block_panel(b, &x, b, 0..7, &blk, got.data.as_mut_ptr(), ldy, b)
                };
            }
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "b={b}: {}",
                got.max_abs_diff(&want)
            );
        }
    }
}
