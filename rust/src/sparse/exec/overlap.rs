//! Overlap scheduler: dW ∥ dX dependency-driven backward (ISSUE 10).
//!
//! The sequential backward runs every layer as dX-then-dW under one
//! latch, so the pool idles while layer i−1's dX (the only thing the
//! critical path actually needs) is still propagating. This module
//! provides the runtime half of the split: a single persistent FIFO
//! worker thread that executes *deferred* dW/db (and, when enabled,
//! eager-update) tasks off the critical path, plus the mode axis that
//! controls it (`PIXELFLY_OVERLAP={off,dw,dw+comm}` / `--overlap`).
//!
//! Why ONE worker, and why FIFO: bit-exactness. Each deferred task is
//! an entire layer's dW sweep (which internally fans out over the
//! resident pool with its worker-count-invariant scatter schedule, see
//! [`super::pool`]) followed optionally by a grad-sink copy and an
//! eager `sgd_momentum` sweep. A single FIFO consumer executes those
//! layer tasks in exactly the order the serial backward would have —
//! reverse layer order — so every float is produced by the same
//! operation sequence as `PIXELFLY_OVERLAP=off`, just at a different
//! wall-clock time. The dX critical path on the calling thread never
//! reads anything a deferred task writes (grad buffers are layer-owned;
//! weights are only mutated by the eager update *after* every consumer
//! of that layer's weights has run), so overlap changes timing, not
//! bits — the proptests pin this.
//!
//! Scopes are per-call ([`OverlapScope`]): each backward owns an
//! `Arc`-shared completion board, so concurrent train steps (parallel
//! tests) sharing the one worker thread only wait on their own tasks.
//! Task panics are caught on the worker, parked on the board, and
//! re-thrown on the scope's thread at drain — same surface behavior as
//! the pool's dispatch protocol.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use super::workspace::Workspace;

// ---------------------------------------------------------------------
// Mode axis
// ---------------------------------------------------------------------

/// How much of the train step runs off the critical path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// fused serial backward + whole-model update pass (the pre-overlap
    /// schedule — and the bit-oracle the other modes are pinned to)
    Off,
    /// dW/db deferred to the overlap worker; eager per-layer updates
    Dw,
    /// `Dw` plus dist grad streaming: a worker ships gradient bucket k
    /// the moment layer k's dW lands, instead of after the full backward
    DwComm,
}

impl OverlapMode {
    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "off" => Some(OverlapMode::Off),
            "dw" => Some(OverlapMode::Dw),
            "dw+comm" => Some(OverlapMode::DwComm),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverlapMode::Off => "off",
            OverlapMode::Dw => "dw",
            OverlapMode::DwComm => "dw+comm",
        }
    }

    /// Deferred-dW scheduling engaged (either overlap tier).
    pub fn dw(self) -> bool {
        !matches!(self, OverlapMode::Off)
    }

    /// Comm/compute overlap engaged (dist workers stream buckets).
    pub fn comm(self) -> bool {
        matches!(self, OverlapMode::DwComm)
    }
}

/// Runtime override: 0 = unset (fall through to env), else mode + 1.
static OVERLAP_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Env resolution happens once; tests that need to flip modes use
/// [`set_overlap`], which wins over the cached env value.
static OVERLAP_ENV: OnceLock<OverlapMode> = OnceLock::new();

/// Force an overlap mode (`Some`) or drop back to env/default (`None`).
/// Process-global, like [`super::pool::set_pool_mode`] — tests that flip
/// it must restore under a drop guard.
pub fn set_overlap(mode: Option<OverlapMode>) {
    let v = match mode {
        None => 0,
        Some(OverlapMode::Off) => 1,
        Some(OverlapMode::Dw) => 2,
        Some(OverlapMode::DwComm) => 3,
    };
    OVERLAP_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The active overlap mode: [`set_overlap`] override, then the
/// `PIXELFLY_OVERLAP` environment variable, then the default `dw+comm`.
/// An unrecognized env value falls back to the default.
pub fn overlap_mode() -> OverlapMode {
    match OVERLAP_OVERRIDE.load(Ordering::Relaxed) {
        1 => return OverlapMode::Off,
        2 => return OverlapMode::Dw,
        3 => return OverlapMode::DwComm,
        _ => {}
    }
    *OVERLAP_ENV.get_or_init(|| {
        std::env::var("PIXELFLY_OVERLAP")
            .ok()
            .and_then(|s| OverlapMode::parse(&s))
            .unwrap_or(OverlapMode::DwComm)
    })
}

// ---------------------------------------------------------------------
// The overlap worker + scope protocol
// ---------------------------------------------------------------------

/// What the overlap thread measured for one scope: `exposed` is how
/// long the scope's own thread had to wait at drain for stragglers,
/// `hidden` is the rest of the worker's busy time — deferred work that
/// genuinely ran under the dX critical path.
/// `hidden + exposed ≈ serial dW+update time`; a perfect overlap has
/// `exposed ≈ 0`.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapStats {
    pub hidden: Duration,
    pub exposed: Duration,
}

type Task = Box<dyn FnOnce(&mut Workspace) + Send + 'static>;

struct Job {
    state: Arc<ScopeState>,
    task: Task,
}

/// Per-scope completion board. `done`/`busy` are written by the worker
/// under the mutex (the release gives the draining thread its
/// happens-before edge on everything the tasks wrote), `panic` parks
/// the first task panic for re-throw at drain.
struct Board {
    done: usize,
    busy: Duration,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct ScopeState {
    board: Mutex<Board>,
    cv: Condvar,
}

impl ScopeState {
    fn new() -> Arc<ScopeState> {
        Arc::new(ScopeState {
            board: Mutex::new(Board { done: 0, busy: Duration::ZERO, panic: None }),
            cv: Condvar::new(),
        })
    }
}

/// The single worker thread's inbox. A `Mutex` around the `Sender`
/// keeps enqueue order identical to program order across one scope
/// (scopes enqueue from one thread anyway; the lock is for cheap
/// cross-scope safety).
static INBOX: OnceLock<Mutex<Sender<Job>>> = OnceLock::new();

fn inbox() -> &'static Mutex<Sender<Job>> {
    INBOX.get_or_init(|| {
        let (tx, rx) = channel::<Job>();
        std::thread::Builder::new()
            .name("pixelfly-overlap".into())
            .spawn(move || worker_entry(rx))
            .expect("spawn overlap worker");
        Mutex::new(tx)
    })
}

/// Worker body: FIFO-execute deferred tasks with a pinned [`Workspace`],
/// catching panics per task so one bad scope can't kill the thread.
fn worker_entry(rx: Receiver<Job>) {
    let mut ws = Workspace::new();
    for job in rx {
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| (job.task)(&mut ws)));
        let busy = t0.elapsed();
        let mut b = job.state.board.lock().unwrap_or_else(PoisonError::into_inner);
        b.done += 1;
        b.busy += busy;
        if let Err(p) = result {
            if b.panic.is_none() {
                b.panic = Some(p);
            }
        }
        drop(b);
        job.state.cv.notify_all();
    }
}

/// A borrow-scoped batch of deferred tasks. `defer` hands a closure to
/// the overlap worker; `drain` blocks until every deferred task of THIS
/// scope finished and returns the hidden/exposed split. Dropping the
/// scope without draining still waits (drop guard), so borrows captured
/// by the tasks provably outlive every worker access even on unwind.
pub struct OverlapScope<'a> {
    state: Arc<ScopeState>,
    submitted: usize,
    drained: bool,
    _anchor: std::marker::PhantomData<&'a mut ()>,
}

impl<'a> OverlapScope<'a> {
    pub fn new() -> OverlapScope<'a> {
        OverlapScope {
            state: ScopeState::new(),
            submitted: 0,
            drained: false,
            _anchor: std::marker::PhantomData,
        }
    }

    /// Queue `f` on the overlap worker. Tasks run in FIFO submission
    /// order — the caller is responsible for submitting in the serial
    /// schedule's order (reverse layer order for a backward).
    pub fn defer(&mut self, f: impl FnOnce(&mut Workspace) + Send + 'a) {
        let boxed: Box<dyn FnOnce(&mut Workspace) + Send + 'a> = Box::new(f);
        // Safety: lifetime erasure only — `drain` (or the drop guard)
        // blocks this thread until the worker has finished every task
        // of this scope, so the 'a borrows inside the closure are live
        // for the whole time the worker can touch them.
        let boxed: Task = unsafe { std::mem::transmute(boxed) };
        let job = Job { state: Arc::clone(&self.state), task: boxed };
        let tx = inbox().lock().unwrap_or_else(PoisonError::into_inner);
        tx.send(job).expect("overlap worker alive for the process lifetime");
        drop(tx);
        self.submitted += 1;
    }

    fn wait_all(&self) -> (Duration, Option<Box<dyn std::any::Any + Send>>) {
        let t0 = Instant::now();
        let mut b = self.state.board.lock().unwrap_or_else(PoisonError::into_inner);
        while b.done < self.submitted {
            b = self
                .state
                .cv
                .wait(b)
                .unwrap_or_else(PoisonError::into_inner);
        }
        let exposed = t0.elapsed();
        (exposed, b.panic.take())
    }

    /// Block until every deferred task completed; re-throw the first
    /// task panic, otherwise report the hidden/exposed timing split.
    pub fn drain(mut self) -> OverlapStats {
        let (exposed, panic) = self.wait_all();
        self.drained = true;
        if let Some(p) = panic {
            resume_unwind(p);
        }
        let b = self.state.board.lock().unwrap_or_else(PoisonError::into_inner);
        let busy = b.busy;
        drop(b);
        OverlapStats { hidden: busy.saturating_sub(exposed), exposed }
    }
}

impl Drop for OverlapScope<'_> {
    fn drop(&mut self) {
        if self.drained {
            return;
        }
        // Unwind path: the deferred closures borrow the caller's frames,
        // so we MUST outwait the worker before those frames die. Panics
        // recorded on the board are swallowed here — either the thread
        // is already panicking, or the caller chose not to drain.
        let _ = self.wait_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_mode_parses_and_defaults() {
        assert_eq!(OverlapMode::parse("off"), Some(OverlapMode::Off));
        assert_eq!(OverlapMode::parse("dw"), Some(OverlapMode::Dw));
        assert_eq!(OverlapMode::parse("dw+comm"), Some(OverlapMode::DwComm));
        assert_eq!(OverlapMode::parse("dwcomm"), None);
        assert_eq!(OverlapMode::parse(""), None);
        assert_eq!(OverlapMode::Off.name(), "off");
        assert_eq!(OverlapMode::Dw.name(), "dw");
        assert_eq!(OverlapMode::DwComm.name(), "dw+comm");
        assert!(!OverlapMode::Off.dw());
        assert!(OverlapMode::Dw.dw() && !OverlapMode::Dw.comm());
        assert!(OverlapMode::DwComm.dw() && OverlapMode::DwComm.comm());
    }

    #[test]
    fn set_overlap_overrides_and_restores() {
        set_overlap(Some(OverlapMode::Off));
        assert_eq!(overlap_mode(), OverlapMode::Off);
        set_overlap(Some(OverlapMode::Dw));
        assert_eq!(overlap_mode(), OverlapMode::Dw);
        set_overlap(None);
        // back to env/default — either way a valid mode
        let m = overlap_mode();
        assert!(!m.name().is_empty());
    }

    #[test]
    fn scope_runs_tasks_in_fifo_order() {
        let mut order: Vec<usize> = Vec::new();
        {
            let mut scope = OverlapScope::new();
            let cell = Mutex::new(&mut order);
            for i in 0..16 {
                scope.defer(|_ws| {
                    cell.lock().unwrap().push(i);
                });
            }
            scope.drain();
        }
        assert_eq!(order, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_scopes_only_wait_on_their_own_tasks() {
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let total = std::sync::atomic::AtomicUsize::new(0);
                    let mut scope = OverlapScope::new();
                    for _ in 0..8 {
                        scope.defer(|_ws| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                    scope.drain();
                    assert_eq!(total.load(Ordering::Relaxed), 8);
                });
            }
        });
    }

    #[test]
    fn task_panic_rethrows_at_drain_and_worker_survives() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut scope = OverlapScope::new();
            scope.defer(|_ws| panic!("deferred boom"));
            scope.drain();
        }));
        assert!(caught.is_err());
        // the worker thread must still be serving tasks afterwards
        let mut ok = false;
        {
            let mut scope = OverlapScope::new();
            let flag = Mutex::new(&mut ok);
            scope.defer(|_ws| {
                **flag.lock().unwrap() = true;
            });
            scope.drain();
        }
        assert!(ok);
    }

    #[test]
    fn drop_without_drain_still_waits() {
        let mut hits = 0usize;
        {
            // declared before the scope so it outlives the drop guard's
            // wait (drop order is reverse declaration order)
            let cell = Mutex::new(&mut hits);
            let mut scope = OverlapScope::new();
            scope.defer(|_ws| {
                std::thread::sleep(Duration::from_millis(5));
                **cell.lock().unwrap() += 1;
            });
            // dropped un-drained: the guard must block until the task ran
        }
        assert_eq!(hits, 1);
    }
}
