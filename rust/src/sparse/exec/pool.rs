//! Resident worker-pool runtime + weighted work partitioning.
//!
//! `run_tasks` / `run_tasks_scratch` are the execution primitives shared
//! by the sparse GEMM plans, the parallel dense paths, the fused
//! attention executors and the optimizer sweep. Since PR 5 they dispatch
//! to a process-wide pool of **long-lived resident workers** instead of
//! spawning fresh OS threads per call:
//!
//! - Workers park on a [`Doorbell`] (Condvar + atomic-epoch mirror).
//!   Dispatch installs a stack-allocated job descriptor in the doorbell
//!   slot, bumps the epoch, wakes parked workers, and then the **caller
//!   participates as worker 0**, pulling task indices from the job's
//!   shared atomic cursor alongside the residents. Because the caller
//!   always drains the cursor itself, a dispatch can never deadlock —
//!   resident help is an accelerator, not a dependency.
//! - Completion is a packed-u64 latch (low 32 bits: unfinished tasks,
//!   high 32 bits: active visitors). Workers register as *visitors*
//!   under the doorbell lock before touching a job and deregister after
//!   their last access, so the caller's stack-owned job (and the
//!   borrowed closure inside it) provably outlives every worker access —
//!   no per-dispatch allocation, no `Arc`, nothing for the steady state
//!   to allocate (the `pool_dispatch` bench asserts this).
//! - Worker panics are caught per task, recorded in the job, and
//!   re-thrown on the calling thread after the latch settles — same
//!   surface behavior as `std::thread::scope`, without the deadlock a
//!   lost decrement would cause.
//! - Each resident worker owns a pinned [`Workspace`]; scratch-carrying
//!   executors ([`run_tasks_scratch`]) draw per-worker scratch from the
//!   worker itself instead of caller-pre-split slices, keeping the
//!   metered zero-alloc steady state ([`worker_alloc_events`]) across
//!   dispatches.
//! - [`step_scope`] marks a whole-step region (`Model::train_step`,
//!   `InferenceSession::run`): between the step's job batches workers
//!   spin briefly on the epoch mirror before parking, so a chain of
//!   layer dispatches flows through the pool latch-to-latch without
//!   paying a park/unpark round trip per op.
//!
//! The pre-PR-5 `std::thread::scope` spawn-per-call path survives as the
//! `PIXELFLY_POOL=scoped` fallback and as the oracle the parity tests
//! and the `pool_dispatch` bench compare the resident runtime against.
//!
//! Two properties here are load-bearing for the overlap scheduler
//! ([`super::overlap`]), which dispatches pool jobs from its own thread
//! *concurrently* with the training thread's dX chain: every job's
//! completion is guaranteed by its own caller's participation (resident
//! help is best-effort, so concurrent dispatchers can never deadlock
//! each other), and [`STEP_DEPTH`] is process-wide, so deferred dW
//! sweeps dispatched off-thread inside a [`step_scope`] still get the
//! spin-before-park fast path. dW bit-identity across worker counts
//! (each stored slot is swept by exactly one task in a fixed order) is
//! what lets the overlap worker re-run the same scatter schedule the
//! serial backward would have.

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use super::workspace::Workspace;

/// Shared raw-pointer wrapper for the executors' disjoint-write pattern:
/// worker tasks write through one base pointer into regions their
/// schedule proves disjoint. This wrapper only asserts that *sharing*
/// the pointer across workers is safe (`Sync`) — every executor must
/// still carry its own safety comment arguing the disjointness of the
/// writes it performs through it. Living next to [`run_tasks`] keeps
/// that one line of `unsafe impl` in a single audited place instead of
/// re-stated per executor.
pub struct SyncPtr<T>(pub *mut T);
unsafe impl<T> Sync for SyncPtr<T> {}

// ---------------------------------------------------------------------
// Doorbell: the one Condvar-wakeup primitive
// ---------------------------------------------------------------------

/// A `Mutex<T>` paired with a `Condvar`: the engine's one wakeup
/// primitive. The resident pool parks its workers on one; the data
/// prefetcher ([`crate::data::prefetch`]) builds its bounded queue on
/// one — nobody sleep-polls.
pub struct Doorbell<T> {
    state: Mutex<T>,
    bell: Condvar,
}

impl<T> Doorbell<T> {
    pub const fn new(state: T) -> Self {
        Doorbell { state: Mutex::new(state), bell: Condvar::new() }
    }

    /// Lock, mutate, ring: run `f` under the lock and wake every waiter
    /// afterwards (they re-check their predicates under the lock).
    pub fn update<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        let r = f(&mut self.state.lock().unwrap());
        self.bell.notify_all();
        r
    }

    /// Park until `f` yields a value. `f` runs under the lock and may
    /// mutate the state; the bell is rung once on exit so peers observe
    /// the mutation (e.g. a consumer popping an item wakes the producer
    /// blocked on a full queue).
    pub fn wait_until<R>(&self, mut f: impl FnMut(&mut T) -> Option<R>) -> R {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(r) = f(&mut g) {
                drop(g);
                self.bell.notify_all();
                return r;
            }
            g = self.bell.wait(g).unwrap();
        }
    }

    /// [`Doorbell::wait_until`] with a deadline: parks until `f` yields a
    /// value or `timeout` elapses, whichever comes first. `None` on
    /// timeout — the caller re-checks its world (liveness deadlines,
    /// shutdown flags) and decides whether to wait again. This is what
    /// keeps every barrier built on a doorbell hang-free: a peer that
    /// dies without ringing can only cost one timeout tick, not forever.
    pub fn wait_timeout_until<R>(&self, timeout: std::time::Duration,
                                 mut f: impl FnMut(&mut T) -> Option<R>)
                                 -> Option<R> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(r) = f(&mut g) {
                drop(g);
                self.bell.notify_all();
                return Some(r);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            g = self.bell.wait_timeout(g, deadline - now).unwrap().0;
        }
    }
}

// ---------------------------------------------------------------------
// Pool mode: resident runtime vs the scoped spawn-per-call fallback
// ---------------------------------------------------------------------

/// Which execution substrate [`run_tasks`] dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMode {
    /// Long-lived parked workers + caller participation (the default).
    Resident,
    /// `std::thread::scope` spawn-per-call — the pre-PR-5 path, kept as
    /// the fallback and the parity oracle.
    Scoped,
}

impl PoolMode {
    pub fn parse(s: &str) -> Option<PoolMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "resident" => Some(PoolMode::Resident),
            "scoped" => Some(PoolMode::Scoped),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PoolMode::Resident => "resident",
            PoolMode::Scoped => "scoped",
        }
    }
}

/// 0 = no override; 1 = resident; 2 = scoped.
static MODE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// `PIXELFLY_POOL` resolved once (the hot path must not re-read env).
static MODE_ENV: OnceLock<PoolMode> = OnceLock::new();

/// Override the pool mode for this process (the CLI's `--pool`); `None`
/// returns to `PIXELFLY_POOL` / default resolution.
pub fn set_pool_mode(mode: Option<PoolMode>) {
    let v = match mode {
        None => 0,
        Some(PoolMode::Resident) => 1,
        Some(PoolMode::Scoped) => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Effective pool mode: `set_pool_mode` override, else `PIXELFLY_POOL`
/// (`resident` | `scoped`), else resident.
pub fn pool_mode() -> PoolMode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => PoolMode::Resident,
        2 => PoolMode::Scoped,
        _ => *MODE_ENV.get_or_init(|| {
            std::env::var("PIXELFLY_POOL")
                .ok()
                .and_then(|s| PoolMode::parse(&s))
                .unwrap_or(PoolMode::Resident)
        }),
    }
}

// ---------------------------------------------------------------------
// Resident pool internals
// ---------------------------------------------------------------------

/// Visitor unit in the packed completion latch (tasks live in the low
/// 32 bits, visitors in the high 32 — one atomic, so "all tasks done AND
/// all workers finished touching the job" is a single load == 0).
const VISITOR: u64 = 1 << 32;

/// Hard cap on resident helper threads (requests beyond it are served by
/// fewer helpers plus the participating caller — still correct).
const MAX_RESIDENT: usize = 256;

/// Epoch-mirror spins a worker performs between a step's job batches
/// before parking (`step_scope` active). ~tens of microseconds: enough
/// to bridge the serial sections between a layer chain's dispatches.
const WORKER_SPINS: u32 = 20_000;

/// Spins the dispatching caller performs on the completion latch before
/// parking (helper stragglers usually finish within this window).
const CALLER_SPINS: u32 = 10_000;

/// What the parked workers watch: the latest dispatched job. A single
/// slot, not a queue — every job's completion is guaranteed by its own
/// caller's participation, so resident help is best-effort by design
/// and concurrent dispatchers can never deadlock each other.
struct PoolState {
    epoch: u64,
    job: *const Job,
    parked: usize,
    spawned: usize,
}
// Safety: the raw job pointer is only dereferenced by workers that
// registered as visitors under the doorbell lock while the slot was
// non-null; the dispatch protocol (clear slot, then wait for the latch)
// guarantees the pointee outlives every such access.
unsafe impl Send for PoolState {}

static POOL: Doorbell<PoolState> = Doorbell::new(PoolState {
    epoch: 0,
    job: std::ptr::null(),
    parked: 0,
    spawned: 0,
});

/// Lock-free mirror of `PoolState::epoch` for the spin phase (workers
/// watching for the next batch of a step without taking the lock).
static EPOCH: AtomicU64 = AtomicU64::new(0);

/// Nesting depth of active [`step_scope`]s (process-wide).
static STEP_DEPTH: AtomicUsize = AtomicUsize::new(0);

/// Allocation events across every resident worker's pinned workspace —
/// the worker-side half of the zero-alloc metering story (the caller's
/// own `Workspace` counts the other half).
static WORKER_ALLOCS: AtomicUsize = AtomicUsize::new(0);

/// Total `Workspace::take` calls by resident workers that touched the
/// global allocator. Flat after warmup — the `pool_dispatch` bench
/// asserts it.
pub fn worker_alloc_events() -> usize {
    WORKER_ALLOCS.load(Ordering::Relaxed)
}

/// How a worker invokes the type-erased caller closure.
#[derive(Clone, Copy)]
enum Kind {
    /// `f(task)`
    Plain(unsafe fn(*const (), usize)),
    /// `f(scratch, task)` with `per` f32s of private scratch per worker.
    Scratch { call: unsafe fn(*const (), &mut [f32], usize), per: usize },
}

/// One dispatched job batch. Lives on the **dispatching caller's stack**
/// for the duration of the dispatch — the visitor protocol (see
/// [`PoolState`]) is what makes lending it to detached worker threads
/// sound without an allocation.
struct Job {
    ctx: *const (),
    kind: Kind,
    n_tasks: usize,
    cursor: AtomicUsize,
    /// packed latch: `n_tasks` in the low 32 bits + [`VISITOR`] per
    /// registered worker; 0 ⇔ every task executed and every worker done
    /// touching this job
    latch: AtomicU64,
    /// caps resident helpers at `threads − 1` (the caller is worker 0)
    max_helpers: usize,
    /// set on the first caught panic: remaining tasks are skipped (but
    /// still drain the latch) so the failure surfaces fast
    poisoned: AtomicBool,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// the dispatching thread, parked while the latch drains. An OWNED
    /// handle (`Thread` is internally refcounted), so the zeroing
    /// drainer can wake the caller without touching job memory — see
    /// [`Job::drain`].
    waiter: std::thread::Thread,
}

// Safety: `ctx` points at a closure the generic front-ends constrain to
// `Sync`, owned by the dispatching thread's stack and kept alive until
// the completion latch settles; all other fields are Sync primitives.
unsafe impl Sync for Job {}

unsafe fn call_plain<F: Fn(usize) + Sync>(ctx: *const (), t: usize) {
    (*(ctx as *const F))(t)
}

unsafe fn call_scratch<F: Fn(&mut [f32], usize) + Sync>(ctx: *const (), s: &mut [f32],
                                                        t: usize) {
    (*(ctx as *const F))(s, t)
}

impl Job {
    /// Claim-and-execute loop shared by the caller and the residents.
    /// Every claimed task drains exactly one latch unit, panic or not —
    /// the invariant that makes completion detection exact.
    fn work(&self, scratch: &mut [f32]) {
        loop {
            let t = self.cursor.fetch_add(1, Ordering::Relaxed);
            if t >= self.n_tasks {
                break;
            }
            if !self.poisoned.load(Ordering::Relaxed) {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    // Safety: ctx is the Sync closure the front-end
                    // erased; the visitor/latch protocol keeps it alive.
                    unsafe {
                        match self.kind {
                            Kind::Plain(call) => call(self.ctx, t),
                            Kind::Scratch { call, .. } => call(self.ctx, scratch, t),
                        }
                    }
                }));
                if let Err(p) = r {
                    self.poisoned.store(true, Ordering::Relaxed);
                    let mut slot = self.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                }
            }
            self.drain(1);
        }
    }

    /// Remove `unit` from the latch; whoever zeroes it wakes the parked
    /// dispatcher. The waiter handle is cloned BEFORE the decrement:
    /// the instant the zeroing `fetch_sub` lands, the caller's
    /// completion check may pass and free the stack-owned job, so the
    /// wake must go through an owned handle — this `fetch_sub` is the
    /// drainer's last access to job memory. `unpark`'s token semantics
    /// (an unpark before the park makes the next park return) close the
    /// check-then-park window on the caller side.
    fn drain(&self, unit: u64) {
        let waiter = self.waiter.clone();
        if self.latch.fetch_sub(unit, Ordering::AcqRel) == unit {
            waiter.unpark();
        }
    }
}

/// Resident worker body: park on the doorbell, visit jobs, repeat.
/// Owns the pinned per-worker [`Workspace`] scratch jobs draw from.
fn worker_main() {
    let mut ws = Workspace::new();
    let mut last_epoch = 0u64;
    loop {
        // Whole-step spin phase: between a step's job batches the next
        // dispatch is microseconds away, so watching the lock-free epoch
        // mirror beats a park/unpark round trip. Bounded, and yields
        // periodically so a spinning helper cannot starve the caller's
        // serial sections.
        if STEP_DEPTH.load(Ordering::Relaxed) > 0 {
            let mut spins = 0u32;
            while EPOCH.load(Ordering::Acquire) == last_epoch && spins < WORKER_SPINS {
                if spins % 1024 == 1023 {
                    std::thread::yield_now();
                }
                std::hint::spin_loop();
                spins += 1;
            }
        }
        let job: &Job = {
            let mut st = POOL.state.lock().unwrap();
            loop {
                if st.epoch != last_epoch {
                    last_epoch = st.epoch;
                    if !st.job.is_null() {
                        // Safety: slot non-null ⇒ the dispatcher has not
                        // cleared it, so the job is alive; registering
                        // as a visitor BEFORE releasing the lock keeps
                        // it alive until we drain our visitor unit.
                        let j = unsafe { &*st.job };
                        let visitors = (j.latch.load(Ordering::Relaxed) >> 32) as usize;
                        if visitors < j.max_helpers {
                            j.latch.fetch_add(VISITOR, Ordering::AcqRel);
                            break j;
                        }
                    }
                    // cleared slot or fully-staffed job: treat as seen
                    continue;
                }
                st.parked += 1;
                st = POOL.bell.wait(st).unwrap();
                st.parked -= 1;
            }
        };
        let before = ws.alloc_events();
        match job.kind {
            Kind::Plain(_) => job.work(&mut []),
            Kind::Scratch { per, .. } => {
                let mut s = ws.take(per);
                job.work(&mut s);
                ws.give(s);
            }
        }
        WORKER_ALLOCS.fetch_add(ws.alloc_events() - before, Ordering::Relaxed);
        job.drain(VISITOR);
    }
}

/// Install `job` in the doorbell slot, wake/grow the residents, work it
/// as worker 0, then retire the slot and wait out the latch.
fn run_resident_job(job: &Job, caller_scratch: &mut [f32]) {
    // hard assert: an overflow into the visitor bits would let the
    // latch read zero while workers still hold registrations on the
    // stack-owned job — a memory-safety bound, not a debug nicety
    assert!((job.n_tasks as u64) < VISITOR,
            "task count {} overflows the packed completion latch", job.n_tasks);
    {
        let mut st = POOL.state.lock().unwrap();
        st.epoch += 1;
        st.job = job;
        EPOCH.store(st.epoch, Ordering::Release);
        // grow the pool on demand (first dispatch, or a wider request)
        let want = job.max_helpers.min(MAX_RESIDENT);
        while st.spawned < want {
            let id = st.spawned + 1;
            let spawned = std::thread::Builder::new()
                .name(format!("pixelfly-pool-{id}"))
                .spawn(worker_main)
                .is_ok();
            if !spawned {
                break; // degrade gracefully: fewer helpers, still correct
            }
            st.spawned += 1;
        }
        if st.parked > 0 {
            POOL.bell.notify_all();
        }
    }
    // the caller is worker 0: drain the cursor alongside the residents
    job.work(caller_scratch);
    // retire the slot (no NEW visitors past this point — registration
    // happens under the same lock), then wait for stragglers
    {
        let mut st = POOL.state.lock().unwrap();
        if std::ptr::eq(st.job, job) {
            st.job = std::ptr::null();
        }
    }
    // bounded spin (helper stragglers usually finish within it), then
    // park; a stale park token or spurious wake just re-checks the latch
    let mut spins = 0u32;
    while job.latch.load(Ordering::Acquire) != 0 {
        if spins < CALLER_SPINS {
            std::hint::spin_loop();
            spins += 1;
        } else {
            std::thread::park();
        }
    }
    if let Some(p) = job.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
}

fn make_job(ctx: *const (), kind: Kind, n_tasks: usize, workers: usize) -> Job {
    Job {
        ctx,
        kind,
        n_tasks,
        cursor: AtomicUsize::new(0),
        latch: AtomicU64::new(n_tasks as u64),
        max_helpers: workers - 1,
        poisoned: AtomicBool::new(false),
        panic: Mutex::new(None),
        waiter: std::thread::current(),
    }
}

// ---------------------------------------------------------------------
// Whole-step dispatch
// ---------------------------------------------------------------------

/// Mark a whole-step region: `Model::train_step`, `InferenceSession::run`
/// and the `TrainStep` drivers wrap their layer chains in one, so the
/// chain runs as a sequence of job batches separated by pool-internal
/// latches — workers spin on the epoch mirror between batches instead of
/// parking, and the step never pays a per-op park/unpark round trip.
/// Nests; panic-safe (the depth is restored on unwind).
pub fn step_scope<R>(f: impl FnOnce() -> R) -> R {
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            STEP_DEPTH.fetch_sub(1, Ordering::Relaxed);
        }
    }
    STEP_DEPTH.fetch_add(1, Ordering::Relaxed);
    let _g = Guard;
    f()
}

// ---------------------------------------------------------------------
// Front-ends
// ---------------------------------------------------------------------

/// Run `f(0..n_tasks)` across up to `threads` workers with dynamic
/// (pull-based) scheduling, on the mode-resolved substrate (resident
/// pool by default; `PIXELFLY_POOL=scoped` falls back to scoped spawns).
/// Serial when one worker suffices. `f` must be safe to call
/// concurrently for distinct task indices. A panicking task poisons the
/// batch (remaining tasks are skipped) and the panic resurfaces on the
/// calling thread once the batch settles.
pub fn run_tasks<F>(n_tasks: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    run_tasks_in(pool_mode(), n_tasks, threads, f)
}

/// [`run_tasks`] with an explicit substrate — the parity tests and the
/// dispatch bench compare the two paths through this entry point.
pub fn run_tasks_in<F>(mode: PoolMode, n_tasks: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = threads.min(n_tasks).max(1);
    if workers == 1 {
        for t in 0..n_tasks {
            f(t);
        }
        return;
    }
    match mode {
        PoolMode::Resident => {
            let job = make_job(&f as *const F as *const (),
                               Kind::Plain(call_plain::<F>), n_tasks, workers);
            run_resident_job(&job, &mut []);
        }
        PoolMode::Scoped => {
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let t = next.fetch_add(1, Ordering::Relaxed);
                        if t >= n_tasks {
                            break;
                        }
                        f(t);
                    });
                }
            });
        }
    }
}

/// Like [`run_tasks`], but every participating worker runs its tasks
/// with a private scratch slice of `per` f32s — the per-worker-state
/// pattern the fused attention executors rely on for their zero-alloc
/// hot path. Resident workers draw the slice from their own pinned
/// workspace (metered by [`worker_alloc_events`]); the caller draws its
/// slice from `ws`. The scoped fallback checks out `per × workers` from
/// `ws` and splits it, exactly like the pre-resident engine did.
/// Scratch contents are UNSPECIFIED on entry (Workspace contract):
/// `f` must initialize everything it reads.
pub fn run_tasks_scratch<F>(n_tasks: usize, threads: usize, per: usize,
                            ws: &mut Workspace, f: F)
where
    F: Fn(&mut [f32], usize) + Sync,
{
    run_tasks_scratch_in(pool_mode(), n_tasks, threads, per, ws, f)
}

/// [`run_tasks_scratch`] with an explicit substrate (parity tests).
pub fn run_tasks_scratch_in<F>(mode: PoolMode, n_tasks: usize, threads: usize,
                               per: usize, ws: &mut Workspace, f: F)
where
    F: Fn(&mut [f32], usize) + Sync,
{
    if n_tasks == 0 {
        return;
    }
    if per == 0 {
        // degenerate scratch: route through the plain front-end so the
        // scoped split below never builds zero-length chunks
        return run_tasks_in(mode, n_tasks, threads, |t| f(&mut [], t));
    }
    let workers = threads.min(n_tasks).max(1);
    if workers == 1 {
        let mut s = ws.take(per);
        for t in 0..n_tasks {
            f(&mut s, t);
        }
        ws.give(s);
        return;
    }
    match mode {
        PoolMode::Resident => {
            let mut s = ws.take(per);
            let job = make_job(&f as *const F as *const (),
                               Kind::Scratch { call: call_scratch::<F>, per },
                               n_tasks, workers);
            run_resident_job(&job, &mut s);
            ws.give(s);
        }
        PoolMode::Scoped => {
            let mut scratch = ws.take(per * workers);
            let next = AtomicUsize::new(0);
            let next_ref = &next;
            let f_ref = &f;
            std::thread::scope(|scope| {
                for part in scratch.chunks_mut(per).take(workers) {
                    scope.spawn(move || loop {
                        let t = next_ref.fetch_add(1, Ordering::Relaxed);
                        if t >= n_tasks {
                            break;
                        }
                        f_ref(part, t);
                    });
                }
            });
            ws.give(scratch);
        }
    }
}

// ---------------------------------------------------------------------
// Work partitioning helpers (unchanged semantics)
// ---------------------------------------------------------------------

/// Split `0..n` into at most `parts` contiguous, non-empty ranges of
/// near-equal length — the unweighted sibling of [`weighted_ranges`] for
/// item sets whose per-item cost is uniform (e.g. stored-block slots in
/// the dW scatter schedule, where every block costs the same m·b² flops).
/// Avoids materialising a constant weights vector just to chunk evenly.
pub fn even_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split items `0..weights.len()` into at most `parts` contiguous,
/// non-empty ranges of approximately equal total weight (greedy against
/// the even share of the remaining weight). Used to chunk block columns
/// by nnz blocks and attention query rows by visible key blocks.
pub fn weighted_ranges(weights: &[usize], parts: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let total: usize = weights.iter().sum();
    let mut out: Vec<Range<usize>> = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if out.len() + 1 < parts && i + 1 < n {
            let target = (total - assigned) / (parts - out.len());
            if acc >= target.max(1) {
                out.push(start..i + 1);
                start = i + 1;
                assigned += acc;
                acc = 0;
            }
        }
    }
    out.push(start..n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestAtomicU64;

    const BOTH: [PoolMode; 2] = [PoolMode::Resident, PoolMode::Scoped];

    #[test]
    fn run_tasks_covers_every_index_once_on_both_substrates() {
        for mode in BOTH {
            for threads in [1usize, 2, 8] {
                let hits: Vec<AtomicUsize> =
                    (0..37).map(|_| AtomicUsize::new(0)).collect();
                run_tasks_in(mode, hits.len(), threads, |t| {
                    hits[t].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "{mode:?} threads={threads} task {i}");
                }
            }
        }
    }

    #[test]
    fn run_tasks_sums_in_parallel() {
        for mode in BOTH {
            let sum = TestAtomicU64::new(0);
            run_tasks_in(mode, 100, 4, |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2, "{mode:?}");
        }
    }

    #[test]
    fn resident_repeated_dispatches_from_one_caller_stay_exact() {
        // the steady-state shape: one caller, many sequential job batches
        for round in 0..200usize {
            let n = 1 + round % 23;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            run_tasks_in(PoolMode::Resident, n, 4, |t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} task {i}");
            }
        }
    }

    #[test]
    fn resident_dispatch_after_idle_rewakes_parked_workers() {
        let run = |tag: &str| {
            let sum = TestAtomicU64::new(0);
            run_tasks_in(PoolMode::Resident, 64, 4, |t| {
                sum.fetch_add(t as u64 + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 64 * 65 / 2, "{tag}");
        };
        run("warm");
        // long past the spin window (no step scope is active here, so
        // workers park immediately): the next dispatch must ring them up
        std::thread::sleep(std::time::Duration::from_millis(120));
        run("after-idle");
    }

    #[test]
    fn scratch_state_is_private_per_worker_and_covers_every_task() {
        for mode in BOTH {
            for threads in [1usize, 2, 8] {
                let hits: Vec<AtomicUsize> =
                    (0..41).map(|_| AtomicUsize::new(0)).collect();
                let mut ws = Workspace::new();
                run_tasks_scratch_in(mode, hits.len(), threads, 8, &mut ws,
                                     |s, t| {
                    // tag the private scratch, linger, and verify nobody
                    // else wrote over it — a shared buffer fails this
                    let tag = t as f32 + 1.0;
                    s[0] = tag;
                    for _ in 0..500 {
                        std::hint::spin_loop();
                    }
                    assert_eq!(s[0], tag, "scratch shared across workers");
                    hits[t].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "{mode:?} threads={threads} task {i}");
                }
            }
        }
    }

    #[test]
    fn resident_caller_scratch_checkouts_are_steady_state_free() {
        // caller-side metering: after the first dispatch sizes the
        // buffer, repeat dispatches of the same shape must be served
        // from the free list (worker-side metering is asserted by the
        // single-process pool_dispatch bench, where the global counter
        // is not shared with concurrent tests)
        let mut ws = Workspace::new();
        for _ in 0..5 {
            run_tasks_scratch_in(PoolMode::Resident, 16, 4, 32, &mut ws, |s, _t| {
                s[0] = 1.0;
            });
        }
        assert_eq!(ws.alloc_events(), 1, "caller checkout must reuse its buffer");
    }

    #[test]
    fn pool_surfaces_worker_panics_instead_of_deadlocking() {
        for mode in BOTH {
            let r = catch_unwind(|| {
                run_tasks_in(mode, 64, 4, |t| {
                    if t == 13 {
                        panic!("boom-13");
                    }
                });
            });
            let err = r.expect_err("panic must propagate, not deadlock");
            if mode == PoolMode::Resident {
                // the resident runtime preserves the worker's payload;
                // the scoped oracle re-panics through std::thread::scope,
                // whose auto-join substitutes its own generic message
                let msg = err
                    .downcast_ref::<&str>()
                    .copied()
                    .map(str::to_owned)
                    .or_else(|| err.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                assert!(msg.contains("boom-13"), "payload was {msg:?}");
            }
        }
    }

    #[test]
    fn step_scope_nests_and_passes_results_through() {
        let r = step_scope(|| {
            let sum = TestAtomicU64::new(0);
            // two batches inside one step: the whole-step shape
            run_tasks_in(PoolMode::Resident, 32, 4, |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            });
            step_scope(|| {
                run_tasks_in(PoolMode::Resident, 32, 4, |t| {
                    sum.fetch_add(t as u64, Ordering::Relaxed);
                });
            });
            sum.load(Ordering::Relaxed)
        });
        assert_eq!(r, 2 * (31 * 32 / 2));
    }

    #[test]
    fn doorbell_bounded_handoff_never_polls() {
        // producer/consumer ping-pong through a Doorbell-backed slot —
        // the shape the prefetcher builds on
        let bell = std::sync::Arc::new(Doorbell::new((0usize, false)));
        let b2 = std::sync::Arc::clone(&bell);
        let h = std::thread::spawn(move || {
            for i in 1..=50usize {
                b2.wait_until(|(slot, full)| {
                    if *full {
                        None
                    } else {
                        *slot = i;
                        *full = true;
                        Some(())
                    }
                });
            }
        });
        let mut got = Vec::new();
        for _ in 0..50 {
            got.push(bell.wait_until(|(slot, full)| {
                if *full {
                    *full = false;
                    Some(*slot)
                } else {
                    None
                }
            }));
        }
        h.join().unwrap();
        assert_eq!(got, (1..=50).collect::<Vec<_>>());
    }

    #[test]
    fn pool_mode_parses_and_defaults() {
        assert_eq!(PoolMode::parse("resident"), Some(PoolMode::Resident));
        assert_eq!(PoolMode::parse(" SCOPED "), Some(PoolMode::Scoped));
        assert_eq!(PoolMode::parse("eager"), None);
        assert_eq!(PoolMode::Resident.name(), "resident");
    }

    #[test]
    fn weighted_ranges_partition_and_balance() {
        let weights = vec![1usize, 9, 1, 1, 1, 9, 1, 1];
        let ranges = weighted_ranges(&weights, 3);
        assert!(!ranges.is_empty() && ranges.len() <= 3);
        // exact cover, in order, non-empty
        let mut expect = 0usize;
        for r in &ranges {
            assert_eq!(r.start, expect);
            assert!(r.end > r.start);
            expect = r.end;
        }
        assert_eq!(expect, weights.len());
        // no range should carry almost everything when 3 were requested
        let total: usize = weights.iter().sum();
        for r in &ranges {
            let w: usize = weights[r.clone()].iter().sum();
            assert!(w < total, "one range took all the weight");
        }
    }

    #[test]
    fn even_ranges_partition_exactly() {
        assert!(even_ranges(0, 4).is_empty());
        for (n, parts) in [(1usize, 1usize), (1, 5), (7, 3), (8, 4), (10, 10), (23, 4)] {
            let r = even_ranges(n, parts);
            assert!(r.len() <= parts && !r.is_empty(), "n={n} parts={parts}");
            let mut expect = 0usize;
            let (mut min_len, mut max_len) = (usize::MAX, 0usize);
            for range in &r {
                assert_eq!(range.start, expect);
                assert!(range.end > range.start);
                min_len = min_len.min(range.len());
                max_len = max_len.max(range.len());
                expect = range.end;
            }
            assert_eq!(expect, n, "n={n} parts={parts}");
            assert!(max_len - min_len <= 1, "n={n} parts={parts}: uneven split");
        }
    }

    #[test]
    fn weighted_ranges_edge_cases() {
        assert!(weighted_ranges(&[], 4).is_empty());
        assert_eq!(weighted_ranges(&[5], 4), vec![0..1]);
        // more parts than items: one item per range at most
        let r = weighted_ranges(&[1, 1, 1], 10);
        assert_eq!(r.len(), 3);
        // zero weights don't panic
        let r = weighted_ranges(&[0, 0, 0, 0], 2);
        let covered: usize = r.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 4);
    }

    #[test]
    fn wait_timeout_until_times_out_and_delivers() {
        use std::time::Duration;
        let bell = std::sync::Arc::new(Doorbell::new(0usize));
        // nobody rings: must return None, not hang
        let r = bell.wait_timeout_until(Duration::from_millis(20),
                                        |v| (*v > 0).then_some(*v));
        assert_eq!(r, None);
        // a peer rings within the window: must deliver the value
        let b2 = std::sync::Arc::clone(&bell);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            b2.update(|v| *v = 7);
        });
        let r = bell.wait_timeout_until(Duration::from_secs(5),
                                        |v| (*v > 0).then_some(*v));
        assert_eq!(r, Some(7));
        t.join().unwrap();
    }
}
