//! Dependency-free scoped worker pool + weighted work partitioning.
//!
//! `run_tasks` is the execution primitive shared by the sparse GEMM plan
//! and the parallel dense/attention paths: workers are `std::thread::scope`
//! threads pulling task indices from a shared atomic cursor, so an uneven
//! task (a heavy block-column chunk) delays only the worker that drew it.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Shared raw-pointer wrapper for the executors' disjoint-write pattern:
/// worker tasks write through one base pointer into regions their
/// schedule proves disjoint. This wrapper only asserts that *sharing*
/// the pointer across the scoped workers is safe (`Sync`) — every
/// executor must still carry its own safety comment arguing the
/// disjointness of the writes it performs through it. Living next to
/// [`run_tasks`] keeps that one line of `unsafe impl` in a single
/// audited place instead of re-stated per executor.
pub struct SyncPtr<T>(pub *mut T);
unsafe impl<T> Sync for SyncPtr<T> {}

/// Run `f(0..n_tasks)` across up to `threads` scoped workers with dynamic
/// (pull-based) scheduling. Serial when one worker suffices. `f` must be
/// safe to call concurrently for distinct task indices.
pub fn run_tasks<F>(n_tasks: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = threads.min(n_tasks).max(1);
    if workers == 1 {
        for t in 0..n_tasks {
            f(t);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let t = next.fetch_add(1, Ordering::Relaxed);
                if t >= n_tasks {
                    break;
                }
                f(t);
            });
        }
    });
}

/// Like [`run_tasks`], but each worker owns one element of `states` —
/// the per-worker scratch pattern the fused attention executor relies on
/// for its zero-alloc hot path. At most `states.len()` workers run (fewer
/// when tasks are scarce); `f(state, task)` must be safe to call
/// concurrently for distinct states/tasks.
pub fn run_tasks_with<S, F>(n_tasks: usize, states: &mut [S], f: F)
where
    S: Send,
    F: Fn(&mut S, usize) + Sync,
{
    if n_tasks == 0 {
        return;
    }
    assert!(!states.is_empty(), "run_tasks_with needs at least one state");
    let workers = states.len().min(n_tasks);
    if workers == 1 {
        let s = &mut states[0];
        for t in 0..n_tasks {
            f(s, t);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let next_ref = &next;
    let f_ref = &f;
    std::thread::scope(|scope| {
        for st in states.iter_mut().take(workers) {
            scope.spawn(move || loop {
                let t = next_ref.fetch_add(1, Ordering::Relaxed);
                if t >= n_tasks {
                    break;
                }
                f_ref(st, t);
            });
        }
    });
}

/// Split `0..n` into at most `parts` contiguous, non-empty ranges of
/// near-equal length — the unweighted sibling of [`weighted_ranges`] for
/// item sets whose per-item cost is uniform (e.g. stored-block slots in
/// the dW scatter schedule, where every block costs the same m·b² flops).
/// Avoids materialising a constant weights vector just to chunk evenly.
pub fn even_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split items `0..weights.len()` into at most `parts` contiguous,
/// non-empty ranges of approximately equal total weight (greedy against
/// the even share of the remaining weight). Used to chunk block columns
/// by nnz blocks and attention query rows by visible key blocks.
pub fn weighted_ranges(weights: &[usize], parts: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let total: usize = weights.iter().sum();
    let mut out: Vec<Range<usize>> = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if out.len() + 1 < parts && i + 1 < n {
            let target = (total - assigned) / (parts - out.len());
            if acc >= target.max(1) {
                out.push(start..i + 1);
                start = i + 1;
                assigned += acc;
                acc = 0;
            }
        }
    }
    out.push(start..n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_tasks_covers_every_index_once() {
        for threads in [1usize, 2, 8] {
            let hits: Vec<AtomicUsize> =
                (0..37).map(|_| AtomicUsize::new(0)).collect();
            run_tasks(hits.len(), threads, |t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
            }
        }
    }

    #[test]
    fn run_tasks_sums_in_parallel() {
        let sum = AtomicU64::new(0);
        run_tasks(100, 4, |t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn run_tasks_with_gives_each_worker_private_state() {
        for workers in [1usize, 2, 4] {
            let mut states = vec![0usize; workers];
            run_tasks_with(23, &mut states, |s, _t| {
                *s += 1;
            });
            // every task ran exactly once, spread over the worker states
            assert_eq!(states.iter().sum::<usize>(), 23);
        }
    }

    #[test]
    fn weighted_ranges_partition_and_balance() {
        let weights = vec![1usize, 9, 1, 1, 1, 9, 1, 1];
        let ranges = weighted_ranges(&weights, 3);
        assert!(!ranges.is_empty() && ranges.len() <= 3);
        // exact cover, in order, non-empty
        let mut expect = 0usize;
        for r in &ranges {
            assert_eq!(r.start, expect);
            assert!(r.end > r.start);
            expect = r.end;
        }
        assert_eq!(expect, weights.len());
        // no range should carry almost everything when 3 were requested
        let total: usize = weights.iter().sum();
        for r in &ranges {
            let w: usize = weights[r.clone()].iter().sum();
            assert!(w < total, "one range took all the weight");
        }
    }

    #[test]
    fn even_ranges_partition_exactly() {
        assert!(even_ranges(0, 4).is_empty());
        for (n, parts) in [(1usize, 1usize), (1, 5), (7, 3), (8, 4), (10, 10), (23, 4)] {
            let r = even_ranges(n, parts);
            assert!(r.len() <= parts && !r.is_empty(), "n={n} parts={parts}");
            let mut expect = 0usize;
            let (mut min_len, mut max_len) = (usize::MAX, 0usize);
            for range in &r {
                assert_eq!(range.start, expect);
                assert!(range.end > range.start);
                min_len = min_len.min(range.len());
                max_len = max_len.max(range.len());
                expect = range.end;
            }
            assert_eq!(expect, n, "n={n} parts={parts}");
            assert!(max_len - min_len <= 1, "n={n} parts={parts}: uneven split");
        }
    }

    #[test]
    fn weighted_ranges_edge_cases() {
        assert!(weighted_ranges(&[], 4).is_empty());
        assert_eq!(weighted_ranges(&[5], 4), vec![0..1]);
        // more parts than items: one item per range at most
        let r = weighted_ranges(&[1, 1, 1], 10);
        assert_eq!(r.len(), 3);
        // zero weights don't panic
        let r = weighted_ranges(&[0, 0, 0, 0], 2);
        let covered: usize = r.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 4);
    }
}
