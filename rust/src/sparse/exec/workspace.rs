//! Reusable scratch arena for the substrate's hot paths.
//!
//! `Workspace` is a checkout pool of `Vec<f32>` buffers keyed by shape
//! (capacity): [`Workspace::take`] hands out a buffer of exactly `len`
//! elements (contents unspecified — every hot-path consumer fully
//! initializes its scratch, so checkouts cost neither an allocation nor a
//! redundant memset), reusing the best-fitting free buffer when one
//! exists; [`Workspace::give`] returns it to the pool with its capacity
//! intact.
//! After one warmup pass over a steady-state shape set every `take` is
//! served from the free list and the hot path never touches the global
//! allocator. Two counters make that verifiable rather than aspirational:
//!
//! - [`Workspace::alloc_events`] counts the `take` calls that had to touch
//!   the allocator — benches and tests assert it stays flat after warmup;
//! - [`Workspace::peak_bytes`] tracks the high-water scratch footprint —
//!   the fused-attention bench asserts it stays O(threads · block²·d), not
//!   O(seq²).
//!
//! A workspace is single-threaded by design (one per owner; parallel
//! executors split one checked-out buffer into per-worker slices). The
//! thread-local [`with_thread_workspace`] backs the allocating convenience
//! wrappers (`block_sparse_attention`, `FlatLowRank::matmul`, …) so even
//! those are zero-alloc in steady state.

use std::cell::RefCell;

/// Free-list entries kept per workspace; beyond this the smallest buffer
/// is dropped (the large steady-state buffers are the ones worth keeping).
const MAX_FREE: usize = 64;

/// Checkout pool of f32 scratch buffers (see module docs).
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    /// total capacity (elements) currently checked out via `take`
    live_elems: usize,
    /// total capacity (elements) parked on the free list
    free_elems: usize,
    peak_elems: usize,
    allocs: usize,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a buffer of exactly `len` elements, reusing the
    /// best-fitting (smallest sufficient) free buffer when available.
    ///
    /// CONTENTS ARE UNSPECIFIED (stale data from a previous checkout is
    /// normal): callers must initialize everything they read. That is the
    /// deal that makes steady-state checkouts free — no allocation AND no
    /// O(len) re-zeroing on the hot path; fresh growth is zero-filled
    /// only because safe `Vec::resize` requires some value.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len
                && best.map_or(true, |j: usize| b.capacity() < self.free[j].capacity())
            {
                best = Some(i);
            }
        }
        let mut buf = match best {
            Some(i) => {
                let b = self.free.swap_remove(i);
                self.free_elems -= b.capacity();
                b
            }
            None => {
                self.allocs += 1;
                Vec::with_capacity(len)
            }
        };
        // no clear(): a same-shape reuse (the steady state) makes this
        // resize a no-op; shrink truncates, growth zero-fills within the
        // already-sufficient capacity (never reallocates)
        buf.resize(len, 0.0);
        self.live_elems += buf.capacity();
        self.note_peak();
        buf
    }

    /// Return a buffer to the pool. Any `Vec` is accepted (capacity is
    /// what gets reused), including ones not originally from `take`.
    pub fn give(&mut self, buf: Vec<f32>) {
        self.live_elems = self.live_elems.saturating_sub(buf.capacity());
        self.free_elems += buf.capacity();
        self.free.push(buf);
        if self.free.len() > MAX_FREE {
            let i = self
                .free
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .unwrap();
            let dropped = self.free.swap_remove(i);
            self.free_elems -= dropped.capacity();
        }
        self.note_peak();
    }

    fn note_peak(&mut self) {
        self.peak_elems = self.peak_elems.max(self.live_elems + self.free_elems);
    }

    /// Number of `take` calls that had to touch the global allocator.
    pub fn alloc_events(&self) -> usize {
        self.allocs
    }

    /// High-water mark of scratch bytes owned through this workspace.
    pub fn peak_bytes(&self) -> usize {
        self.peak_elems * std::mem::size_of::<f32>()
    }

    /// Bytes currently held (free-listed + checked out).
    pub fn held_bytes(&self) -> usize {
        (self.live_elems + self.free_elems) * std::mem::size_of::<f32>()
    }
}

thread_local! {
    static TLS_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Run `f` with this thread's shared workspace. Backs the allocating
/// convenience wrappers; do NOT call re-entrantly from inside `f` — APIs
/// that need scratch should take `&mut Workspace` parameters instead.
pub fn with_thread_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    TLS_WS.with(|w| f(&mut w.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_capacity_without_reallocating() {
        let mut ws = Workspace::new();
        let mut a = ws.take(16);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        let b = ws.take(16);
        assert_eq!(b.len(), 16);
        // contents are unspecified on reuse (callers initialize what they
        // read); what matters is the checkout came from the free list
        assert_eq!(ws.alloc_events(), 1);
    }

    #[test]
    fn steady_state_is_alloc_free() {
        let mut ws = Workspace::new();
        for _ in 0..5 {
            let a = ws.take(128);
            let b = ws.take(64);
            ws.give(a);
            ws.give(b);
        }
        // first round allocates two buffers; every later round reuses them
        assert_eq!(ws.alloc_events(), 2);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.give(big);
        ws.give(small);
        let got = ws.take(8);
        assert!(got.capacity() < 1000, "should reuse the small buffer");
        assert_eq!(ws.alloc_events(), 2);
    }

    #[test]
    fn peak_bytes_tracks_high_water() {
        let mut ws = Workspace::new();
        let a = ws.take(100);
        let b = ws.take(50);
        let peak = ws.peak_bytes();
        assert!(peak >= 150 * 4);
        ws.give(a);
        ws.give(b);
        // giving back does not raise the peak
        assert_eq!(ws.peak_bytes(), peak);
        let _ = ws.take(100);
        assert_eq!(ws.peak_bytes(), peak);
    }

    #[test]
    fn thread_workspace_is_reusable() {
        let first = with_thread_workspace(|ws| {
            let b = ws.take(32);
            ws.give(b);
            ws.alloc_events()
        });
        let second = with_thread_workspace(|ws| {
            let b = ws.take(32);
            ws.give(b);
            ws.alloc_events()
        });
        assert_eq!(first, second, "second pass must reuse the TLS buffer");
    }
}
