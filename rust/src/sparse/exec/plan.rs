//! `GemmPlan`: the plan half of the plan/executor split for BSR GEMM —
//! forward AND backward.
//!
//! `y = x · W` writes each output block column `j` from exactly the stored
//! blocks `(i, j)` of `W`, so the natural race-free ownership unit is the
//! block row of `Wᵀ`. The plan inverts the BSR row structure once into
//! that column-owned schedule and partitions it into contiguous chunks of
//! near-equal nnz-block weight; the executor hands chunks (crossed with
//! batch-row panels when the chunk count alone cannot feed every worker)
//! to the scoped pool. Each task owns a disjoint rows × column-stripe
//! region of `y`, which is what makes the shared-pointer writes sound.
//!
//! The same structure carries three schedules under one fingerprint:
//!
//! - **forward** (`execute` / `execute_fused`): the column-owned
//!   inversion above. `execute_fused` additionally folds a bias +
//!   activation epilogue into the output sweep while each tile is still
//!   cache-hot (optionally stashing the pre-activation for GELU
//!   backward), so no separate O(m·n) epilogue pass exists.
//! - **dX = dY·Wᵀ** (`execute_dx`): transpose-free. Wᵀ's row structure IS
//!   W's row structure read as columns, so the backward schedule is the
//!   BSR row list itself — output block column `i` of dX is owned by
//!   whoever owns block row `i` of W, and the [`micro::block_panel_t`]
//!   kernel reads each stored block untransposed (its rows become dot
//!   operands). No transposed matrix, no transposed blocks, ever.
//! - **dW = Xᵀ·dY** (`execute_dw`): pattern-frozen scatter. Gradients
//!   exist only for stored blocks, so the schedule partitions stored
//!   slots into contiguous chunks; each task exclusively owns its slots'
//!   `b×b` gradient blocks (race-free by construction) and sweeps the
//!   batch in cache tiles through [`micro::scatter_block`].
//!
//! Plans are cheap (O(nnz) integer work) but reusable: benches and layers
//! that multiply many times against a fixed pattern should build one plan
//! and call the executors per batch.

use std::ops::Range;

use crate::sparse::bsr::BsrMatrix;
use crate::sparse::dense::Matrix;

use super::{micro, par_threshold_flops, pool, quant, Activation};

/// Batch rows per cache tile: at b=32 a tile holds an 8 KB y stripe and an
/// 8 KB x panel next to the 4 KB weight block — comfortably L1-resident.
const TILE_ROWS: usize = 64;

/// Minimum batch rows worth giving a worker of its own.
const MIN_PANEL_ROWS: usize = 8;

/// Target chunks per worker; >1 so the atomic cursor can rebalance.
const CHUNKS_PER_THREAD: usize = 4;

/// One output block column and the stored blocks feeding it.
#[derive(Clone, Debug)]
struct ColTask {
    /// output block column index
    j: u32,
    /// (input block row i, stored slot s) pairs, i ascending — the same
    /// accumulation order as the serial reference path
    srcs: Vec<(u32, u32)>,
}

/// One dX output block column (= one block row of W) and the stored
/// blocks feeding it — the transpose-free backward schedule.
#[derive(Clone, Debug)]
struct RowTask {
    /// block row of W = output block column of dX
    i: u32,
    /// (block column j, stored slot s) pairs, j ascending
    srcs: Vec<(u32, u32)>,
}

/// Fused output epilogue for [`GemmPlan::execute_fused`]: optional bias
/// (length `nbc·b`, added per output column) followed by an activation.
#[derive(Clone, Copy, Debug)]
pub struct Epilogue<'a> {
    pub bias: Option<&'a [f32]>,
    pub act: Activation,
}

/// Parallel tiled execution schedule for one BSR operand (forward and
/// backward — see the module docs for the three schedules).
#[derive(Clone, Debug)]
pub struct GemmPlan {
    nnz_blocks: usize,
    threads: usize,
    /// FNV-1a over (block, nbr, nbc, row_ptr, cols): executing against a
    /// matrix whose *pattern* differs — not just shape/nnz — must fail
    fingerprint: u64,
    block: usize,
    col_tasks: Vec<ColTask>,
    /// ranges over `col_tasks`, balanced by nnz-block weight
    chunks: Vec<Range<usize>>,
    /// output block columns with NO stored blocks: zero on the plain
    /// path, but the fused epilogue must still bias+activate them
    empty_cols: Vec<u32>,
    /// dX schedule: one task per non-empty block row of W
    row_tasks: Vec<RowTask>,
    /// ranges over `row_tasks`, balanced by nnz-block weight
    row_chunks: Vec<Range<usize>>,
    /// block row of each stored slot (slot → `(i, cols[s])` recovers the
    /// block coordinates inside the dW scatter tasks)
    slot_rows: Vec<u32>,
    /// ranges over stored slots; every slot costs the same m·b² flops,
    /// so even chunks are the weighted chunks
    slot_chunks: Vec<Range<usize>>,
}

/// FNV-1a over a stream of u64 words — the one hashing scheme behind
/// every structure fingerprint (GEMM plans here, attention plans in
/// `sparse::attention`), so collision behavior can only ever change in
/// one place.
pub fn fnv1a(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the arrays that determine the schedule. O(nbr + nnz)
/// integer work — negligible next to the O(m·nnz·b²) multiply it guards.
/// Public so `BsrMatrix::matmul_into` can validate its cached plan (and
/// replan, instead of executing a stale schedule, when the structure was
/// mutated after the first multiply).
pub fn structure_fingerprint(w: &BsrMatrix) -> u64 {
    fnv1a(
        [w.block as u64, w.nbr as u64, w.nbc as u64]
            .into_iter()
            .chain(w.row_ptr.iter().map(|&p| p as u64))
            .chain(w.cols.iter().map(|&c| c as u64)),
    )
}

impl GemmPlan {
    /// Build the schedules for `w` targeting `threads` workers.
    pub fn new(w: &BsrMatrix, threads: usize) -> Self {
        let threads = threads.max(1);
        let mut col_tasks: Vec<ColTask> = (0..w.nbc)
            .map(|j| ColTask { j: j as u32, srcs: Vec::new() })
            .collect();
        for i in 0..w.nbr {
            for s in w.row_ptr[i]..w.row_ptr[i + 1] {
                col_tasks[w.cols[s]].srcs.push((i as u32, s as u32));
            }
        }
        let empty_cols: Vec<u32> = col_tasks
            .iter()
            .filter(|t| t.srcs.is_empty())
            .map(|t| t.j)
            .collect();
        col_tasks.retain(|t| !t.srcs.is_empty());
        let weights: Vec<usize> = col_tasks.iter().map(|t| t.srcs.len()).collect();
        let chunks = pool::weighted_ranges(&weights, threads * CHUNKS_PER_THREAD);

        // backward schedules ride on the same pass over the structure:
        // dX tasks are the BSR row lists verbatim (the transpose schedule
        // without a transpose), dW tasks are even chunks of stored slots
        let mut slot_rows = vec![0u32; w.cols.len()];
        let mut row_tasks: Vec<RowTask> = Vec::new();
        for i in 0..w.nbr {
            let (s0, s1) = (w.row_ptr[i], w.row_ptr[i + 1]);
            if s0 == s1 {
                continue;
            }
            let srcs: Vec<(u32, u32)> = (s0..s1)
                .map(|s| {
                    slot_rows[s] = i as u32;
                    (w.cols[s] as u32, s as u32)
                })
                .collect();
            row_tasks.push(RowTask { i: i as u32, srcs });
        }
        let row_weights: Vec<usize> = row_tasks.iter().map(|t| t.srcs.len()).collect();
        let row_chunks = pool::weighted_ranges(&row_weights, threads * CHUNKS_PER_THREAD);
        let slot_chunks = pool::even_ranges(w.cols.len(), threads * CHUNKS_PER_THREAD);

        GemmPlan {
            block: w.block,
            nnz_blocks: w.nnz_blocks(),
            threads,
            fingerprint: structure_fingerprint(w),
            col_tasks,
            chunks,
            empty_cols,
            row_tasks,
            row_chunks,
            slot_rows,
            slot_chunks,
        }
    }

    /// Worker count this plan was built for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fingerprint of the structure this plan was built from (compare
    /// against [`structure_fingerprint`] to detect staleness cheaply).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Secondary split over the batch dimension when the primary chunk
    /// count alone cannot feed every worker.
    fn batch_step(m: usize, threads: usize, n_chunks: usize) -> usize {
        let mut row_step = m;
        if threads > 1 && n_chunks < 2 * threads {
            let max_panels = m.div_ceil(MIN_PANEL_ROWS);
            let want = (2 * threads).div_ceil(n_chunks).min(max_panels.max(1));
            row_step = m.div_ceil(want).max(1);
        }
        row_step
    }

    /// Effective worker count for a problem of `flops` floating ops
    /// (serial below the calibrated dispatch-vs-kernel cutover).
    fn workers_for(&self, flops: f64) -> usize {
        if flops < par_threshold_flops() {
            1
        } else {
            self.threads
        }
    }

    /// Execute `y = x · w` through the schedule. `w` must be the matrix
    /// (or one with identical structure) the plan was built from.
    pub fn execute(&self, w: &BsrMatrix, x: &Matrix, y: &mut Matrix) {
        self.run_forward(w, x, y, None, None);
    }

    /// Execute `y = act(x · w + bias)` with the epilogue fused into the
    /// output sweep: each finished rows × block-column tile is biased and
    /// activated while still cache-hot, so the separate O(m·n) epilogue
    /// pass of an unfused layer never runs. When `pre` is given (same
    /// shape as `y`) the pre-activation `x·w + bias` is stashed there in
    /// the same sweep — mandatory for activations whose derivative needs
    /// it ([`Activation::needs_pre`], i.e. GELU).
    pub fn execute_fused(&self, w: &BsrMatrix, x: &Matrix, y: &mut Matrix,
                         epi: &Epilogue, pre: Option<&mut Matrix>) {
        if let Some(bias) = epi.bias {
            assert_eq!(bias.len(), w.cols_elems());
        }
        if let Some(p) = &pre {
            assert_eq!((p.rows, p.cols), (x.rows, w.cols_elems()));
        }
        assert!(
            pre.is_some() || !epi.act.needs_pre(),
            "{:?} backward needs the pre-activation: pass a `pre` buffer",
            epi.act
        );
        self.run_forward(w, x, y, Some(epi), pre);
    }

    /// Shared forward executor (plain and fused paths).
    fn run_forward(&self, w: &BsrMatrix, x: &Matrix, y: &mut Matrix,
                   epi: Option<&Epilogue>, pre: Option<&mut Matrix>) {
        let b = self.block;
        // debug-only: `BsrMatrix::matmul_into` already fingerprints on the
        // cached path, so hashing here too would double the O(nnz) cost of
        // every release-mode multiply. Explicit `matmul_with_plan` misuse
        // still fails loudly in debug/test builds (and stays memory-safe
        // in release: all block/slot accesses are bounds-checked slices).
        debug_assert_eq!(
            structure_fingerprint(w),
            self.fingerprint,
            "plan built for a different sparsity structure"
        );
        assert_eq!(x.cols, w.rows());
        assert_eq!((y.rows, y.cols), (x.rows, w.cols_elems()));
        y.data.fill(0.0);
        let m = x.rows;
        if m == 0 {
            return;
        }
        let ldy = y.cols;
        let preptr: Option<pool::SyncPtr<f32>> =
            pre.map(|p| pool::SyncPtr(p.data.as_mut_ptr()));

        if self.nnz_blocks > 0 {
            let flops = 2.0 * (m * self.nnz_blocks) as f64 * (b * b) as f64;
            let threads = self.workers_for(flops);
            let n_chunks = self.chunks.len();
            let row_step = Self::batch_step(m, threads, n_chunks);
            let n_panels = m.div_ceil(row_step);
            let n_tasks = n_chunks * n_panels;

            let ybase = pool::SyncPtr(y.data.as_mut_ptr());

            // Reduced-precision selection: a quantized payload (created
            // only by quantize-at-freeze) always wins; otherwise an
            // engaged bf16 weight shadow runs when the global tier is
            // bf16. The activation panel for the bf16 path is packed once
            // here on the caller thread into reused u16 scratch — the
            // workers read the shared view.
            let wq8 = w.qblocks.as_ref();
            let wq16 = if wq8.is_none() && quant::precision() == quant::Precision::Bf16
            {
                w.blocks_bf16.as_deref()
            } else {
                None
            };
            let xq_buf = wq16.map(|_| {
                let mut buf = quant::take_u16(x.data.len());
                quant::pack_bf16_into(&x.data, &mut buf);
                buf
            });
            let xq = xq_buf.as_ref().map(|buf| quant::Bf16Panel {
                data: buf,
                rows: x.rows,
                cols: x.cols,
            });

            pool::run_tasks(n_tasks, threads, |t| {
                let chunk = &self.chunks[t % n_chunks];
                let p = t / n_chunks;
                let rows = p * row_step..((p + 1) * row_step).min(m);
                let y = &ybase;
                let pre = &preptr;
                for ct in &self.col_tasks[chunk.clone()] {
                    let jc = ct.j as usize * b;
                    let mut r0 = rows.start;
                    while r0 < rows.end {
                        let r1 = (r0 + TILE_ROWS).min(rows.end);
                        for &(i, s) in &ct.srcs {
                            let s = s as usize;
                            // Safety: tasks partition the batch-row ×
                            // block-column grid (each column belongs to
                            // exactly one chunk, each row to exactly one
                            // panel), so this task exclusively owns y
                            // rows r0..r1 at columns jc..jc+b; bounds
                            // follow from the shape asserts. `pre` shares
                            // y's shape, so the same ownership covers it.
                            // The reduced-precision twins share the exact
                            // ownership contract of `micro::block_panel`.
                            unsafe {
                                if let Some(q) = wq8 {
                                    quant::block_panel_i8(
                                        b,
                                        x,
                                        i as usize * b,
                                        r0..r1,
                                        &q.data[s * b * b..(s + 1) * b * b],
                                        q.scales[s],
                                        y.0,
                                        ldy,
                                        jc,
                                    );
                                } else if let (Some(w16), Some(xq)) = (wq16, &xq) {
                                    quant::block_panel_bf16(
                                        b,
                                        xq,
                                        i as usize * b,
                                        r0..r1,
                                        &w16[s * b * b..(s + 1) * b * b],
                                        y.0,
                                        ldy,
                                        jc,
                                    );
                                } else {
                                    let blk =
                                        &w.blocks[s * b * b..(s + 1) * b * b];
                                    micro::block_panel(
                                        b,
                                        x,
                                        i as usize * b,
                                        r0..r1,
                                        blk,
                                        y.0,
                                        ldy,
                                        jc,
                                    );
                                }
                            }
                        }
                        if let Some(e) = epi {
                            // the tile is complete (every stored block of
                            // this column accumulated) and still cache-hot
                            unsafe {
                                apply_epilogue_tile(y.0, ldy, jc, b, r0..r1, e,
                                                    pre.as_ref().map(|p| p.0));
                            }
                        }
                        r0 = r1;
                    }
                }
            });

            if let Some(buf) = xq_buf {
                quant::give_u16(buf);
            }
        }

        // Columns with no stored blocks hold zeros; the fused epilogue
        // must still bias + activate them (cheap and rare — serial).
        if let Some(e) = epi {
            for &j in &self.empty_cols {
                let jc = j as usize * b;
                // Safety: serial section, exclusive &mut y / pre.
                unsafe {
                    apply_epilogue_tile(y.data.as_mut_ptr(), ldy, jc, b, 0..m, e,
                                        preptr.as_ref().map(|p| p.0));
                }
            }
        }
    }

    /// Execute `dx = dy · wᵀ` through the transpose-free backward
    /// schedule: output block column `i` of `dx` is fed by exactly the
    /// stored blocks of W's block row `i`, so the BSR row lists ARE the
    /// schedule and [`micro::block_panel_t`] reads each block
    /// untransposed. No `Wᵀ` is ever materialised.
    pub fn execute_dx(&self, w: &BsrMatrix, dy: &Matrix, dx: &mut Matrix) {
        let b = self.block;
        debug_assert_eq!(
            structure_fingerprint(w),
            self.fingerprint,
            "plan built for a different sparsity structure"
        );
        assert_eq!(dy.cols, w.cols_elems());
        assert_eq!((dx.rows, dx.cols), (dy.rows, w.rows()));
        dx.data.fill(0.0);
        let m = dy.rows;
        if m == 0 || self.nnz_blocks == 0 {
            return;
        }

        let flops = 2.0 * (m * self.nnz_blocks) as f64 * (b * b) as f64;
        let threads = self.workers_for(flops);
        let n_chunks = self.row_chunks.len();
        let row_step = Self::batch_step(m, threads, n_chunks);
        let n_panels = m.div_ceil(row_step);
        let n_tasks = n_chunks * n_panels;

        let dxbase = pool::SyncPtr(dx.data.as_mut_ptr());
        let lddx = dx.cols;

        // bf16 tier (training only — the quantized payload never feeds
        // backward): run the reduced-storage twin when this matrix's bf16
        // shadow is engaged. dY packs once on the caller thread.
        let wq16 = if quant::precision() == quant::Precision::Bf16 {
            w.blocks_bf16.as_deref()
        } else {
            None
        };
        let dyq_buf = wq16.map(|_| {
            let mut buf = quant::take_u16(dy.data.len());
            quant::pack_bf16_into(&dy.data, &mut buf);
            buf
        });
        let dyq = dyq_buf.as_ref().map(|buf| quant::Bf16Panel {
            data: buf,
            rows: dy.rows,
            cols: dy.cols,
        });

        pool::run_tasks(n_tasks, threads, |t| {
            let chunk = &self.row_chunks[t % n_chunks];
            let p = t / n_chunks;
            let rows = p * row_step..((p + 1) * row_step).min(m);
            let dx = &dxbase;
            for rt in &self.row_tasks[chunk.clone()] {
                let ic_out = rt.i as usize * b;
                let mut r0 = rows.start;
                while r0 < rows.end {
                    let r1 = (r0 + TILE_ROWS).min(rows.end);
                    for &(j, s) in &rt.srcs {
                        let s = s as usize;
                        // Safety: row chunks partition W's block rows and
                        // panels partition the batch, so this task
                        // exclusively owns dx rows r0..r1 at columns
                        // ic_out..ic_out+b; bounds follow from the shape
                        // asserts. The bf16 twin shares the contract.
                        unsafe {
                            if let (Some(w16), Some(dyq)) = (wq16, &dyq) {
                                quant::block_panel_t_bf16(
                                    b,
                                    dyq,
                                    j as usize * b,
                                    r0..r1,
                                    &w16[s * b * b..(s + 1) * b * b],
                                    dx.0,
                                    lddx,
                                    ic_out,
                                );
                            } else {
                                let blk = &w.blocks[s * b * b..(s + 1) * b * b];
                                micro::block_panel_t(
                                    b,
                                    dy,
                                    j as usize * b,
                                    r0..r1,
                                    blk,
                                    dx.0,
                                    lddx,
                                    ic_out,
                                );
                            }
                        }
                    }
                    r0 = r1;
                }
            }
        });

        if let Some(buf) = dyq_buf {
            quant::give_u16(buf);
        }
    }

    /// Execute `dw = xᵀ · dy` scatter-accumulated into exactly the stored
    /// blocks (pattern-frozen gradient: `dw` mirrors `w.blocks`, slot for
    /// slot — fill-in cannot exist by construction). Stored slots are
    /// partitioned into contiguous chunks, so each task exclusively owns
    /// its gradient blocks; the batch is swept in cache tiles inside each
    /// slot.
    pub fn execute_dw(&self, w: &BsrMatrix, x: &Matrix, dy: &Matrix, dw: &mut [f32]) {
        let b = self.block;
        debug_assert_eq!(
            structure_fingerprint(w),
            self.fingerprint,
            "plan built for a different sparsity structure"
        );
        assert_eq!(x.cols, w.rows());
        assert_eq!(dy.cols, w.cols_elems());
        assert_eq!(x.rows, dy.rows);
        assert_eq!(dw.len(), w.blocks.len());
        dw.fill(0.0);
        let m = x.rows;
        if m == 0 || self.nnz_blocks == 0 {
            return;
        }

        let flops = 2.0 * (m * self.nnz_blocks) as f64 * (b * b) as f64;
        let threads = self.workers_for(flops);
        let n_chunks = self.slot_chunks.len();

        let dwbase = pool::SyncPtr(dw.as_mut_ptr());

        // bf16 tier: when this matrix's shadow is engaged, both operand
        // panels run reduced-storage (the gradient block itself stays
        // f32). Packed once on the caller thread into reused scratch.
        let bufs = if quant::precision() == quant::Precision::Bf16
            && w.blocks_bf16.is_some()
        {
            let mut xb = quant::take_u16(x.data.len());
            quant::pack_bf16_into(&x.data, &mut xb);
            let mut db = quant::take_u16(dy.data.len());
            quant::pack_bf16_into(&dy.data, &mut db);
            Some((xb, db))
        } else {
            None
        };
        let panels = bufs.as_ref().map(|(xb, db)| {
            (
                quant::Bf16Panel { data: xb, rows: x.rows, cols: x.cols },
                quant::Bf16Panel { data: db, rows: dy.rows, cols: dy.cols },
            )
        });

        pool::run_tasks(n_chunks, threads, |t| {
            let dwb = &dwbase;
            for s in self.slot_chunks[t].clone() {
                let i = self.slot_rows[s] as usize;
                let j = w.cols[s];
                // Safety: slot chunks partition the stored slots, so this
                // task exclusively owns dw[s*b²..(s+1)*b²]; dw.len() was
                // asserted equal to w.blocks.len() ≥ (s+1)·b².
                let blk = unsafe {
                    std::slice::from_raw_parts_mut(dwb.0.add(s * b * b), b * b)
                };
                let mut r0 = 0usize;
                while r0 < m {
                    let r1 = (r0 + TILE_ROWS).min(m);
                    match &panels {
                        Some((xq, dq)) => quant::scatter_block_bf16(
                            b,
                            xq,
                            i * b,
                            dq,
                            j * b,
                            r0..r1,
                            blk,
                        ),
                        None => {
                            micro::scatter_block(b, x, i * b, dy, j * b, r0..r1, blk)
                        }
                    }
                    r0 = r1;
                }
            }
        });

        if let Some((xb, db)) = bufs {
            quant::give_u16(xb);
            quant::give_u16(db);
        }
    }
}

/// Bias + activation over one finished rows × block-column tile of `y`
/// (optionally stashing the pre-activation into `pre`, which shares y's
/// layout).
///
/// # Safety
/// Caller exclusively owns rows `rows` × columns `jc..jc+b` of `y` (and
/// of `pre` when present); both are valid for `rows.end * ldy` elements
/// with `jc + b <= ldy`; `bias.len() > jc + b - 1` when present.
unsafe fn apply_epilogue_tile(y: *mut f32, ldy: usize, jc: usize, b: usize,
                              rows: Range<usize>, epi: &Epilogue,
                              pre: Option<*mut f32>) {
    for r in rows {
        let yrow = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        match pre {
            Some(p) => {
                let prow = std::slice::from_raw_parts_mut(p.add(r * ldy + jc), b);
                for c in 0..b {
                    let z = yrow[c] + epi.bias.map_or(0.0, |bb| bb[jc + c]);
                    prow[c] = z;
                    yrow[c] = epi.act.apply(z);
                }
            }
            None => {
                for c in 0..b {
                    let z = yrow[c] + epi.bias.map_or(0.0, |bb| bb[jc + c]);
                    yrow[c] = epi.act.apply(z);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::{baselines, flat_butterfly_mask, BlockMask};
    use crate::util::Rng;

    #[test]
    fn plan_executes_like_serial_reference() {
        let mut rng = Rng::new(71);
        let mask = flat_butterfly_mask(8, 8);
        let w = BsrMatrix::random(&mask, 16, 0.5, &mut rng);
        let x = Matrix::randn(19, w.rows(), 1.0, &mut rng);
        let mut want = Matrix::zeros(19, w.cols_elems());
        w.matmul_serial_into(&x, &mut want);
        for threads in [1usize, 3, 8] {
            let plan = GemmPlan::new(&w, threads);
            let mut y = Matrix::zeros(19, w.cols_elems());
            plan.execute(&w, &x, &mut y);
            assert!(
                y.max_abs_diff(&want) < 1e-4,
                "threads={threads}: {}",
                y.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn empty_and_ragged_structures() {
        let mut rng = Rng::new(72);
        // all-zero mask: executes to zeros
        let empty = BsrMatrix::random(&BlockMask::zeros(4, 4), 8, 1.0, &mut rng);
        let x = Matrix::randn(5, empty.rows(), 1.0, &mut rng);
        let plan = GemmPlan::new(&empty, 4);
        let mut y = Matrix::randn(5, empty.cols_elems(), 1.0, &mut rng);
        plan.execute(&empty, &x, &mut y);
        assert!(y.data.iter().all(|v| *v == 0.0));
        // ragged random rectangular mask with empty columns
        let mask = baselines::random_mask(3, 9, 0.2, &mut rng);
        let w = BsrMatrix::random(&mask, 4, 1.0, &mut rng);
        let x = Matrix::randn(2, w.rows(), 1.0, &mut rng);
        let plan = GemmPlan::new(&w, 8);
        let mut y = Matrix::zeros(2, w.cols_elems());
        plan.execute(&w, &x, &mut y);
        let mut want = Matrix::zeros(2, w.cols_elems());
        w.matmul_serial_into(&x, &mut want);
        assert!(y.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn execute_dx_matches_dense_transpose_math() {
        let mut rng = Rng::new(81);
        let mask = baselines::random_mask(5, 7, 0.4, &mut rng);
        let w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let dy = Matrix::randn(23, w.cols_elems(), 1.0, &mut rng);
        // dense oracle: dX = dY · Wᵀ (transpose materialised ONLY here, in
        // the test — the engine path never builds one)
        let want = crate::sparse::dense::matmul_blocked(&dy, &w.to_dense().transpose());
        for threads in [1usize, 3, 8] {
            let plan = GemmPlan::new(&w, threads);
            let mut dx = Matrix::zeros(23, w.rows());
            plan.execute_dx(&w, &dy, &mut dx);
            assert!(
                dx.max_abs_diff(&want) < 1e-3,
                "threads={threads}: {}",
                dx.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn execute_dw_matches_dense_projection_and_has_no_fill_in() {
        let mut rng = Rng::new(82);
        let mask = baselines::random_mask(6, 4, 0.5, &mut rng);
        let w = BsrMatrix::random(&mask, 8, 0.5, &mut rng);
        let x = Matrix::randn(19, w.rows(), 1.0, &mut rng);
        let dy = Matrix::randn(19, w.cols_elems(), 1.0, &mut rng);
        // dense oracle: dW = Xᵀ·dY, then read back only the stored blocks
        let dwd = crate::sparse::dense::matmul_blocked(&x.transpose(), &dy);
        for threads in [1usize, 4] {
            let plan = GemmPlan::new(&w, threads);
            let mut dw = vec![f32::NAN; w.blocks.len()];
            plan.execute_dw(&w, &x, &dy, &mut dw);
            let b = w.block;
            for i in 0..w.nbr {
                for s in w.row_ptr[i]..w.row_ptr[i + 1] {
                    let j = w.cols[s];
                    for r in 0..b {
                        for c in 0..b {
                            let got = dw[s * b * b + r * b + c];
                            let want = dwd.get(i * b + r, j * b + c);
                            assert!(
                                (got - want).abs() < 1e-3,
                                "threads={threads} slot {s} ({r},{c}): {got} vs {want}"
                            );
                        }
                    }
                }
            }
            // support IS the stored pattern: the gradient buffer mirrors
            // w.blocks slot-for-slot, so fill-in has nowhere to live
            assert_eq!(dw.len(), w.nnz_blocks() * b * b);
        }
    }

    #[test]
    fn execute_fused_matches_plain_plus_manual_epilogue() {
        use crate::sparse::exec::Activation;
        let mut rng = Rng::new(83);
        // a mask with an empty output column exercises the epilogue-only
        // postpass
        let mut mask = baselines::random_mask(4, 5, 0.5, &mut rng);
        for i in 0..4 {
            mask.set(i, 2, false);
        }
        let w = BsrMatrix::random(&mask, 16, 0.5, &mut rng);
        let x = Matrix::randn(9, w.rows(), 1.0, &mut rng);
        let bias = rng.normal_vec(w.cols_elems(), 1.0);
        for act in [Activation::Identity, Activation::Relu, Activation::Gelu] {
            for threads in [1usize, 4] {
                let plan = GemmPlan::new(&w, threads);
                // reference: plain execute, then bias + act by hand
                let mut z = Matrix::zeros(9, w.cols_elems());
                plan.execute(&w, &x, &mut z);
                let mut want = z.clone();
                for r in 0..9 {
                    for c in 0..w.cols_elems() {
                        let zv = z.get(r, c) + bias[c];
                        want.set(r, c, act.apply(zv));
                    }
                }
                let mut y = Matrix::zeros(9, w.cols_elems());
                let mut pre = Matrix::zeros(9, w.cols_elems());
                let epi = Epilogue { bias: Some(&bias), act };
                plan.execute_fused(&w, &x, &mut y, &epi, Some(&mut pre));
                assert!(
                    y.max_abs_diff(&want) < 1e-4,
                    "act={act:?} threads={threads}: {}",
                    y.max_abs_diff(&want)
                );
                // the stashed pre-activation is z + bias everywhere,
                // including the empty column
                for r in 0..9 {
                    for c in 0..w.cols_elems() {
                        let zv = z.get(r, c) + bias[c];
                        assert!(
                            (pre.get(r, c) - zv).abs() < 1e-4,
                            "pre mismatch at ({r},{c})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn execute_fused_on_empty_structure_is_pure_epilogue() {
        use crate::sparse::exec::Activation;
        let mut rng = Rng::new(84);
        let empty = BsrMatrix::random(&BlockMask::zeros(3, 3), 8, 1.0, &mut rng);
        let x = Matrix::randn(5, empty.rows(), 1.0, &mut rng);
        let bias: Vec<f32> = (0..empty.cols_elems()).map(|c| c as f32 - 10.0).collect();
        let plan = GemmPlan::new(&empty, 2);
        let mut y = Matrix::randn(5, empty.cols_elems(), 1.0, &mut rng);
        let epi = Epilogue { bias: Some(&bias), act: Activation::Relu };
        plan.execute_fused(&empty, &x, &mut y, &epi, None);
        for r in 0..5 {
            for c in 0..empty.cols_elems() {
                assert_eq!(y.get(r, c), (c as f32 - 10.0).max(0.0), "({r},{c})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "needs the pre-activation")]
    fn fused_gelu_without_pre_buffer_fails_loudly() {
        use crate::sparse::exec::Activation;
        let mut rng = Rng::new(85);
        let w = BsrMatrix::random(&flat_butterfly_mask(4, 2), 8, 1.0, &mut rng);
        let x = Matrix::randn(3, w.rows(), 1.0, &mut rng);
        let mut y = Matrix::zeros(3, w.cols_elems());
        let plan = GemmPlan::new(&w, 1);
        plan.execute_fused(&w, &x, &mut y,
                           &Epilogue { bias: None, act: Activation::Gelu }, None);
    }

    #[test]
    #[should_panic(expected = "different sparsity structure")]
    fn plan_rejects_mismatched_matrix() {
        let mut rng = Rng::new(73);
        let a = BsrMatrix::random(&flat_butterfly_mask(4, 2), 8, 1.0, &mut rng);
        let b = BsrMatrix::random(&flat_butterfly_mask(4, 4), 8, 1.0, &mut rng);
        let plan = GemmPlan::new(&a, 2);
        let x = Matrix::randn(3, b.rows(), 1.0, &mut rng);
        let mut y = Matrix::zeros(3, b.cols_elems());
        plan.execute(&b, &x, &mut y);
    }

    #[test]
    #[should_panic(expected = "different sparsity structure")]
    fn plan_rejects_same_shape_same_nnz_different_pattern() {
        // same 2x2 grid, same block size, same nnz=2 — only the pattern
        // differs; the fingerprint (not just shape/nnz) must catch it
        let mut rng = Rng::new(74);
        let mut diag = BlockMask::zeros(2, 2);
        diag.set(0, 0, true);
        diag.set(1, 1, true);
        let mut anti = BlockMask::zeros(2, 2);
        anti.set(0, 1, true);
        anti.set(1, 0, true);
        let a = BsrMatrix::random(&diag, 4, 1.0, &mut rng);
        let b = BsrMatrix::random(&anti, 4, 1.0, &mut rng);
        let plan = GemmPlan::new(&a, 2);
        let x = Matrix::randn(3, b.rows(), 1.0, &mut rng);
        let mut y = Matrix::zeros(3, b.cols_elems());
        plan.execute(&b, &x, &mut y);
    }
}
