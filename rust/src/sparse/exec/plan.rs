//! `GemmPlan`: the plan half of the plan/executor split for BSR GEMM.
//!
//! `y = x · W` writes each output block column `j` from exactly the stored
//! blocks `(i, j)` of `W`, so the natural race-free ownership unit is the
//! block row of `Wᵀ`. The plan inverts the BSR row structure once into
//! that column-owned schedule and partitions it into contiguous chunks of
//! near-equal nnz-block weight; the executor hands chunks (crossed with
//! batch-row panels when the chunk count alone cannot feed every worker)
//! to the scoped pool. Each task owns a disjoint rows × column-stripe
//! region of `y`, which is what makes the shared-pointer writes sound.
//!
//! Plans are cheap (O(nnz) integer work) but reusable: benches and layers
//! that multiply many times against a fixed pattern should build one plan
//! and call [`GemmPlan::execute`] per batch.

use std::ops::Range;

use crate::sparse::bsr::BsrMatrix;
use crate::sparse::dense::Matrix;

use super::{micro, pool, MIN_PAR_FLOPS};

/// Batch rows per cache tile: at b=32 a tile holds an 8 KB y stripe and an
/// 8 KB x panel next to the 4 KB weight block — comfortably L1-resident.
const TILE_ROWS: usize = 64;

/// Minimum batch rows worth giving a worker of its own.
const MIN_PANEL_ROWS: usize = 8;

/// Target chunks per worker; >1 so the atomic cursor can rebalance.
const CHUNKS_PER_THREAD: usize = 4;

/// One output block column and the stored blocks feeding it.
#[derive(Clone, Debug)]
struct ColTask {
    /// output block column index
    j: u32,
    /// (input block row i, stored slot s) pairs, i ascending — the same
    /// accumulation order as the serial reference path
    srcs: Vec<(u32, u32)>,
}

/// Parallel tiled execution schedule for one BSR operand.
#[derive(Clone, Debug)]
pub struct GemmPlan {
    nnz_blocks: usize,
    threads: usize,
    /// FNV-1a over (block, nbr, nbc, row_ptr, cols): executing against a
    /// matrix whose *pattern* differs — not just shape/nnz — must fail
    fingerprint: u64,
    block: usize,
    col_tasks: Vec<ColTask>,
    /// ranges over `col_tasks`, balanced by nnz-block weight
    chunks: Vec<Range<usize>>,
}

/// FNV-1a over a stream of u64 words — the one hashing scheme behind
/// every structure fingerprint (GEMM plans here, attention plans in
/// `sparse::attention`), so collision behavior can only ever change in
/// one place.
pub fn fnv1a(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the arrays that determine the schedule. O(nbr + nnz)
/// integer work — negligible next to the O(m·nnz·b²) multiply it guards.
/// Public so `BsrMatrix::matmul_into` can validate its cached plan (and
/// replan, instead of executing a stale schedule, when the structure was
/// mutated after the first multiply).
pub fn structure_fingerprint(w: &BsrMatrix) -> u64 {
    fnv1a(
        [w.block as u64, w.nbr as u64, w.nbc as u64]
            .into_iter()
            .chain(w.row_ptr.iter().map(|&p| p as u64))
            .chain(w.cols.iter().map(|&c| c as u64)),
    )
}

impl GemmPlan {
    /// Build the schedule for `w` targeting `threads` workers.
    pub fn new(w: &BsrMatrix, threads: usize) -> Self {
        let threads = threads.max(1);
        let mut col_tasks: Vec<ColTask> = (0..w.nbc)
            .map(|j| ColTask { j: j as u32, srcs: Vec::new() })
            .collect();
        for i in 0..w.nbr {
            for s in w.row_ptr[i]..w.row_ptr[i + 1] {
                col_tasks[w.cols[s]].srcs.push((i as u32, s as u32));
            }
        }
        col_tasks.retain(|t| !t.srcs.is_empty());
        let weights: Vec<usize> = col_tasks.iter().map(|t| t.srcs.len()).collect();
        let chunks = pool::weighted_ranges(&weights, threads * CHUNKS_PER_THREAD);
        GemmPlan {
            block: w.block,
            nnz_blocks: w.nnz_blocks(),
            threads,
            fingerprint: structure_fingerprint(w),
            col_tasks,
            chunks,
        }
    }

    /// Worker count this plan was built for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fingerprint of the structure this plan was built from (compare
    /// against [`structure_fingerprint`] to detect staleness cheaply).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Execute `y = x · w` through the schedule. `w` must be the matrix
    /// (or one with identical structure) the plan was built from.
    pub fn execute(&self, w: &BsrMatrix, x: &Matrix, y: &mut Matrix) {
        let b = self.block;
        // debug-only: `BsrMatrix::matmul_into` already fingerprints on the
        // cached path, so hashing here too would double the O(nnz) cost of
        // every release-mode multiply. Explicit `matmul_with_plan` misuse
        // still fails loudly in debug/test builds (and stays memory-safe
        // in release: all block/slot accesses are bounds-checked slices).
        debug_assert_eq!(
            structure_fingerprint(w),
            self.fingerprint,
            "plan built for a different sparsity structure"
        );
        assert_eq!(x.cols, w.rows());
        assert_eq!((y.rows, y.cols), (x.rows, w.cols_elems()));
        y.data.fill(0.0);
        let m = x.rows;
        if m == 0 || self.nnz_blocks == 0 {
            return;
        }

        let flops = 2.0 * (m * self.nnz_blocks) as f64 * (b * b) as f64;
        let threads = if flops < MIN_PAR_FLOPS { 1 } else { self.threads };

        let n_chunks = self.chunks.len();
        // Secondary split over the batch dimension when column chunks
        // alone cannot feed every worker.
        let mut row_step = m;
        if threads > 1 && n_chunks < 2 * threads {
            let max_panels = m.div_ceil(MIN_PANEL_ROWS);
            let want = (2 * threads).div_ceil(n_chunks).min(max_panels.max(1));
            row_step = m.div_ceil(want).max(1);
        }
        let n_panels = m.div_ceil(row_step);
        let n_tasks = n_chunks * n_panels;

        struct YBase(*mut f32);
        unsafe impl Sync for YBase {}
        let ybase = YBase(y.data.as_mut_ptr());
        let ldy = y.cols;

        pool::run_tasks(n_tasks, threads, |t| {
            let chunk = &self.chunks[t % n_chunks];
            let p = t / n_chunks;
            let rows = p * row_step..((p + 1) * row_step).min(m);
            let y = &ybase;
            for ct in &self.col_tasks[chunk.clone()] {
                let jc = ct.j as usize * b;
                let mut r0 = rows.start;
                while r0 < rows.end {
                    let r1 = (r0 + TILE_ROWS).min(rows.end);
                    for &(i, s) in &ct.srcs {
                        let s = s as usize;
                        let blk = &w.blocks[s * b * b..(s + 1) * b * b];
                        // Safety: tasks partition the batch-row × block-
                        // column grid (each column belongs to exactly one
                        // chunk, each row to exactly one panel), so this
                        // task exclusively owns y rows r0..r1 at columns
                        // jc..jc+b; bounds follow from the shape asserts.
                        unsafe {
                            micro::block_panel(
                                b,
                                x,
                                i as usize * b,
                                r0..r1,
                                blk,
                                y.0,
                                ldy,
                                jc,
                            );
                        }
                    }
                    r0 = r1;
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::{baselines, flat_butterfly_mask, BlockMask};
    use crate::util::Rng;

    #[test]
    fn plan_executes_like_serial_reference() {
        let mut rng = Rng::new(71);
        let mask = flat_butterfly_mask(8, 8);
        let w = BsrMatrix::random(&mask, 16, 0.5, &mut rng);
        let x = Matrix::randn(19, w.rows(), 1.0, &mut rng);
        let mut want = Matrix::zeros(19, w.cols_elems());
        w.matmul_serial_into(&x, &mut want);
        for threads in [1usize, 3, 8] {
            let plan = GemmPlan::new(&w, threads);
            let mut y = Matrix::zeros(19, w.cols_elems());
            plan.execute(&w, &x, &mut y);
            assert!(
                y.max_abs_diff(&want) < 1e-4,
                "threads={threads}: {}",
                y.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn empty_and_ragged_structures() {
        let mut rng = Rng::new(72);
        // all-zero mask: executes to zeros
        let empty = BsrMatrix::random(&BlockMask::zeros(4, 4), 8, 1.0, &mut rng);
        let x = Matrix::randn(5, empty.rows(), 1.0, &mut rng);
        let plan = GemmPlan::new(&empty, 4);
        let mut y = Matrix::randn(5, empty.cols_elems(), 1.0, &mut rng);
        plan.execute(&empty, &x, &mut y);
        assert!(y.data.iter().all(|v| *v == 0.0));
        // ragged random rectangular mask with empty columns
        let mask = baselines::random_mask(3, 9, 0.2, &mut rng);
        let w = BsrMatrix::random(&mask, 4, 1.0, &mut rng);
        let x = Matrix::randn(2, w.rows(), 1.0, &mut rng);
        let plan = GemmPlan::new(&w, 8);
        let mut y = Matrix::zeros(2, w.cols_elems());
        plan.execute(&w, &x, &mut y);
        let mut want = Matrix::zeros(2, w.cols_elems());
        w.matmul_serial_into(&x, &mut want);
        assert!(y.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    #[should_panic(expected = "different sparsity structure")]
    fn plan_rejects_mismatched_matrix() {
        let mut rng = Rng::new(73);
        let a = BsrMatrix::random(&flat_butterfly_mask(4, 2), 8, 1.0, &mut rng);
        let b = BsrMatrix::random(&flat_butterfly_mask(4, 4), 8, 1.0, &mut rng);
        let plan = GemmPlan::new(&a, 2);
        let x = Matrix::randn(3, b.rows(), 1.0, &mut rng);
        let mut y = Matrix::zeros(3, b.cols_elems());
        plan.execute(&b, &x, &mut y);
    }

    #[test]
    #[should_panic(expected = "different sparsity structure")]
    fn plan_rejects_same_shape_same_nnz_different_pattern() {
        // same 2x2 grid, same block size, same nnz=2 — only the pattern
        // differs; the fingerprint (not just shape/nnz) must catch it
        let mut rng = Rng::new(74);
        let mut diag = BlockMask::zeros(2, 2);
        diag.set(0, 0, true);
        diag.set(1, 1, true);
        let mut anti = BlockMask::zeros(2, 2);
        anti.set(0, 1, true);
        anti.set(1, 0, true);
        let a = BsrMatrix::random(&diag, 4, 1.0, &mut rng);
        let b = BsrMatrix::random(&anti, 4, 1.0, &mut rng);
        let plan = GemmPlan::new(&a, 2);
        let x = Matrix::randn(3, b.rows(), 1.0, &mut rng);
        let mut y = Matrix::zeros(3, b.cols_elems());
        plan.execute(&b, &x, &mut y);
    }
}
