//! Register-blocked `b×b` panel micro-kernels (the portable scalar tier).
//!
//! One call accumulates a stored weight block into the output stripe of a
//! batch-row panel: `y[r, jc..jc+b] += x[r, ic..ic+b] · blk` for each row
//! `r` in the panel. The const-generic kernels (b ∈ {16, 32, 48} — the
//! hardware block sizes the cost model targets) let LLVM fully unroll and
//! vectorise the fixed-width inner loops; rows are processed four at a
//! time so one sweep over the weight block feeds four accumulator rows
//! (the register-blocking that pays for the bandwidth-bound shapes).
//!
//! [`block_panel`] is the dispatch point of the kernel tier: when the
//! resolved tier ([`super::simd`]) has an explicit AVX2/NEON kernel for
//! this block width it runs that, otherwise the const-specialised scalar
//! kernels below — so callers (the GEMM plan executor) never care which
//! tier is active.

use super::simd;
use crate::sparse::dense::Matrix;
use std::ops::Range;

/// Accumulate `blk` (row-major `b*b`) into `y` over the given batch rows.
///
/// `y`/`ldy` describe a row-major matrix; `ic`/`jc` are element (not
/// block) column offsets into `x`/`y`.
///
/// # Safety
/// The caller must guarantee exclusive ownership of rows `rows` ×
/// columns `jc..jc+b` of `y`; that `y` is valid for `rows.end * ldy`
/// elements with `jc + b <= ldy`; that `ic + b <= x.cols` and
/// `rows.end <= x.rows`; and that `blk.len() == b * b`.
pub unsafe fn block_panel(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(blk.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if simd::try_block_panel(b, x, ic, rows.clone(), blk, y, ldy, jc) {
        return;
    }
    match b {
        16 => block_panel_const::<16>(x, ic, rows, blk, y, ldy, jc),
        32 => block_panel_const::<32>(x, ic, rows, blk, y, ldy, jc),
        48 => block_panel_const::<48>(x, ic, rows, blk, y, ldy, jc),
        _ => block_panel_generic(b, x, ic, rows, blk, y, ldy, jc),
    }
}

unsafe fn block_panel_const<const B: usize>(
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    let mut r = rows.start;
    while r + 4 <= rows.end {
        let x0: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let x1: &[f32; B] = x.row(r + 1)[ic..ic + B].try_into().unwrap();
        let x2: &[f32; B] = x.row(r + 2)[ic..ic + B].try_into().unwrap();
        let x3: &[f32; B] = x.row(r + 3)[ic..ic + B].try_into().unwrap();
        let y0 = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        let y1 = &mut *(y.add((r + 1) * ldy + jc) as *mut [f32; B]);
        let y2 = &mut *(y.add((r + 2) * ldy + jc) as *mut [f32; B]);
        let y3 = &mut *(y.add((r + 3) * ldy + jc) as *mut [f32; B]);
        rows4::<B>(x0, x1, x2, x3, blk, y0, y1, y2, y3);
        r += 4;
    }
    while r < rows.end {
        let xr: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let yr = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        row1::<B>(xr, blk, yr);
        r += 1;
    }
}

/// Four activation rows share one sweep over the weight block.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn rows4<const B: usize>(
    x0: &[f32; B],
    x1: &[f32; B],
    x2: &[f32; B],
    x3: &[f32; B],
    blk: &[f32],
    y0: &mut [f32; B],
    y1: &mut [f32; B],
    y2: &mut [f32; B],
    y3: &mut [f32; B],
) {
    for (k, wrow) in blk.chunks_exact(B).enumerate() {
        let w: &[f32; B] = wrow.try_into().unwrap();
        let (a0, a1, a2, a3) = (x0[k], x1[k], x2[k], x3[k]);
        for c in 0..B {
            let wc = w[c];
            y0[c] += a0 * wc;
            y1[c] += a1 * wc;
            y2[c] += a2 * wc;
            y3[c] += a3 * wc;
        }
    }
}

#[inline(always)]
fn row1<const B: usize>(xr: &[f32; B], blk: &[f32], yr: &mut [f32; B]) {
    for (k, wrow) in blk.chunks_exact(B).enumerate() {
        let w: &[f32; B] = wrow.try_into().unwrap();
        let a = xr[k];
        for c in 0..B {
            yr[c] += a * w[c];
        }
    }
}

unsafe fn block_panel_generic(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for (k, wrow) in blk.chunks_exact(b).enumerate() {
            let a = xr[k];
            for (yc, wc) in yr.iter_mut().zip(wrow) {
                *yc += a * *wc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference: plain triple loop over one block.
    fn reference(b: usize, x: &Matrix, ic: usize, blk: &[f32], y: &mut Matrix, jc: usize) {
        for r in 0..x.rows {
            for k in 0..b {
                let a = x.get(r, ic + k);
                for c in 0..b {
                    let v = y.get(r, jc + c) + a * blk[k * b + c];
                    y.set(r, jc + c, v);
                }
            }
        }
    }

    fn apply(b: usize, x: &Matrix, ic: usize, blk: &[f32], y: &mut Matrix, jc: usize) {
        let ldy = y.cols;
        let rows = 0..x.rows;
        unsafe { block_panel(b, x, ic, rows, blk, y.data.as_mut_ptr(), ldy, jc) }
    }

    #[test]
    fn kernels_match_reference_all_widths() {
        // 4 and 8 exercise the generic path; 16/32/48 the const kernels;
        // m = 7 exercises the 4-row main loop plus remainder rows
        for b in [4usize, 8, 16, 32, 48] {
            let mut rng = Rng::new(100 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blk = rng.normal_vec(b * b, 0.5);
            let mut y = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut want = y.clone();
            apply(b, &x, b, &blk, &mut y, b); // middle block of x, second stripe of y
            reference(b, &x, b, &blk, &mut want, b);
            assert!(y.max_abs_diff(&want) < 1e-4, "b={b}: {}", y.max_abs_diff(&want));
        }
    }

    #[test]
    fn accumulates_rather_than_overwrites() {
        let b = 16;
        let mut rng = Rng::new(9);
        let x = Matrix::randn(4, b, 1.0, &mut rng);
        let blk = rng.normal_vec(b * b, 1.0);
        let mut y = Matrix::zeros(4, b);
        apply(b, &x, 0, &blk, &mut y, 0);
        let once = y.clone();
        apply(b, &x, 0, &blk, &mut y, 0);
        for (got, want) in y.data.iter().zip(&once.data) {
            assert!((got - 2.0 * want).abs() < 1e-3);
        }
    }
}
