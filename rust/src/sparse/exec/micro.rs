//! Register-blocked `b×b` panel micro-kernels (the portable scalar tier).
//!
//! One call accumulates a stored weight block into the output stripe of a
//! batch-row panel: `y[r, jc..jc+b] += x[r, ic..ic+b] · blk` for each row
//! `r` in the panel. The const-generic kernels (b ∈ {16, 32, 48} — the
//! hardware block sizes the cost model targets) let LLVM fully unroll and
//! vectorise the fixed-width inner loops; rows are processed four at a
//! time so one sweep over the weight block feeds four accumulator rows
//! (the register-blocking that pays for the bandwidth-bound shapes).
//!
//! [`block_panel`] is the dispatch point of the kernel tier: when the
//! resolved tier ([`super::simd`]) has an explicit AVX2/NEON kernel for
//! this block width it runs that, otherwise the const-specialised scalar
//! kernels below — so callers (the GEMM plan executor) never care which
//! tier is active.
//!
//! The backward engine adds two siblings with the same dispatch shape:
//! - [`block_panel_t`] — `y[r, jc..jc+b] += x[r, ic..ic+b] · blkᵀ`, the
//!   `dX = dY·Wᵀ` kernel. The transpose is *algorithmic* (the kernel reads
//!   `blk` by rows as dot operands); no transposed copy of the block ever
//!   exists.
//! - [`scatter_block`] — `blk[k, c] += Σ_r x[r, ic+k] · dy[r, jc+c]`, the
//!   `dW = Xᵀ·dY` rank-`panel` update that scatter-accumulates into ONE
//!   stored block (pattern-frozen gradient: only stored blocks exist to
//!   receive it).

use super::simd;
use crate::sparse::dense::Matrix;
use std::ops::Range;

/// Accumulate `blk` (row-major `b*b`) into `y` over the given batch rows.
///
/// `y`/`ldy` describe a row-major matrix; `ic`/`jc` are element (not
/// block) column offsets into `x`/`y`.
///
/// # Safety
/// The caller must guarantee exclusive ownership of rows `rows` ×
/// columns `jc..jc+b` of `y`; that `y` is valid for `rows.end * ldy`
/// elements with `jc + b <= ldy`; that `ic + b <= x.cols` and
/// `rows.end <= x.rows`; and that `blk.len() == b * b`.
pub unsafe fn block_panel(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(blk.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if simd::try_block_panel(b, x, ic, rows.clone(), blk, y, ldy, jc) {
        return;
    }
    match b {
        16 => block_panel_const::<16>(x, ic, rows, blk, y, ldy, jc),
        32 => block_panel_const::<32>(x, ic, rows, blk, y, ldy, jc),
        48 => block_panel_const::<48>(x, ic, rows, blk, y, ldy, jc),
        _ => block_panel_generic(b, x, ic, rows, blk, y, ldy, jc),
    }
}

unsafe fn block_panel_const<const B: usize>(
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    let mut r = rows.start;
    while r + 4 <= rows.end {
        let x0: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let x1: &[f32; B] = x.row(r + 1)[ic..ic + B].try_into().unwrap();
        let x2: &[f32; B] = x.row(r + 2)[ic..ic + B].try_into().unwrap();
        let x3: &[f32; B] = x.row(r + 3)[ic..ic + B].try_into().unwrap();
        let y0 = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        let y1 = &mut *(y.add((r + 1) * ldy + jc) as *mut [f32; B]);
        let y2 = &mut *(y.add((r + 2) * ldy + jc) as *mut [f32; B]);
        let y3 = &mut *(y.add((r + 3) * ldy + jc) as *mut [f32; B]);
        rows4::<B>(x0, x1, x2, x3, blk, y0, y1, y2, y3);
        r += 4;
    }
    while r < rows.end {
        let xr: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let yr = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        row1::<B>(xr, blk, yr);
        r += 1;
    }
}

/// Four activation rows share one sweep over the weight block.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn rows4<const B: usize>(
    x0: &[f32; B],
    x1: &[f32; B],
    x2: &[f32; B],
    x3: &[f32; B],
    blk: &[f32],
    y0: &mut [f32; B],
    y1: &mut [f32; B],
    y2: &mut [f32; B],
    y3: &mut [f32; B],
) {
    for (k, wrow) in blk.chunks_exact(B).enumerate() {
        let w: &[f32; B] = wrow.try_into().unwrap();
        let (a0, a1, a2, a3) = (x0[k], x1[k], x2[k], x3[k]);
        for c in 0..B {
            let wc = w[c];
            y0[c] += a0 * wc;
            y1[c] += a1 * wc;
            y2[c] += a2 * wc;
            y3[c] += a3 * wc;
        }
    }
}

#[inline(always)]
fn row1<const B: usize>(xr: &[f32; B], blk: &[f32], yr: &mut [f32; B]) {
    for (k, wrow) in blk.chunks_exact(B).enumerate() {
        let w: &[f32; B] = wrow.try_into().unwrap();
        let a = xr[k];
        for c in 0..B {
            yr[c] += a * w[c];
        }
    }
}

unsafe fn block_panel_generic(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for (k, wrow) in blk.chunks_exact(b).enumerate() {
            let a = xr[k];
            for (yc, wc) in yr.iter_mut().zip(wrow) {
                *yc += a * *wc;
            }
        }
    }
}

/// Accumulate `blkᵀ` into `y` over the given batch rows:
/// `y[r, jc+c] += Σ_k x[r, ic+k] · blk[c*b + k]` — the `dX = dY·Wᵀ`
/// kernel, reading the stored (untransposed) block by rows as dot
/// operands so no transposed copy is ever materialised.
///
/// # Safety
/// Same contract as [`block_panel`].
pub unsafe fn block_panel_t(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    debug_assert_eq!(blk.len(), b * b);
    debug_assert!(jc + b <= ldy && ic + b <= x.cols && rows.end <= x.rows);
    if simd::try_block_panel_t(b, x, ic, rows.clone(), blk, y, ldy, jc) {
        return;
    }
    match b {
        16 => block_panel_t_const::<16>(x, ic, rows, blk, y, ldy, jc),
        32 => block_panel_t_const::<32>(x, ic, rows, blk, y, ldy, jc),
        48 => block_panel_t_const::<48>(x, ic, rows, blk, y, ldy, jc),
        _ => block_panel_t_generic(b, x, ic, rows, blk, y, ldy, jc),
    }
}

unsafe fn block_panel_t_const<const B: usize>(
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    let mut r = rows.start;
    while r + 4 <= rows.end {
        let x0: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let x1: &[f32; B] = x.row(r + 1)[ic..ic + B].try_into().unwrap();
        let x2: &[f32; B] = x.row(r + 2)[ic..ic + B].try_into().unwrap();
        let x3: &[f32; B] = x.row(r + 3)[ic..ic + B].try_into().unwrap();
        let y0 = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        let y1 = &mut *(y.add((r + 1) * ldy + jc) as *mut [f32; B]);
        let y2 = &mut *(y.add((r + 2) * ldy + jc) as *mut [f32; B]);
        let y3 = &mut *(y.add((r + 3) * ldy + jc) as *mut [f32; B]);
        // four rows share one sweep over the weight block rows; the inner
        // k-loops are fixed-width dots that LLVM vectorises
        for (c, wrow) in blk.chunks_exact(B).enumerate() {
            let w: &[f32; B] = wrow.try_into().unwrap();
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
            for k in 0..B {
                let wk = w[k];
                a0 += x0[k] * wk;
                a1 += x1[k] * wk;
                a2 += x2[k] * wk;
                a3 += x3[k] * wk;
            }
            y0[c] += a0;
            y1[c] += a1;
            y2[c] += a2;
            y3[c] += a3;
        }
        r += 4;
    }
    while r < rows.end {
        let xr: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let yr = &mut *(y.add(r * ldy + jc) as *mut [f32; B]);
        for (c, wrow) in blk.chunks_exact(B).enumerate() {
            let w: &[f32; B] = wrow.try_into().unwrap();
            let mut a = 0.0f32;
            for k in 0..B {
                a += xr[k] * w[k];
            }
            yr[c] += a;
        }
        r += 1;
    }
}

unsafe fn block_panel_t_generic(
    b: usize,
    x: &Matrix,
    ic: usize,
    rows: Range<usize>,
    blk: &[f32],
    y: *mut f32,
    ldy: usize,
    jc: usize,
) {
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let yr = std::slice::from_raw_parts_mut(y.add(r * ldy + jc), b);
        for (c, wrow) in blk.chunks_exact(b).enumerate() {
            let mut a = 0.0f32;
            for (xv, wv) in xr.iter().zip(wrow) {
                a += *xv * *wv;
            }
            yr[c] += a;
        }
    }
}

/// Scatter-accumulate the `dW = Xᵀ·dY` contribution of a batch-row panel
/// into one stored block: `blk[k*b + c] += Σ_r x[r, ic+k] · dy[r, jc+c]`.
/// The block layout matches BSR storage (row `k` = weight row within the
/// block), so the gradient lands directly where the optimizer sweep reads
/// it — no reshuffle, no fill-in outside the stored pattern.
///
/// Safe: `blk` is a `&mut` slice (exclusivity is the borrow checker's
/// problem, unlike the panel kernels' shared output pointer) and the
/// asserts below bound every access the SIMD tier performs unchecked.
pub fn scatter_block(
    b: usize,
    x: &Matrix,
    ic: usize,
    dy: &Matrix,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) {
    assert_eq!(blk.len(), b * b);
    assert!(ic + b <= x.cols && jc + b <= dy.cols);
    assert!(rows.end <= x.rows && rows.end <= dy.rows);
    // Safety: the asserts above establish the bounds contract.
    if unsafe { simd::try_scatter_block(b, x, ic, dy, jc, rows.clone(), blk) } {
        return;
    }
    match b {
        16 => scatter_block_const::<16>(x, ic, dy, jc, rows, blk),
        32 => scatter_block_const::<32>(x, ic, dy, jc, rows, blk),
        48 => scatter_block_const::<48>(x, ic, dy, jc, rows, blk),
        _ => scatter_block_generic(b, x, ic, dy, jc, rows, blk),
    }
}

fn scatter_block_const<const B: usize>(
    x: &Matrix,
    ic: usize,
    dy: &Matrix,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) {
    let mut r = rows.start;
    // four batch rows share one sweep over the gradient block, so each
    // blk row is loaded/stored once per four rank-1 updates
    while r + 4 <= rows.end {
        let x0: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let x1: &[f32; B] = x.row(r + 1)[ic..ic + B].try_into().unwrap();
        let x2: &[f32; B] = x.row(r + 2)[ic..ic + B].try_into().unwrap();
        let x3: &[f32; B] = x.row(r + 3)[ic..ic + B].try_into().unwrap();
        let d0: &[f32; B] = dy.row(r)[jc..jc + B].try_into().unwrap();
        let d1: &[f32; B] = dy.row(r + 1)[jc..jc + B].try_into().unwrap();
        let d2: &[f32; B] = dy.row(r + 2)[jc..jc + B].try_into().unwrap();
        let d3: &[f32; B] = dy.row(r + 3)[jc..jc + B].try_into().unwrap();
        for (k, wrow) in blk.chunks_exact_mut(B).enumerate() {
            let (a0, a1, a2, a3) = (x0[k], x1[k], x2[k], x3[k]);
            for c in 0..B {
                wrow[c] += a0 * d0[c] + a1 * d1[c] + a2 * d2[c] + a3 * d3[c];
            }
        }
        r += 4;
    }
    while r < rows.end {
        let xr: &[f32; B] = x.row(r)[ic..ic + B].try_into().unwrap();
        let dr: &[f32; B] = dy.row(r)[jc..jc + B].try_into().unwrap();
        for (k, wrow) in blk.chunks_exact_mut(B).enumerate() {
            let a = xr[k];
            for c in 0..B {
                wrow[c] += a * dr[c];
            }
        }
        r += 1;
    }
}

fn scatter_block_generic(
    b: usize,
    x: &Matrix,
    ic: usize,
    dy: &Matrix,
    jc: usize,
    rows: Range<usize>,
    blk: &mut [f32],
) {
    for r in rows {
        let xr = &x.row(r)[ic..ic + b];
        let dr = &dy.row(r)[jc..jc + b];
        for (k, wrow) in blk.chunks_exact_mut(b).enumerate() {
            let a = xr[k];
            for (wc, dv) in wrow.iter_mut().zip(dr) {
                *wc += a * *dv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference: plain triple loop over one block.
    fn reference(b: usize, x: &Matrix, ic: usize, blk: &[f32], y: &mut Matrix, jc: usize) {
        for r in 0..x.rows {
            for k in 0..b {
                let a = x.get(r, ic + k);
                for c in 0..b {
                    let v = y.get(r, jc + c) + a * blk[k * b + c];
                    y.set(r, jc + c, v);
                }
            }
        }
    }

    fn apply(b: usize, x: &Matrix, ic: usize, blk: &[f32], y: &mut Matrix, jc: usize) {
        let ldy = y.cols;
        let rows = 0..x.rows;
        unsafe { block_panel(b, x, ic, rows, blk, y.data.as_mut_ptr(), ldy, jc) }
    }

    #[test]
    fn kernels_match_reference_all_widths() {
        // 4 and 8 exercise the generic path; 16/32/48 the const kernels;
        // m = 7 exercises the 4-row main loop plus remainder rows
        for b in [4usize, 8, 16, 32, 48] {
            let mut rng = Rng::new(100 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blk = rng.normal_vec(b * b, 0.5);
            let mut y = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut want = y.clone();
            apply(b, &x, b, &blk, &mut y, b); // middle block of x, second stripe of y
            reference(b, &x, b, &blk, &mut want, b);
            assert!(y.max_abs_diff(&want) < 1e-4, "b={b}: {}", y.max_abs_diff(&want));
        }
    }

    /// Reference for the transpose kernel: plain triple loop over blkᵀ.
    fn reference_t(b: usize, x: &Matrix, ic: usize, blk: &[f32], y: &mut Matrix, jc: usize) {
        for r in 0..x.rows {
            for c in 0..b {
                let mut acc = y.get(r, jc + c);
                for k in 0..b {
                    acc += x.get(r, ic + k) * blk[c * b + k];
                }
                y.set(r, jc + c, acc);
            }
        }
    }

    #[test]
    fn transpose_kernels_match_reference_all_widths() {
        for b in [4usize, 8, 16, 32, 48] {
            let mut rng = Rng::new(300 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let blk = rng.normal_vec(b * b, 0.5);
            let mut y = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut want = y.clone();
            let ldy = y.cols;
            unsafe {
                block_panel_t(b, &x, b, 0..7, &blk, y.data.as_mut_ptr(), ldy, b)
            }
            reference_t(b, &x, b, &blk, &mut want, b);
            assert!(y.max_abs_diff(&want) < 1e-4, "b={b}: {}", y.max_abs_diff(&want));
        }
    }

    #[test]
    fn panel_then_transpose_panel_roundtrips_identity_block() {
        // with blk = I, both kernels reduce to y += x-segment; running the
        // forward panel and the transpose panel with the same identity
        // block must agree exactly
        let b = 16;
        let mut rng = Rng::new(11);
        let x = Matrix::randn(5, b, 1.0, &mut rng);
        let mut eye = vec![0.0f32; b * b];
        for i in 0..b {
            eye[i * b + i] = 1.0;
        }
        let mut a = Matrix::zeros(5, b);
        let mut t = Matrix::zeros(5, b);
        let (lda, ldt) = (a.cols, t.cols);
        unsafe {
            block_panel(b, &x, 0, 0..5, &eye, a.data.as_mut_ptr(), lda, 0);
            block_panel_t(b, &x, 0, 0..5, &eye, t.data.as_mut_ptr(), ldt, 0);
        }
        assert!(a.max_abs_diff(&t) < 1e-6);
        assert!(a.max_abs_diff(&x) < 1e-6);
    }

    /// Reference for the scatter kernel: plain triple loop.
    fn reference_scatter(b: usize, x: &Matrix, ic: usize, dy: &Matrix, jc: usize,
                         blk: &mut [f32]) {
        for r in 0..x.rows {
            for k in 0..b {
                for c in 0..b {
                    blk[k * b + c] += x.get(r, ic + k) * dy.get(r, jc + c);
                }
            }
        }
    }

    #[test]
    fn scatter_kernels_match_reference_all_widths() {
        // m = 7 exercises the 4-row main loop plus remainder rows
        for b in [4usize, 8, 16, 32, 48] {
            let mut rng = Rng::new(400 + b as u64);
            let x = Matrix::randn(7, 3 * b, 1.0, &mut rng);
            let dy = Matrix::randn(7, 2 * b, 1.0, &mut rng);
            let mut blk = rng.normal_vec(b * b, 0.5);
            let mut want = blk.clone();
            scatter_block(b, &x, b, &dy, b, 0..7, &mut blk);
            reference_scatter(b, &x, b, &dy, b, &mut want);
            let diff = blk
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-4, "b={b}: {diff}");
        }
    }

    #[test]
    fn accumulates_rather_than_overwrites() {
        let b = 16;
        let mut rng = Rng::new(9);
        let x = Matrix::randn(4, b, 1.0, &mut rng);
        let blk = rng.normal_vec(b * b, 1.0);
        let mut y = Matrix::zeros(4, b);
        apply(b, &x, 0, &blk, &mut y, 0);
        let once = y.clone();
        apply(b, &x, 0, &blk, &mut y, 0);
        for (got, want) in y.data.iter().zip(&once.data) {
            assert!((got - 2.0 * want).abs() < 1e-3);
        }
    }
}
