//! Parallel, cache-tiled execution engine for the block-sparse substrate.
//!
//! Plan/executor split (DESIGN.md "Execution engine"):
//! - [`plan::GemmPlan`] inverts a [`crate::sparse::BsrMatrix`]'s row
//!   structure once into a column-owned schedule — the block rows of Wᵀ —
//!   and partitions it into load-balanced chunks weighted by nnz blocks.
//! - [`pool`] is the dependency-free `std::thread` scoped worker pool:
//!   workers pull chunk × batch-panel tasks from a shared atomic cursor.
//! - [`micro`] holds the register-blocked `b×b` panel kernels
//!   (specialised for b ∈ {16, 32, 48}, generic fallback).
//!
//! Thread count resolution order: explicit [`set_threads`] (the CLI's
//! `--threads`), then `PIXELFLY_THREADS`, then available parallelism.
//! Small problems fall back to the serial path automatically so the
//! engine never pessimises the tiny shapes used in tests.
//!
//! Kernel tier resolution mirrors it: explicit [`set_kernel`] (the CLI's
//! `--kernel`), then `PIXELFLY_KERNEL`, then auto-detection — see
//! [`simd`]. [`workspace::Workspace`] is the scratch arena that keeps the
//! steady-state hot paths allocation-free.

pub mod micro;
pub mod plan;
pub mod pool;
pub mod simd;
pub mod workspace;

pub use plan::GemmPlan;
pub use simd::{kernel_choice, kernel_name, set_kernel, simd_available, KernelChoice};
pub use workspace::Workspace;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Below this many flops the scoped-pool spawn overhead outweighs the
/// parallel win and every engine path (BSR plan, dense panels, attention)
/// stays serial. One knob — retune it here, not per call site.
pub const MIN_PAR_FLOPS: f64 = 4.0e6;
use std::sync::OnceLock;

/// 0 = no override; set once from the CLI / caller.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Env/auto detection resolved once: `threads()` sits on the hot path
/// (every matmul/attention call), so no per-call env-lock or syscall.
static DETECTED: OnceLock<usize> = OnceLock::new();

/// Override the substrate thread count for this process (0 clears the
/// override and returns to env/auto detection).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Effective substrate thread count: `set_threads` override, else
/// `PIXELFLY_THREADS`, else `std::thread::available_parallelism()`
/// (the latter two resolved once per process).
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    *DETECTED.get_or_init(|| {
        parse_threads(std::env::var("PIXELFLY_THREADS").ok()).unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

fn parse_threads(v: Option<String>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_threads_filters_garbage() {
        assert_eq!(parse_threads(None), None);
        assert_eq!(parse_threads(Some("0".into())), None);
        assert_eq!(parse_threads(Some("abc".into())), None);
        assert_eq!(parse_threads(Some(" 8 ".into())), Some(8));
    }

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
    }
}
