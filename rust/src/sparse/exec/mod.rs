//! Parallel, cache-tiled execution engine for the block-sparse substrate.
//!
//! Plan/executor split (DESIGN.md "Execution engine"):
//! - [`plan::GemmPlan`] inverts a [`crate::sparse::BsrMatrix`]'s row
//!   structure once into a column-owned schedule — the block rows of Wᵀ —
//!   and partitions it into load-balanced chunks weighted by nnz blocks.
//! - [`pool`] is the dependency-free resident worker-pool runtime:
//!   long-lived workers park on a Condvar/atomic-epoch doorbell and pull
//!   chunk × batch-panel tasks of dispatched job batches from a shared
//!   atomic cursor (`PIXELFLY_POOL=scoped` keeps the old spawn-per-call
//!   path as the fallback/oracle).
//! - [`micro`] holds the register-blocked `b×b` panel kernels
//!   (specialised for b ∈ {16, 32, 48}, generic fallback).
//!
//! Thread count resolution order: explicit [`set_threads`] (the CLI's
//! `--threads`), then `PIXELFLY_THREADS`, then available parallelism.
//! Small problems fall back to the serial path automatically; the
//! cutover is no longer a hard-coded constant but a one-shot startup
//! [`calibration`] of measured dispatch overhead against the measured
//! per-flop kernel rate (override with `PIXELFLY_PAR_FLOPS`).
//!
//! Kernel tier resolution mirrors it: explicit [`set_kernel`] (the CLI's
//! `--kernel`), then `PIXELFLY_KERNEL`, then auto-detection — see
//! [`simd`]. [`workspace::Workspace`] is the scratch arena that keeps the
//! steady-state hot paths allocation-free.
//!
//! A third axis, precision, resolves the same way: explicit
//! [`set_precision`] (the CLI's `--precision`), then `PIXELFLY_PREC`,
//! then f32 — see [`quant`] for the bf16 training tier and the per-block
//! int8 inference tier it selects between.
//!
//! The training tier lives here too: [`Activation`] (the epilogue the
//! GEMM plans can fuse into their output sweep), [`epilogue_backward`]
//! (the matching dz = dy ⊙ act' pass with the bias gradient folded in),
//! and [`sgd_momentum`] (the fused optimizer sweep over stored blocks).

pub mod micro;
pub mod overlap;
pub mod plan;
pub mod pool;
pub mod quant;
pub mod simd;
pub mod workspace;

pub use overlap::{overlap_mode, set_overlap, OverlapMode, OverlapScope, OverlapStats};
pub use plan::{Epilogue, GemmPlan};
pub use pool::{pool_mode, set_pool_mode, step_scope, worker_alloc_events, PoolMode};
pub use quant::{precision, precision_name, set_precision, Precision};
pub use simd::{kernel_choice, kernel_name, set_kernel, simd_available, KernelChoice};
pub use workspace::Workspace;

use crate::sparse::dense::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// 0 = no override; set once from the CLI / caller.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Env/auto detection resolved once: `threads()` sits on the hot path
/// (every matmul/attention call), so no per-call env-lock or syscall.
static DETECTED: OnceLock<usize> = OnceLock::new();

/// Override the substrate thread count for this process (0 clears the
/// override and returns to env/auto detection).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Effective substrate thread count: `set_threads` override, else
/// `PIXELFLY_THREADS`, else `std::thread::available_parallelism()`
/// (the latter two resolved once per process).
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    *DETECTED.get_or_init(|| {
        parse_threads(std::env::var("PIXELFLY_THREADS").ok()).unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

fn parse_threads(v: Option<String>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

// ---------------------------------------------------------------------
// Startup calibration: serial-vs-parallel cutover from measured numbers
// ---------------------------------------------------------------------

/// One-shot startup measurement replacing the old hard-coded
/// `MIN_PAR_FLOPS` constant: the cutover between the serial path and a
/// pool dispatch is decided from *this machine's* dispatch overhead and
/// kernel rate, not a number tuned on whatever box wrote the constant.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// measured cost of one empty job-batch dispatch on the active pool
    /// mode (resident doorbell ring or scoped spawn+join), nanoseconds
    pub dispatch_ns: f64,
    /// measured serial rate of the active SIMD tier's dot primitive
    /// (the building block of every hot loop), ns per flop
    pub ns_per_flop: f64,
    /// flops below which every engine path (BSR plan, dense panels,
    /// attention, optimizer sweep) stays serial
    pub par_threshold_flops: f64,
}

/// One slot per [`PoolMode`] (resident, scoped): dispatch cost differs
/// by orders of magnitude between the substrates, so a threshold
/// measured under one mode must never govern the other after a
/// `set_pool_mode` switch.
static CALIBRATIONS: [OnceLock<Calibration>; 2] = [OnceLock::new(), OnceLock::new()];

/// The calibration for the ACTIVE pool mode, measured once per mode on
/// first use (a few hundred microseconds). `PIXELFLY_PAR_FLOPS=<flops>`
/// pins the threshold without measuring — CI determinism and
/// experiments.
pub fn calibration() -> &'static Calibration {
    let mode = pool::pool_mode();
    let slot = match mode {
        PoolMode::Resident => &CALIBRATIONS[0],
        PoolMode::Scoped => &CALIBRATIONS[1],
    };
    slot.get_or_init(|| measure_calibration(mode))
}

fn measure_calibration(mode: PoolMode) -> Calibration {
    {
        if let Some(t) = std::env::var("PIXELFLY_PAR_FLOPS")
            .ok()
            .and_then(|s| s.trim().parse::<f64>().ok())
            .filter(|t| *t > 0.0)
        {
            return Calibration { dispatch_ns: 0.0, ns_per_flop: 0.0,
                                 par_threshold_flops: t };
        }
        let workers = threads();
        if workers <= 1 {
            // one worker: parallelism never pays, whatever the numbers
            return Calibration { dispatch_ns: 0.0, ns_per_flop: 0.0,
                                 par_threshold_flops: f64::INFINITY };
        }
        // (a) dispatch overhead of the requested pool mode: empty job
        // batches, one task per worker. The first call warms the pool
        // (spawns residents / first scoped spawn) outside the clock.
        pool::run_tasks_in(mode, workers, workers, |t| {
            std::hint::black_box(t);
        });
        const REPS: usize = 32;
        let t0 = Instant::now();
        for _ in 0..REPS {
            pool::run_tasks_in(mode, workers, workers, |t| {
                std::hint::black_box(t);
            });
        }
        let dispatch_ns = t0.elapsed().as_nanos() as f64 / REPS as f64;
        // (b) serial kernel rate: the resolved tier's dot primitive over
        // an L1-resident operand pair
        let tier = simd::active_tier();
        let n = 4096usize;
        let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.71).cos()).collect();
        const KREPS: usize = 256;
        let t0 = Instant::now();
        let mut acc = 0.0f32;
        for _ in 0..KREPS {
            acc += simd::dot_with(tier, std::hint::black_box(&a),
                                  std::hint::black_box(&b));
        }
        std::hint::black_box(acc);
        let ns_per_flop =
            (t0.elapsed().as_nanos() as f64 / (2 * n * KREPS) as f64).max(1e-4);
        // breakeven: f·r = f·r/w + D  ⇒  f = D / (r·(1 − 1/w)); 2× safety
        // so borderline shapes stay serial, clamped against degenerate
        // timer readings on noisy machines
        let frac = (1.0 - 1.0 / workers as f64).max(0.25);
        let thresh = 2.0 * dispatch_ns / (ns_per_flop * frac);
        Calibration {
            dispatch_ns,
            ns_per_flop,
            par_threshold_flops: thresh.clamp(2.0e5, 6.4e7),
        }
    }
}

/// The calibrated serial-vs-parallel cutover in flops — what every
/// engine path consults where `MIN_PAR_FLOPS` used to sit.
pub fn par_threshold_flops() -> f64 {
    calibration().par_threshold_flops
}

// ---------------------------------------------------------------------
// Epilogues + optimizer sweep (the training tier's scalar contracts)
// ---------------------------------------------------------------------

/// `tanh` coefficient of the GELU approximation, √(2/π).
const GELU_C: f32 = 0.797_884_56;
/// Cubic coefficient of the GELU approximation.
const GELU_A: f32 = 0.044_715;

/// Elementwise activation a GEMM plan can fuse into its output sweep
/// (and whose derivative the backward pass folds into the dz sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Relu,
    /// tanh-approximated GELU (the transformer MLP default).
    Gelu,
}

impl Activation {
    /// a = act(z).
    #[inline]
    pub fn apply(self, z: f32) -> f32 {
        match self {
            Activation::Identity => z,
            Activation::Relu => z.max(0.0),
            Activation::Gelu => {
                0.5 * z * (1.0 + (GELU_C * (z + GELU_A * z * z * z)).tanh())
            }
        }
    }

    /// Whether the backward pass needs the pre-activation `z` (GELU) or
    /// can recover act' from the activated output alone (Identity/ReLU).
    /// Fused forwards must stash `z` exactly when this is true.
    #[inline]
    pub fn needs_pre(self) -> bool {
        matches!(self, Activation::Gelu)
    }

    /// Select the auxiliary matrix [`Self::grad_from_aux`] consumes from
    /// a layer's activated output and (optional) stashed pre-activation
    /// — the one place the aux contract lives, so every backward caller
    /// (trainer layers, tests) picks identically.
    #[inline]
    pub fn pick_aux<'a>(self, out: &'a Matrix, pre: Option<&'a Matrix>)
                        -> Option<&'a Matrix> {
        match self {
            Activation::Identity => None,
            Activation::Relu => Some(out),
            Activation::Gelu => pre,
        }
    }

    /// act'(z) given the auxiliary value the forward kept: the activated
    /// output `a` for ReLU (act' = 1[a > 0]), the pre-activation `z` for
    /// GELU; Identity ignores it.
    #[inline]
    pub fn grad_from_aux(self, aux: f32) -> f32 {
        match self {
            Activation::Identity => 1.0,
            Activation::Relu => {
                if aux > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Gelu => {
                let z = aux;
                let u = GELU_C * (z + GELU_A * z * z * z);
                let t = u.tanh();
                0.5 * (1.0 + t)
                    + 0.5 * z * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * z * z)
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Activation::Identity => "identity",
            Activation::Relu => "relu",
            Activation::Gelu => "gelu",
        }
    }
}

/// Backward epilogue, fused: `dy ⊙= act'(aux)` in place AND (when `db` is
/// given) `db[c] += Σ_r dz[r, c]` in the same sweep — the two O(m·n)
/// passes an unfused backward would spend on the activation derivative
/// and the bias reduction collapse into one.
///
/// `aux` is what the fused forward kept: the activated output for ReLU,
/// the stashed pre-activation for GELU (see [`Activation::grad_from_aux`]);
/// `None` is allowed only for Identity. `db` accumulates (callers zero it
/// once per step, so microbatches can sum).
pub fn epilogue_backward(dy: &mut Matrix, aux: Option<&Matrix>, act: Activation,
                         mut db: Option<&mut [f32]>) {
    if let Some(a) = aux {
        assert_eq!((a.rows, a.cols), (dy.rows, dy.cols));
    } else {
        assert_eq!(act, Activation::Identity, "{act:?} backward needs its aux matrix");
    }
    if let Some(db) = db.as_deref() {
        assert_eq!(db.len(), dy.cols);
    }
    for r in 0..dy.rows {
        let dyrow = &mut dy.data[r * dy.cols..(r + 1) * dy.cols];
        if act != Activation::Identity {
            let auxrow = aux.unwrap().row(r);
            for (d, &a) in dyrow.iter_mut().zip(auxrow) {
                *d *= act.grad_from_aux(a);
            }
        }
        if let Some(db) = db.as_deref_mut() {
            for (acc, &d) in db.iter_mut().zip(dyrow.iter()) {
                *acc += d;
            }
        }
    }
}

/// Fused SGD-with-momentum update `m = momentum·m + g; w -= lr·m` over a
/// parameter slice — one SIMD sweep (two FMAs per element), split across
/// the worker pool when the slice is large enough to be bandwidth-bound.
/// This is the whole optimizer step for a BSR layer: `w` is the stored
/// blocks, `g` the pattern-frozen gradient, no densification anywhere.
pub fn sgd_momentum(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32, momentum: f32) {
    let n = w.len();
    assert_eq!(n, g.len());
    assert_eq!(n, m.len());
    let workers = threads();
    // 2 flops/element; reuse the calibrated cutover so tiny layers stay serial
    if workers <= 1 || (2 * n) as f64 * 2.0 < par_threshold_flops() {
        let tier = simd::active_tier();
        return simd::sgd_momentum_with(tier, w, g, m, lr, momentum);
    }
    sgd_momentum_split(w, g, m, lr, momentum, workers);
}

/// The pool-split sweep behind [`sgd_momentum`], gate-free so the parity
/// test can exercise the parallel path regardless of what the host's
/// calibration decided. Arithmetic chunking (no range vector): this sits
/// on the per-layer per-step hot path, and a dispatch must not allocate.
fn sgd_momentum_split(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32,
                      momentum: f32, workers: usize) {
    let n = w.len();
    let tier = simd::active_tier();
    let per = n.div_ceil(workers.max(1));
    let n_chunks = n.div_ceil(per.max(1));
    let wp = pool::SyncPtr(w.as_mut_ptr());
    let mp = pool::SyncPtr(m.as_mut_ptr());
    pool::run_tasks(n_chunks, workers, |t| {
        // capture the whole wrappers (not the raw-pointer fields) so the
        // closure stays Sync under edition-2021 precise capture
        let (wp, mp) = (&wp, &mp);
        let start = t * per;
        let len = per.min(n - start);
        // Safety: the chunks partition 0..n, so this task exclusively
        // owns w[start..start+len] and m[start..start+len]; g is shared
        // read-only; start + len <= n bounds every access.
        let (wc, mc) = unsafe {
            (std::slice::from_raw_parts_mut(wp.0.add(start), len),
             std::slice::from_raw_parts_mut(mp.0.add(start), len))
        };
        simd::sgd_momentum_with(tier, wc, &g[start..start + len], mc, lr, momentum);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn parse_threads_filters_garbage() {
        assert_eq!(parse_threads(None), None);
        assert_eq!(parse_threads(Some("0".into())), None);
        assert_eq!(parse_threads(Some("abc".into())), None);
        assert_eq!(parse_threads(Some(" 8 ".into())), Some(8));
    }

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
    }

    #[test]
    fn calibration_yields_a_usable_threshold() {
        let c = calibration();
        assert!(c.par_threshold_flops > 0.0);
        // repeated calls return the same one-shot measurement
        assert_eq!(calibration().par_threshold_flops, c.par_threshold_flops);
        if threads() > 1 && std::env::var("PIXELFLY_PAR_FLOPS").is_err() {
            assert!(c.par_threshold_flops.is_finite(), "multi-core must allow parallel");
            assert!(c.ns_per_flop > 0.0);
            assert!(c.dispatch_ns >= 0.0);
        }
    }

    #[test]
    fn activations_match_hand_values() {
        assert_eq!(Activation::Identity.apply(-1.5), -1.5);
        assert_eq!(Activation::Relu.apply(-1.5), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        // GELU anchor points: gelu(0) = 0; gelu(z) → z for large z,
        // → 0 for very negative z
        assert_eq!(Activation::Gelu.apply(0.0), 0.0);
        assert!((Activation::Gelu.apply(10.0) - 10.0).abs() < 1e-4);
        assert!(Activation::Gelu.apply(-10.0).abs() < 1e-4);
        // a known midpoint: gelu(1) ≈ 0.8412 (tanh approximation)
        assert!((Activation::Gelu.apply(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn activation_grads_match_finite_differences() {
        let eps = 1e-3f32;
        for act in [Activation::Identity, Activation::Gelu] {
            for z in [-2.0f32, -0.7, 0.0, 0.3, 1.9] {
                let fd = (act.apply(z + eps) - act.apply(z - eps)) / (2.0 * eps);
                let an = act.grad_from_aux(z); // identity ignores aux
                assert!((fd - an).abs() < 1e-2, "{act:?} z={z}: fd {fd} vs {an}");
            }
        }
        // ReLU's grad comes from the OUTPUT a, not z
        assert_eq!(Activation::Relu.grad_from_aux(2.0), 1.0);
        assert_eq!(Activation::Relu.grad_from_aux(0.0), 0.0);
    }

    #[test]
    fn epilogue_backward_scales_and_reduces_in_one_pass() {
        let mut rng = Rng::new(51);
        let dy0 = Matrix::randn(5, 8, 1.0, &mut rng);
        let z = Matrix::randn(5, 8, 1.0, &mut rng);
        // gelu path: dz = dy ⊙ gelu'(z), db = column sums of dz
        let mut dy = dy0.clone();
        let mut db = vec![0.0f32; 8];
        epilogue_backward(&mut dy, Some(&z), Activation::Gelu, Some(&mut db));
        for r in 0..5 {
            for c in 0..8 {
                let want = dy0.get(r, c) * Activation::Gelu.grad_from_aux(z.get(r, c));
                assert!((dy.get(r, c) - want).abs() < 1e-5);
            }
        }
        for c in 0..8 {
            let want: f32 = (0..5).map(|r| dy.get(r, c)).sum();
            assert!((db[c] - want).abs() < 1e-5);
        }
        // identity + no db is a no-op
        let mut dy2 = dy0.clone();
        epilogue_backward(&mut dy2, None, Activation::Identity, None);
        assert!(dy2.max_abs_diff(&dy0) < 1e-7);
    }

    #[test]
    fn sgd_momentum_parallel_matches_serial() {
        let mut rng = Rng::new(52);
        let n = 2_000_000;
        let w0 = rng.normal_vec(n, 1.0);
        let g = rng.normal_vec(n, 1.0);
        let m0 = rng.normal_vec(n, 1.0);
        let mut wp = w0.clone();
        let mut mp = m0.clone();
        // drive the pool split directly (gate-free): the public wrapper's
        // calibrated cutover may keep this shape serial on slow hosts,
        // and the point here is parallel-vs-serial parity
        sgd_momentum_split(&mut wp, &g, &mut mp, 0.1, 0.9, 4);
        let mut ws = w0.clone();
        let mut ms = m0.clone();
        simd::sgd_momentum_scalar(&mut ws, &g, &mut ms, 0.1, 0.9);
        for i in (0..n).step_by(997) {
            assert!((wp[i] - ws[i]).abs() < 1e-5, "i={i}");
            assert!((mp[i] - ms[i]).abs() < 1e-5, "i={i}");
        }
    }
}
