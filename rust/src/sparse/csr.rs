//! Element-level CSR matrix + GEMM: the *unstructured* sparsity baseline
//! (original RigL / magnitude pruning).  Table 7's "random 1x1" measured
//! for real: same nominal FLOPs as a block pattern at equal density, but
//! the scattered access pattern defeats vectorisation and cache lines —
//! the CPU analogue of the paper's GPU memory-coalescing argument.

use crate::patterns::BlockMask;
use crate::sparse::dense::Matrix;
use crate::util::Rng;

/// CSR matrix (f32).
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Random CSR at the given element density.
    pub fn random(rows: usize, cols: usize, density: f64, scale: f32,
                  rng: &mut Rng) -> Self {
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for _ in 0..rows {
            for c in 0..cols {
                if rng.bool(density) {
                    col_idx.push(c);
                }
            }
            row_ptr.push(col_idx.len());
        }
        let values = rng.normal_vec(col_idx.len(), scale);
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    /// From an element mask.
    pub fn from_mask(mask: &BlockMask, scale: f32, rng: &mut Rng) -> Self {
        let mut row_ptr = vec![0usize];
        let mut col_idx = Vec::new();
        for r in 0..mask.rows {
            for c in mask.row_cols(r) {
                col_idx.push(c);
            }
            row_ptr.push(col_idx.len());
        }
        let values = rng.normal_vec(col_idx.len(), scale);
        CsrMatrix { rows: mask.rows, cols: mask.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for s in self.row_ptr[r]..self.row_ptr[r + 1] {
                m.set(r, self.col_idx[s], self.values[s]);
            }
        }
        m
    }

    /// y = x * W with W in CSR: scattered writes into y per nonzero — the
    /// unstructured access pattern under test.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.cols);
        self.matmul_into(x, &mut y);
        y
    }

    pub fn matmul_into(&self, x: &Matrix, y: &mut Matrix) {
        assert_eq!(x.cols, self.rows);
        y.data.fill(0.0);
        for m in 0..x.rows {
            let xrow = x.row(m);
            let yrow = y.row_mut(m);
            for r in 0..self.rows {
                let xv = xrow[r];
                if xv == 0.0 {
                    continue;
                }
                for s in self.row_ptr[r]..self.row_ptr[r + 1] {
                    yrow[self.col_idx[s]] += xv * self.values[s];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense::matmul_blocked;

    #[test]
    fn csr_matmul_matches_dense() {
        let mut rng = Rng::new(41);
        let w = CsrMatrix::random(24, 16, 0.3, 1.0, &mut rng);
        let x = Matrix::randn(7, 24, 1.0, &mut rng);
        let y = w.matmul(&x);
        let yref = matmul_blocked(&x, &w.to_dense());
        assert!(y.max_abs_diff(&yref) < 1e-4);
    }

    #[test]
    fn from_mask_respects_support() {
        let mut rng = Rng::new(42);
        let mut mask = BlockMask::zeros(6, 6);
        mask.set(0, 3, true);
        mask.set(5, 5, true);
        let w = CsrMatrix::from_mask(&mask, 1.0, &mut rng);
        assert_eq!(w.nnz(), 2);
        let d = w.to_dense();
        assert_eq!(d.get(1, 1), 0.0);
        assert!(d.get(0, 3) != 0.0);
    }

    #[test]
    fn density_accounting() {
        let mut rng = Rng::new(43);
        let w = CsrMatrix::random(64, 64, 0.1, 1.0, &mut rng);
        assert!((w.density() - 0.1).abs() < 0.05);
    }
}
