//! Sequential butterfly product vs flat butterfly multiply (Fig 11 / App J)
//! on the Rust substrate.
//!
//! The product form applies log2(k) residual factor multiplies
//! y <- y + λ (y · B_s), each a full pass over the activations; the flat
//! form is ONE BSR multiply with the union pattern.  Same O(n log k)
//! FLOPs — the measured gap is pure scheduling/memory-traffic, which is
//! the paper's point.

use crate::patterns::butterfly::{butterfly_factor_mask, flat_butterfly_mask};
use crate::sparse::bsr::BsrMatrix;
use crate::sparse::dense::Matrix;
use crate::sparse::exec::{workspace, Workspace};
use crate::util::Rng;

/// The residual-product operator (I + λB_2)…(I + λB_k) stored as factors.
pub struct ButterflyProduct {
    pub factors: Vec<BsrMatrix>, // lowest stride first
    pub lam: f32,
    pub block: usize,
}

impl ButterflyProduct {
    pub fn random(n: usize, block: usize, max_stride: usize, lam: f32,
                  rng: &mut Rng) -> Self {
        assert_eq!(n % block, 0);
        let nb = n / block;
        let mut factors = Vec::new();
        let mut s = 2;
        while s <= max_stride {
            let mask = butterfly_factor_mask(nb, s);
            factors.push(BsrMatrix::random(&mask, block, 1.0 / (2.0 * block as f32).sqrt(), rng));
            s *= 2;
        }
        ButterflyProduct { factors, lam, block }
    }

    /// y = x (I + λB_k) … (I + λB_2): apply highest stride first
    /// (row-vector convention matching kernels/ref.py). Scratch comes
    /// from the thread-local workspace, so repeated calls are zero-alloc
    /// apart from the output clone.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let mut y = x.clone();
        workspace::with_thread_workspace(|ws| self.apply_assign(&mut y, ws));
        y
    }

    /// In-place product application y ← y (I + λB_k) … (I + λB_2) with
    /// scratch from `ws` — the fully zero-alloc form the benches and the
    /// trainer-side hot loops use.
    pub fn apply_assign(&self, y: &mut Matrix, ws: &mut Workspace) {
        let mut scratch =
            Matrix { rows: y.rows, cols: y.cols, data: ws.take(y.rows * y.cols) };
        for f in self.factors.iter().rev() {
            f.matmul_into(y, &mut scratch);
            for (yv, sv) in y.data.iter_mut().zip(&scratch.data) {
                *yv += self.lam * sv;
            }
        }
        ws.give(scratch.data);
    }

    /// Gradient buffers matching this product's factors (one pattern-
    /// frozen block buffer per factor, mirroring each factor's storage).
    pub fn grad_buffers(&self) -> Vec<Vec<f32>> {
        self.factors.iter().map(|f| vec![0.0f32; f.blocks.len()]).collect()
    }

    /// Backward of [`Self::apply_assign`]: given `dy` for the product
    /// output, computes `dx` and per-factor gradients `d_factors`
    /// (indexed like `self.factors`, each mirroring that factor's stored
    /// blocks — pattern-frozen, no fill-in).
    ///
    /// The chain needs each factor's *input* activation, so the forward
    /// is recomputed once with all log₂(k) stages parked in `ws` scratch
    /// (O(log k · m·n) floats — reused across calls); the reverse sweep
    /// then walks the stages with the transpose-free `execute_dx` and the
    /// scatter `execute_dw` of each factor's cached plan. Every
    /// intermediate shares the one workspace.
    pub fn backward_into(&self, x: &Matrix, dy: &Matrix, dx: &mut Matrix,
                         d_factors: &mut [Vec<f32>], ws: &mut Workspace) {
        let nf = self.factors.len();
        assert_eq!(d_factors.len(), nf);
        let (m, n) = (x.rows, x.cols);
        assert_eq!((dy.rows, dy.cols), (m, n));
        assert_eq!((dx.rows, dx.cols), (m, n));
        if nf == 0 {
            dx.data.copy_from_slice(&dy.data);
            return;
        }
        // forward recompute, parking the input of each application stage
        // (application order is highest stride first: factor nf-1-t at
        // stage t)
        let mut stages: Vec<Matrix> = (0..nf)
            .map(|_| Matrix { rows: m, cols: n, data: ws.take(m * n) })
            .collect();
        let mut cur = Matrix { rows: m, cols: n, data: ws.take(m * n) };
        let mut scratch = Matrix { rows: m, cols: n, data: ws.take(m * n) };
        cur.data.copy_from_slice(&x.data);
        for t in 0..nf {
            stages[t].data.copy_from_slice(&cur.data);
            let f = &self.factors[nf - 1 - t];
            f.matmul_into(&cur, &mut scratch);
            for (yv, sv) in cur.data.iter_mut().zip(&scratch.data) {
                *yv += self.lam * sv;
            }
        }
        // reverse sweep: cur becomes the running cotangent dy_t
        cur.data.copy_from_slice(&dy.data);
        for t in (0..nf).rev() {
            let fi = nf - 1 - t;
            let f = &self.factors[fi];
            // dB = λ · y_tᵀ · dy_{t+1}, scattered into the stored pattern
            f.matmul_dw_into(&stages[t], &cur, &mut d_factors[fi]);
            crate::sparse::exec::simd::scale(&mut d_factors[fi], self.lam);
            // dy_t = dy_{t+1} + λ · dy_{t+1}·Bᵀ (transpose-free)
            f.matmul_dx_into(&cur, &mut scratch);
            for (dv, sv) in cur.data.iter_mut().zip(&scratch.data) {
                *dv += self.lam * sv;
            }
        }
        dx.data.copy_from_slice(&cur.data);
        ws.give(scratch.data);
        ws.give(cur.data);
        for s in stages {
            ws.give(s.data);
        }
    }

    /// The flat first-order approximation: I + λ Σ B_s as one BSR matrix.
    pub fn flatten(&self) -> BsrMatrix {
        let nb = self.factors[0].nbr;
        let b = self.block;
        let max_stride = 1usize << self.factors.len();
        let mask = flat_butterfly_mask(nb, max_stride);
        let mut dense = Matrix::zeros(nb * b, nb * b);
        for i in 0..nb * b {
            dense.set(i, i, 1.0);
        }
        for f in &self.factors {
            let fd = f.to_dense();
            for (d, s) in dense.data.iter_mut().zip(&fd.data) {
                *d += self.lam * s;
            }
        }
        BsrMatrix::from_dense(&dense, &mask, b)
    }
}

/// Gradients of a [`FlatLowRank`] layer: the flat term's gradient
/// mirrors the stored blocks slot for slot (pattern-frozen — fill-in
/// cannot exist), plus dense dU/dV factors.
#[derive(Clone, Debug)]
pub struct FlatLowRankGrads {
    pub d_flat: Vec<f32>,
    pub du: Matrix,
    pub dv: Matrix,
}

impl FlatLowRankGrads {
    pub fn zeros_like(flr: &FlatLowRank) -> Self {
        FlatLowRankGrads {
            d_flat: vec![0.0f32; flr.flat.blocks.len()],
            du: Matrix::zeros(flr.u.rows, flr.u.cols),
            dv: Matrix::zeros(flr.v.rows, flr.v.cols),
        }
    }
}

/// The paper's pixelfly layer on the substrate: flat block butterfly plus
/// a low-rank term, W = B_flat + U·V (§3.2 "flat butterfly + low rank").
///
/// Both terms route through the parallel engine: the sparse term through
/// the BSR [`crate::sparse::exec::GemmPlan`] (reused across batches), the
/// low-rank term through the panel-tiled dense path — so the composite's
/// latency tracks the block cover plus 2·n·r, exactly the cost model's
/// accounting.
pub struct FlatLowRank {
    pub flat: BsrMatrix,
    /// [n, r]
    pub u: Matrix,
    /// [r, n]
    pub v: Matrix,
    plan: crate::sparse::exec::GemmPlan,
}

impl FlatLowRank {
    /// Random composite on [n, n]: flat butterfly to `max_stride` at the
    /// given block size plus a rank-`rank` correction (rank 0 disables it).
    pub fn random(n: usize, block: usize, max_stride: usize, rank: usize,
                  scale: f32, rng: &mut Rng) -> Self {
        assert_eq!(n % block, 0);
        let mask = flat_butterfly_mask(n / block, max_stride);
        let flat = BsrMatrix::random(&mask, block, scale, rng);
        let lr_scale = if rank > 0 {
            scale / (rank as f32).sqrt()
        } else {
            0.0
        };
        let u = Matrix::randn(n, rank, lr_scale, rng);
        let v = Matrix::randn(rank, n, lr_scale, rng);
        Self::new(flat, u, v)
    }

    /// Random rectangular composite on [rows, cols]: the stretched flat
    /// butterfly (Appendix I.4 — the square pattern tiled along the long
    /// dimension) plus a rank-`rank` correction (rank 0 disables it).
    /// This is what the model compiler materialises `LayerPlan`s with;
    /// the square [`Self::random`] stays as the Fig-11 testbed form.
    pub fn random_rect(rows: usize, cols: usize, block: usize, max_stride: usize,
                       rank: usize, scale: f32, rng: &mut Rng) -> Self {
        assert_eq!(rows % block, 0);
        assert_eq!(cols % block, 0);
        let mask = crate::patterns::butterfly::stretched_flat_butterfly(
            rows / block, cols / block, max_stride);
        let flat = BsrMatrix::random(&mask, block, scale, rng);
        let lr_scale = if rank > 0 {
            scale / (rank as f32).sqrt()
        } else {
            0.0
        };
        let u = Matrix::randn(rows, rank, lr_scale, rng);
        let v = Matrix::randn(rank, cols, lr_scale, rng);
        Self::new(flat, u, v)
    }

    /// Compose an existing flat term with a low-rank factor pair.
    pub fn new(flat: BsrMatrix, u: Matrix, v: Matrix) -> Self {
        assert_eq!(u.rows, flat.rows());
        assert_eq!(u.cols, v.rows);
        assert_eq!(v.cols, flat.cols_elems());
        let plan = flat.plan(crate::sparse::exec::threads());
        FlatLowRank { flat, u, v, plan }
    }

    pub fn rank(&self) -> usize {
        self.u.cols
    }

    /// y = x·B_flat + (x·U)·V (allocating wrapper over [`Self::matmul_into`];
    /// intermediates come from the thread-local workspace).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.flat.cols_elems());
        workspace::with_thread_workspace(|ws| self.matmul_into(x, &mut y, ws));
        y
    }

    /// y = x·B_flat + (x·U)·V with both low-rank intermediates checked out
    /// of `ws` — the composite used to allocate three fresh matrices per
    /// call; this form allocates nothing once the workspace is warm.
    pub fn matmul_into(&self, x: &Matrix, y: &mut Matrix, ws: &mut Workspace) {
        self.flat.matmul_with_plan(&self.plan, x, y);
        if self.rank() > 0 {
            let n = self.flat.cols_elems();
            let mut t =
                Matrix { rows: x.rows, cols: self.rank(), data: ws.take(x.rows * self.rank()) };
            crate::sparse::dense::matmul_blocked_into(x, &self.u, &mut t);
            let mut lr = Matrix { rows: x.rows, cols: n, data: ws.take(x.rows * n) };
            crate::sparse::dense::matmul_blocked_into(&t, &self.v, &mut lr);
            for (yv, lv) in y.data.iter_mut().zip(&lr.data) {
                *yv += lv;
            }
            ws.give(t.data);
            ws.give(lr.data);
        }
    }

    /// Backward of [`Self::matmul_into`]: `y = x·B + (x·U)·V` gives
    ///
    ///   dB = Xᵀ·dY (stored pattern only), dV = (X·U)ᵀ·dY,
    ///   dU = Xᵀ·(dY·Vᵀ), dX = dY·Bᵀ + (dY·Vᵀ)·Uᵀ.
    ///
    /// The sparse terms ride the composite's cached plan (`execute_dx` /
    /// `execute_dw` — transpose-free, pattern-frozen); the dense low-rank
    /// terms use the `A·Bᵀ` / `Aᵀ·B` kernels, which never materialise a
    /// transpose either. All three intermediates (`x·U`, `dY·Vᵀ`, the
    /// low-rank dX term) share ONE workspace checkout lifetime — the
    /// whole backward is zero-alloc once `ws` is warm. `dx: None` skips
    /// BOTH input-gradient terms (the sparse dY·Bᵀ sweep and the
    /// low-rank dY·Vᵀ·Uᵀ GEMM) — a first-layer composite pays only the
    /// parameter gradients.
    pub fn backward_into(&self, x: &Matrix, dy: &Matrix, mut dx: Option<&mut Matrix>,
                         g: &mut FlatLowRankGrads, ws: &mut Workspace) {
        let (m, n) = (x.rows, self.flat.cols_elems());
        assert_eq!(x.cols, self.flat.rows());
        assert_eq!((dy.rows, dy.cols), (m, n));
        if let Some(dx) = dx.as_deref() {
            assert_eq!((dx.rows, dx.cols), (m, self.flat.rows()));
        }
        assert_eq!(g.d_flat.len(), self.flat.blocks.len());
        self.plan.execute_dw(&self.flat, x, dy, &mut g.d_flat);
        if let Some(dx) = dx.as_deref_mut() {
            self.plan.execute_dx(&self.flat, dy, dx);
        }
        let r = self.rank();
        if r > 0 {
            assert_eq!((g.du.rows, g.du.cols), (self.u.rows, r));
            assert_eq!((g.dv.rows, g.dv.cols), (r, n));
            // t = x·U (recomputed: m·n·r ≪ the sparse term at small rank)
            let mut t = Matrix { rows: m, cols: r, data: ws.take(m * r) };
            crate::sparse::dense::matmul_blocked_into(x, &self.u, &mut t);
            // dV = tᵀ·dY
            crate::sparse::dense::matmul_atb_into(&t, dy, &mut g.dv);
            // dyv = dY·Vᵀ (shared by dU and the dX term)
            let mut dyv = Matrix { rows: m, cols: r, data: ws.take(m * r) };
            crate::sparse::dense::matmul_abt_into(dy, &self.v, &mut dyv);
            // dU = Xᵀ·dyv
            crate::sparse::dense::matmul_atb_into(x, &dyv, &mut g.du);
            if let Some(dx) = dx.as_deref_mut() {
                // dX += dyv·Uᵀ
                let mut dxlr =
                    Matrix { rows: m, cols: dx.cols, data: ws.take(m * dx.cols) };
                crate::sparse::dense::matmul_abt_into(&dyv, &self.u, &mut dxlr);
                for (dv, lv) in dx.data.iter_mut().zip(&dxlr.data) {
                    *dv += lv;
                }
                ws.give(dxlr.data);
            }
            ws.give(t.data);
            ws.give(dyv.data);
        }
    }

    /// Critical-path half of [`FlatLowRank::backward_into`]: the input
    /// gradient only (flat dX term + low-rank dX term). The shared
    /// `dyv = dY·Vᵀ` intermediate is recomputed by each half — the same
    /// kernel over the same inputs, so the split stays bit-identical to
    /// the fused sweep at the cost of one skinny `m×r` GEMM.
    pub fn backward_dx_into(&self, x: &Matrix, dy: &Matrix, dx: &mut Matrix,
                            ws: &mut Workspace) {
        let (m, n) = (x.rows, self.flat.cols_elems());
        assert_eq!(x.cols, self.flat.rows());
        assert_eq!((dy.rows, dy.cols), (m, n));
        assert_eq!((dx.rows, dx.cols), (m, self.flat.rows()));
        self.plan.execute_dx(&self.flat, dy, dx);
        let r = self.rank();
        if r > 0 {
            let mut dyv = Matrix { rows: m, cols: r, data: ws.take(m * r) };
            crate::sparse::dense::matmul_abt_into(dy, &self.v, &mut dyv);
            let mut dxlr =
                Matrix { rows: m, cols: dx.cols, data: ws.take(m * dx.cols) };
            crate::sparse::dense::matmul_abt_into(&dyv, &self.u, &mut dxlr);
            for (dv, lv) in dx.data.iter_mut().zip(&dxlr.data) {
                *dv += lv;
            }
            ws.give(dxlr.data);
            ws.give(dyv.data);
        }
    }

    /// Deferred half of [`FlatLowRank::backward_into`]: every weight
    /// gradient (flat scatter + dU/dV), no dX. Reads `x`/`dy` only, so
    /// the overlap scheduler may run it off the critical path.
    pub fn backward_dw_into(&self, x: &Matrix, dy: &Matrix, g: &mut FlatLowRankGrads,
                            ws: &mut Workspace) {
        let (m, n) = (x.rows, self.flat.cols_elems());
        assert_eq!(x.cols, self.flat.rows());
        assert_eq!((dy.rows, dy.cols), (m, n));
        assert_eq!(g.d_flat.len(), self.flat.blocks.len());
        self.plan.execute_dw(&self.flat, x, dy, &mut g.d_flat);
        let r = self.rank();
        if r > 0 {
            assert_eq!((g.du.rows, g.du.cols), (self.u.rows, r));
            assert_eq!((g.dv.rows, g.dv.cols), (r, n));
            let mut t = Matrix { rows: m, cols: r, data: ws.take(m * r) };
            crate::sparse::dense::matmul_blocked_into(x, &self.u, &mut t);
            crate::sparse::dense::matmul_atb_into(&t, dy, &mut g.dv);
            let mut dyv = Matrix { rows: m, cols: r, data: ws.take(m * r) };
            crate::sparse::dense::matmul_abt_into(dy, &self.v, &mut dyv);
            crate::sparse::dense::matmul_atb_into(x, &dyv, &mut g.du);
            ws.give(t.data);
            ws.give(dyv.data);
        }
    }

    /// Dense materialisation (tests / inspection).
    pub fn to_dense(&self) -> Matrix {
        let mut w = self.flat.to_dense();
        for i in 0..self.u.rows {
            for j in 0..self.v.cols {
                let mut dot = 0.0f32;
                for r in 0..self.rank() {
                    dot += self.u.get(i, r) * self.v.get(r, j);
                }
                w.set(i, j, w.get(i, j) + dot);
            }
        }
        w
    }

    /// Parameter density relative to the dense [n, n] layer.
    pub fn density(&self) -> f64 {
        let n = self.flat.rows() * self.flat.cols_elems();
        let params = self.flat.nnz_blocks() * self.flat.block * self.flat.block
            + self.u.rows * self.u.cols
            + self.v.rows * self.v.cols;
        params as f64 / n as f64
    }
}

/// Frobenius distance between the product operator and its flat
/// approximation applied to x (Theorem 4.3 empirically, on the substrate).
pub fn flat_approximation_error(bp: &ButterflyProduct, x: &Matrix) -> f64 {
    let exact = bp.matmul(x);
    let flat = bp.flatten().matmul(x);
    let mut err = 0.0f64;
    let mut base = 0.0f64;
    for (a, b) in exact.data.iter().zip(&flat.data) {
        err += ((a - b) as f64).powi(2);
        base += (*a as f64).powi(2);
    }
    (err / base.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_support_is_flat_mask() {
        let mut rng = Rng::new(31);
        let bp = ButterflyProduct::random(64, 8, 8, 0.1, &mut rng);
        let flat = bp.flatten();
        let mask = flat_butterfly_mask(8, 8);
        assert_eq!(flat.nnz_blocks(), mask.nnz());
    }

    #[test]
    fn small_lambda_flat_approximates_product() {
        let mut rng = Rng::new(32);
        let bp = ButterflyProduct::random(64, 8, 8, 0.01, &mut rng);
        let x = Matrix::randn(16, 64, 1.0, &mut rng);
        let rel = flat_approximation_error(&bp, &x);
        assert!(rel < 0.01, "relative error {rel}");
    }

    #[test]
    fn error_quadratic_in_lambda() {
        let mut rng = Rng::new(33);
        let mut bp = ButterflyProduct::random(64, 8, 8, 0.01, &mut rng);
        let x = Matrix::randn(16, 64, 1.0, &mut Rng::new(34));
        let e1 = flat_approximation_error(&bp, &x);
        bp.lam = 0.02;
        let e2 = flat_approximation_error(&bp, &x);
        let ratio = e2 / e1.max(1e-30);
        assert!(ratio > 2.5 && ratio < 6.0, "expected ~4x, got {ratio}");
    }

    #[test]
    fn flat_lowrank_matches_dense_reference() {
        let mut rng = Rng::new(36);
        let flr = FlatLowRank::random(64, 8, 4, 16, 0.5, &mut rng);
        let x = Matrix::randn(9, 64, 1.0, &mut rng);
        let y = flr.matmul(&x);
        let yref = crate::sparse::dense::matmul_blocked(&x, &flr.to_dense());
        assert!(y.max_abs_diff(&yref) < 1e-3, "{}", y.max_abs_diff(&yref));
        assert!(flr.density() > 0.0 && flr.density() < 1.0);
    }

    #[test]
    fn rect_composite_matches_dense_reference() {
        let mut rng = Rng::new(44);
        let flr = FlatLowRank::random_rect(64, 32, 8, 4, 8, 0.5, &mut rng);
        let x = Matrix::randn(6, 64, 1.0, &mut rng);
        let y = flr.matmul(&x);
        let yref = crate::sparse::dense::matmul_blocked(&x, &flr.to_dense());
        assert!(y.max_abs_diff(&yref) < 1e-3, "{}", y.max_abs_diff(&yref));
        // and its backward stays consistent on the rectangular shape
        let dy = Matrix::randn(6, 32, 1.0, &mut rng);
        let mut dx = Matrix::zeros(6, 64);
        let mut g = FlatLowRankGrads::zeros_like(&flr);
        let mut ws = Workspace::new();
        flr.backward_into(&x, &dy, Some(&mut dx), &mut g, &mut ws);
        let want_dx = crate::sparse::dense::matmul_blocked(&dy, &flr.to_dense().transpose());
        assert!(dx.max_abs_diff(&want_dx) < 1e-3, "{}", dx.max_abs_diff(&want_dx));
    }

    #[test]
    fn flat_lowrank_rank_zero_is_pure_flat() {
        let mut rng = Rng::new(37);
        let flr = FlatLowRank::random(32, 4, 4, 0, 1.0, &mut rng);
        let x = Matrix::randn(6, 32, 1.0, &mut rng);
        let y = flr.matmul(&x);
        let yref = flr.flat.matmul(&x);
        assert!(y.max_abs_diff(&yref) < 1e-6);
    }

    #[test]
    fn composite_steady_state_is_zero_alloc() {
        let mut rng = Rng::new(38);
        let flr = FlatLowRank::random(64, 8, 4, 8, 0.5, &mut rng);
        let x = Matrix::randn(9, 64, 1.0, &mut rng);
        let mut y = Matrix::zeros(9, 64);
        let mut ws = Workspace::new();
        flr.matmul_into(&x, &mut y, &mut ws);
        let warm = ws.alloc_events();
        for _ in 0..3 {
            flr.matmul_into(&x, &mut y, &mut ws);
        }
        assert_eq!(ws.alloc_events(), warm, "hot path must not allocate");
    }

    #[test]
    fn product_apply_assign_matches_matmul() {
        let mut rng = Rng::new(39);
        let bp = ButterflyProduct::random(64, 8, 8, 0.1, &mut rng);
        let x = Matrix::randn(7, 64, 1.0, &mut rng);
        let want = bp.matmul(&x);
        let mut ws = Workspace::new();
        let mut y = x.clone();
        bp.apply_assign(&mut y, &mut ws);
        assert!(y.max_abs_diff(&want) < 1e-6);
        let warm = ws.alloc_events();
        y.data.copy_from_slice(&x.data);
        bp.apply_assign(&mut y, &mut ws);
        assert_eq!(ws.alloc_events(), warm);
    }

    #[test]
    fn flat_lowrank_backward_matches_dense_analytic_grads() {
        use crate::sparse::dense::{matmul_blocked, Matrix};
        let mut rng = Rng::new(40);
        let flr = FlatLowRank::random(64, 8, 4, 16, 0.5, &mut rng);
        let x = Matrix::randn(9, 64, 1.0, &mut rng);
        let dy = Matrix::randn(9, 64, 1.0, &mut rng);
        let mut dx = Matrix::zeros(9, 64);
        let mut g = FlatLowRankGrads::zeros_like(&flr);
        let mut ws = Workspace::new();
        flr.backward_into(&x, &dy, Some(&mut dx), &mut g, &mut ws);
        // dX = dY·Wᵀ with W the full dense composite
        let want_dx = matmul_blocked(&dy, &flr.to_dense().transpose());
        assert!(dx.max_abs_diff(&want_dx) < 1e-3, "{}", dx.max_abs_diff(&want_dx));
        // d_flat = (Xᵀ·dY) restricted to the stored pattern
        let dwd = matmul_blocked(&x.transpose(), &dy);
        let b = flr.flat.block;
        for i in 0..flr.flat.nbr {
            for s in flr.flat.row_ptr[i]..flr.flat.row_ptr[i + 1] {
                let j = flr.flat.cols[s];
                for r in 0..b {
                    for c in 0..b {
                        let got = g.d_flat[s * b * b + r * b + c];
                        let want = dwd.get(i * b + r, j * b + c);
                        assert!((got - want).abs() < 1e-3, "slot {s} ({r},{c})");
                    }
                }
            }
        }
        // dV = (X·U)ᵀ·dY and dU = Xᵀ·(dY·Vᵀ)
        let t = matmul_blocked(&x, &flr.u);
        let want_dv = matmul_blocked(&t.transpose(), &dy);
        assert!(g.dv.max_abs_diff(&want_dv) < 1e-3, "{}", g.dv.max_abs_diff(&want_dv));
        let dyv = matmul_blocked(&dy, &flr.v.transpose());
        let want_du = matmul_blocked(&x.transpose(), &dyv);
        assert!(g.du.max_abs_diff(&want_du) < 1e-3, "{}", g.du.max_abs_diff(&want_du));
        // steady state allocates nothing new
        let warm = ws.alloc_events();
        flr.backward_into(&x, &dy, Some(&mut dx), &mut g, &mut ws);
        assert_eq!(ws.alloc_events(), warm, "backward hot path must not allocate");
    }

    #[test]
    fn flat_lowrank_backward_rank_zero_is_pure_sparse() {
        use crate::sparse::dense::{matmul_blocked, Matrix};
        let mut rng = Rng::new(41);
        let flr = FlatLowRank::random(32, 4, 4, 0, 1.0, &mut rng);
        let dy = Matrix::randn(5, 32, 1.0, &mut rng);
        let x = Matrix::randn(5, 32, 1.0, &mut rng);
        let mut dx = Matrix::zeros(5, 32);
        let mut g = FlatLowRankGrads::zeros_like(&flr);
        let mut ws = Workspace::new();
        flr.backward_into(&x, &dy, Some(&mut dx), &mut g, &mut ws);
        let want = matmul_blocked(&dy, &flr.flat.to_dense().transpose());
        assert!(dx.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn product_backward_dx_matches_dense_chain() {
        use crate::sparse::dense::{matmul_blocked, Matrix};
        let mut rng = Rng::new(42);
        let bp = ButterflyProduct::random(64, 8, 8, 0.1, &mut rng);
        let x = Matrix::randn(7, 64, 1.0, &mut rng);
        let dy = Matrix::randn(7, 64, 1.0, &mut rng);
        let mut dx = Matrix::zeros(7, 64);
        let mut grads = bp.grad_buffers();
        let mut ws = Workspace::new();
        bp.backward_into(&x, &dy, &mut dx, &mut grads, &mut ws);
        // dense chain: y = x·(I+λB_k)···(I+λB_2), so dX = dY·Mᵀ with M
        // the product in application order
        let n = 64;
        let mut mprod = Matrix::zeros(n, n);
        for i in 0..n {
            mprod.set(i, i, 1.0);
        }
        for f in bp.factors.iter().rev() {
            let mut step = Matrix::zeros(n, n);
            for i in 0..n {
                step.set(i, i, 1.0);
            }
            let fd = f.to_dense();
            for (sv, fv) in step.data.iter_mut().zip(&fd.data) {
                *sv += bp.lam * fv;
            }
            mprod = matmul_blocked(&mprod, &step);
        }
        let want_dx = matmul_blocked(&dy, &mprod.transpose());
        assert!(dx.max_abs_diff(&want_dx) < 1e-3, "{}", dx.max_abs_diff(&want_dx));
    }

    #[test]
    fn product_backward_factor_grads_match_finite_differences() {
        use crate::sparse::dense::Matrix;
        let mut rng = Rng::new(43);
        let mut bp = ButterflyProduct::random(32, 4, 4, 0.1, &mut rng);
        let x = Matrix::randn(4, 32, 0.5, &mut rng);
        let cot = Matrix::randn(4, 32, 0.5, &mut rng); // fixed cotangent
        let loss = |bp: &ButterflyProduct| -> f64 {
            let y = bp.matmul(&x);
            y.data.iter().zip(&cot.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let mut dx = Matrix::zeros(4, 32);
        let mut grads = bp.grad_buffers();
        let mut ws = Workspace::new();
        bp.backward_into(&x, &cot, &mut dx, &mut grads, &mut ws);
        // probe a few stored entries of each factor with centered
        // differences (the map is linear in each entry, so eps is benign)
        let eps = 1e-2f32;
        for fi in 0..bp.factors.len() {
            for &e in &[0usize, 7, bp.factors[fi].blocks.len() - 1] {
                let orig = bp.factors[fi].blocks[e];
                bp.factors[fi].blocks[e] = orig + eps;
                let lp = loss(&bp);
                bp.factors[fi].blocks[e] = orig - eps;
                let lm = loss(&bp);
                bp.factors[fi].blocks[e] = orig;
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grads[fi][e];
                assert!((fd - an).abs() < 1e-2 * (1.0 + an.abs()),
                        "factor {fi} entry {e}: fd {fd} vs analytic {an}");
            }
        }
    }

    #[test]
    fn product_with_zero_lambda_is_identity() {
        let mut rng = Rng::new(35);
        let bp = ButterflyProduct::random(32, 4, 4, 0.0, &mut rng);
        let x = Matrix::randn(8, 32, 1.0, &mut rng);
        assert!(bp.matmul(&x).max_abs_diff(&x) < 1e-7);
    }
}
