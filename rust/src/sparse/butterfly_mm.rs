//! Sequential butterfly product vs flat butterfly multiply (Fig 11 / App J)
//! on the Rust substrate.
//!
//! The product form applies log2(k) residual factor multiplies
//! y <- y + λ (y · B_s), each a full pass over the activations; the flat
//! form is ONE BSR multiply with the union pattern.  Same O(n log k)
//! FLOPs — the measured gap is pure scheduling/memory-traffic, which is
//! the paper's point.

use crate::patterns::butterfly::{butterfly_factor_mask, flat_butterfly_mask};
use crate::sparse::bsr::BsrMatrix;
use crate::sparse::dense::Matrix;
use crate::util::Rng;

/// The residual-product operator (I + λB_2)…(I + λB_k) stored as factors.
pub struct ButterflyProduct {
    pub factors: Vec<BsrMatrix>, // lowest stride first
    pub lam: f32,
    pub block: usize,
}

impl ButterflyProduct {
    pub fn random(n: usize, block: usize, max_stride: usize, lam: f32,
                  rng: &mut Rng) -> Self {
        assert_eq!(n % block, 0);
        let nb = n / block;
        let mut factors = Vec::new();
        let mut s = 2;
        while s <= max_stride {
            let mask = butterfly_factor_mask(nb, s);
            factors.push(BsrMatrix::random(&mask, block, 1.0 / (2.0 * block as f32).sqrt(), rng));
            s *= 2;
        }
        ButterflyProduct { factors, lam, block }
    }

    /// y = x (I + λB_k) … (I + λB_2): apply highest stride first
    /// (row-vector convention matching kernels/ref.py).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let mut y = x.clone();
        let mut scratch = Matrix::zeros(x.rows, x.cols);
        for f in self.factors.iter().rev() {
            f.matmul_into(&y, &mut scratch);
            for (yv, sv) in y.data.iter_mut().zip(&scratch.data) {
                *yv += self.lam * sv;
            }
        }
        y
    }

    /// The flat first-order approximation: I + λ Σ B_s as one BSR matrix.
    pub fn flatten(&self) -> BsrMatrix {
        let nb = self.factors[0].nbr;
        let b = self.block;
        let max_stride = 1usize << self.factors.len();
        let mask = flat_butterfly_mask(nb, max_stride);
        let mut dense = Matrix::zeros(nb * b, nb * b);
        for i in 0..nb * b {
            dense.set(i, i, 1.0);
        }
        for f in &self.factors {
            let fd = f.to_dense();
            for (d, s) in dense.data.iter_mut().zip(&fd.data) {
                *d += self.lam * s;
            }
        }
        BsrMatrix::from_dense(&dense, &mask, b)
    }
}

/// Frobenius distance between the product operator and its flat
/// approximation applied to x (Theorem 4.3 empirically, on the substrate).
pub fn flat_approximation_error(bp: &ButterflyProduct, x: &Matrix) -> f64 {
    let exact = bp.matmul(x);
    let flat = bp.flatten().matmul(x);
    let mut err = 0.0f64;
    let mut base = 0.0f64;
    for (a, b) in exact.data.iter().zip(&flat.data) {
        err += ((a - b) as f64).powi(2);
        base += (*a as f64).powi(2);
    }
    (err / base.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_support_is_flat_mask() {
        let mut rng = Rng::new(31);
        let bp = ButterflyProduct::random(64, 8, 8, 0.1, &mut rng);
        let flat = bp.flatten();
        let mask = flat_butterfly_mask(8, 8);
        assert_eq!(flat.nnz_blocks(), mask.nnz());
    }

    #[test]
    fn small_lambda_flat_approximates_product() {
        let mut rng = Rng::new(32);
        let bp = ButterflyProduct::random(64, 8, 8, 0.01, &mut rng);
        let x = Matrix::randn(16, 64, 1.0, &mut rng);
        let rel = flat_approximation_error(&bp, &x);
        assert!(rel < 0.01, "relative error {rel}");
    }

    #[test]
    fn error_quadratic_in_lambda() {
        let mut rng = Rng::new(33);
        let mut bp = ButterflyProduct::random(64, 8, 8, 0.01, &mut rng);
        let x = Matrix::randn(16, 64, 1.0, &mut Rng::new(34));
        let e1 = flat_approximation_error(&bp, &x);
        bp.lam = 0.02;
        let e2 = flat_approximation_error(&bp, &x);
        let ratio = e2 / e1.max(1e-30);
        assert!(ratio > 2.5 && ratio < 6.0, "expected ~4x, got {ratio}");
    }

    #[test]
    fn product_with_zero_lambda_is_identity() {
        let mut rng = Rng::new(35);
        let bp = ButterflyProduct::random(32, 4, 4, 0.0, &mut rng);
        let x = Matrix::randn(8, 32, 1.0, &mut rng);
        assert!(bp.matmul(&x).max_abs_diff(&x) < 1e-7);
    }
}
