//! Row-major f32 matrix + cache-blocked dense GEMM (substrate baseline).
//!
//! The parallel paths split their output into contiguous row panels and
//! run one task per panel on the shared engine pool
//! ([`crate::sparse::exec::pool::run_tasks`]) — the same scheduling
//! substrate as the BSR plans and the attention executors, so dense
//! baselines pay the same (resident, calibrated) dispatch cost and no
//! private spawn logic exists here.

use crate::sparse::exec::pool;
use crate::util::Rng;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Cache-blocked transpose (see [`transpose_into`]).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        transpose_into(&self.data, self.rows, self.cols, &mut t.data);
        t
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }
}

/// Cache-blocked transpose of a `rows × cols` row-major buffer into a
/// `cols × rows` row-major buffer. The naive strided loop touches a new
/// destination cache line on every element once `rows` exceeds a few
/// hundred; walking TB×TB tiles keeps one source tile and one destination
/// tile resident (32×32 f32 = 4 KB each), so both sides stream at cache-
/// line granularity. Shared with `BsrMatrix::transpose`, which runs it
/// per stored block.
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    const TB: usize = 32;
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), rows * cols);
    for r0 in (0..rows).step_by(TB) {
        let r1 = (r0 + TB).min(rows);
        for c0 in (0..cols).step_by(TB) {
            let c1 = (c0 + TB).min(cols);
            for r in r0..r1 {
                let srow = &src[r * cols..r * cols + c1];
                for c in c0..c1 {
                    dst[c * rows + r] = srow[c];
                }
            }
        }
    }
}

/// Naive triple-loop GEMM (oracle for tests; do not benchmark this).
pub fn matmul_naive(x: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(x.cols, w.rows);
    let mut y = Matrix::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        for k in 0..x.cols {
            let xv = x.get(i, k);
            if xv != 0.0 {
                let wrow = w.row(k);
                let yrow = y.row_mut(i);
                for j in 0..w.cols {
                    yrow[j] += xv * wrow[j];
                }
            }
        }
    }
    y
}

/// Cache-blocked GEMM: i-k-j loop order with k-panel blocking; the dense
/// baseline for the Table 7 / Fig 11 latency comparisons.
pub fn matmul_blocked(x: &Matrix, w: &Matrix) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.cols);
    matmul_blocked_into(x, w, &mut y);
    y
}

/// Parallel panel-tiled dense GEMM: the batch dimension is split into row
/// panels (one pool task per panel, each owning a contiguous `y` slice,
/// so the parallelism is race-free by construction) and each panel runs
/// the k-blocked serial kernel. Falls back to the serial path when the
/// problem is too small to amortise a dispatch (calibrated cutover).
pub fn matmul_blocked_into(x: &Matrix, w: &Matrix, y: &mut Matrix) {
    assert_eq!(x.cols, w.rows);
    assert_eq!((y.rows, y.cols), (x.rows, w.cols));
    let (m, k, n) = (x.rows, x.cols, w.cols);
    let threads = crate::sparse::exec::threads();
    let flops = 2.0 * (m * k) as f64 * n as f64;
    if threads <= 1 || m < 2 || flops < crate::sparse::exec::par_threshold_flops() {
        return matmul_blocked_serial_into(x, w, y);
    }
    y.data.fill(0.0);
    let rows_per = m.div_ceil(threads.min(m));
    let n_panels = m.div_ceil(rows_per);
    let ybase = pool::SyncPtr(y.data.as_mut_ptr());
    pool::run_tasks(n_panels, threads, |p| {
        let ybase = &ybase;
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        // Safety: panels partition the batch rows, so this task
        // exclusively owns y rows r0..r0+rows; r0 + rows <= m keeps the
        // slice in bounds of the shape-asserted output.
        let ychunk = unsafe {
            std::slice::from_raw_parts_mut(ybase.0.add(r0 * n), rows * n)
        };
        panel_kernel(x, w, ychunk, r0);
    });
}

/// Single-threaded k-blocked reference kernel (the pre-engine path).
pub fn matmul_blocked_serial_into(x: &Matrix, w: &Matrix, y: &mut Matrix) {
    assert_eq!(x.cols, w.rows);
    assert_eq!((y.rows, y.cols), (x.rows, w.cols));
    y.data.fill(0.0);
    panel_kernel(x, w, &mut y.data, 0);
}

/// k-blocked GEMM over one row panel: `ychunk` holds rows
/// `r0..r0 + ychunk.len()/n` of the (pre-zeroed) output.
fn panel_kernel(x: &Matrix, w: &Matrix, ychunk: &mut [f32], r0: usize) {
    const KB: usize = 64;
    let (k, n) = (x.cols, w.cols);
    if n == 0 {
        return;
    }
    let rows = ychunk.len() / n;
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..rows {
            let xrow = x.row(r0 + i);
            let yrow = &mut ychunk[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let xv = xrow[kk];
                if xv == 0.0 {
                    continue;
                }
                let wrow = w.row(kk);
                // inner j loop vectorises
                for (yj, wj) in yrow.iter_mut().zip(wrow) {
                    *yj += xv * *wj;
                }
            }
        }
    }
}

/// `y = a · bᵀ` without materialising `bᵀ`: `y[i, j] = dot(a_i, b_j)` —
/// both operands stream row-major, the transpose is purely algorithmic.
/// Parallel over row panels of `y` on the shared pool above the
/// calibrated cutover; [`matmul_abt_serial_into`] is the oracle.
pub fn matmul_abt_into(a: &Matrix, b: &Matrix, y: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((y.rows, y.cols), (a.rows, b.rows));
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let threads = crate::sparse::exec::threads();
    let flops = 2.0 * (m * k) as f64 * n as f64;
    if threads <= 1 || m < 2 || flops < crate::sparse::exec::par_threshold_flops() {
        return matmul_abt_serial_into(a, b, y);
    }
    let rows_per = m.div_ceil(threads.min(m));
    let n_panels = m.div_ceil(rows_per);
    let tier = crate::sparse::exec::simd::active_tier();
    let ybase = pool::SyncPtr(y.data.as_mut_ptr());
    pool::run_tasks(n_panels, threads, |p| {
        let ybase = &ybase;
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        // Safety: panels partition a's rows, so this task exclusively
        // owns y rows r0..r0+rows; r0 + rows <= m bounds the slice.
        let ychunk = unsafe {
            std::slice::from_raw_parts_mut(ybase.0.add(r0 * n), rows * n)
        };
        abt_panel(tier, a, b, ychunk, r0);
    });
}

/// Single-threaded reference for [`matmul_abt_into`].
pub fn matmul_abt_serial_into(a: &Matrix, b: &Matrix, y: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((y.rows, y.cols), (a.rows, b.rows));
    let tier = crate::sparse::exec::simd::active_tier();
    abt_panel(tier, a, b, &mut y.data, 0);
}

fn abt_panel(tier: crate::sparse::exec::simd::Tier, a: &Matrix, b: &Matrix,
             ychunk: &mut [f32], r0: usize) {
    let n = b.rows;
    if n == 0 {
        return;
    }
    let rows = ychunk.len() / n;
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let yrow = &mut ychunk[i * n..(i + 1) * n];
        for (j, yv) in yrow.iter_mut().enumerate() {
            *yv = crate::sparse::exec::simd::dot_with(tier, arow, b.row(j));
        }
    }
}

/// `y = aᵀ · b` without materialising `aᵀ`: accumulated as rank-1 updates
/// `y[k, :] += a[i, k] · b[i, :]` so both operands stream row-major.
/// Parallel over row ranges of `y` (= column ranges of `a`): each task
/// sweeps all of `a`/`b` but writes only its own `y` rows, race-free by
/// construction. [`matmul_atb_serial_into`] is the oracle.
pub fn matmul_atb_into(a: &Matrix, b: &Matrix, y: &mut Matrix) {
    assert_eq!(a.rows, b.rows);
    assert_eq!((y.rows, y.cols), (a.cols, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let threads = crate::sparse::exec::threads();
    let flops = 2.0 * (m * k) as f64 * n as f64;
    if threads <= 1 || k < 2 || flops < crate::sparse::exec::par_threshold_flops() {
        return matmul_atb_serial_into(a, b, y);
    }
    let rows_per = k.div_ceil(threads.min(k));
    let n_panels = k.div_ceil(rows_per);
    let tier = crate::sparse::exec::simd::active_tier();
    let ybase = pool::SyncPtr(y.data.as_mut_ptr());
    pool::run_tasks(n_panels, threads, |p| {
        let ybase = &ybase;
        let k0 = p * rows_per;
        let rows = rows_per.min(k - k0);
        // Safety: panels partition y's rows (= a's columns), so this
        // task exclusively owns y rows k0..k0+rows; k0 + rows <= k
        // bounds the slice.
        let ychunk = unsafe {
            std::slice::from_raw_parts_mut(ybase.0.add(k0 * n), rows * n)
        };
        atb_panel(tier, a, b, ychunk, k0);
    });
}

/// Single-threaded reference for [`matmul_atb_into`].
pub fn matmul_atb_serial_into(a: &Matrix, b: &Matrix, y: &mut Matrix) {
    assert_eq!(a.rows, b.rows);
    assert_eq!((y.rows, y.cols), (a.cols, b.cols));
    let tier = crate::sparse::exec::simd::active_tier();
    atb_panel(tier, a, b, &mut y.data, 0);
}

/// Accumulate rows `k0..k0 + ychunk.len()/n` of `aᵀ·b` into `ychunk`.
fn atb_panel(tier: crate::sparse::exec::simd::Tier, a: &Matrix, b: &Matrix,
             ychunk: &mut [f32], k0: usize) {
    let n = b.cols;
    if n == 0 {
        return;
    }
    ychunk.fill(0.0);
    let krows = ychunk.len() / n;
    for i in 0..a.rows {
        let arow = a.row(i);
        let brow = b.row(i);
        for kk in 0..krows {
            let av = arow[k0 + kk];
            if av != 0.0 {
                crate::sparse::exec::simd::axpy_with(
                    tier,
                    av,
                    brow,
                    &mut ychunk[kk * n..(kk + 1) * n],
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(11);
        let x = Matrix::randn(17, 33, 1.0, &mut rng);
        let w = Matrix::randn(33, 29, 1.0, &mut rng);
        let a = matmul_naive(&x, &w);
        let b = matmul_blocked(&x, &w);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn identity_matmul() {
        let mut rng = Rng::new(12);
        let x = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut eye = Matrix::zeros(8, 8);
        for i in 0..8 {
            eye.set(i, i, 1.0);
        }
        let y = matmul_blocked(&x, &eye);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn parallel_panels_match_serial() {
        // big enough to clear the parallel threshold
        let mut rng = Rng::new(14);
        let x = Matrix::randn(258, 128, 1.0, &mut rng);
        let w = Matrix::randn(128, 160, 1.0, &mut rng);
        let mut par = Matrix::zeros(258, 160);
        matmul_blocked_into(&x, &w, &mut par);
        let mut ser = Matrix::zeros(258, 160);
        matmul_blocked_serial_into(&x, &w, &mut ser);
        assert!(par.max_abs_diff(&ser) < 1e-4);
    }

    #[test]
    fn abt_matches_explicit_transpose() {
        let mut rng = Rng::new(16);
        // small (serial) and large (parallel path) shapes
        for (m, k, n) in [(5usize, 9usize, 7usize), (200, 128, 160)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let want = matmul_blocked(&a, &b.transpose());
            let mut y = Matrix::zeros(m, n);
            matmul_abt_into(&a, &b, &mut y);
            assert!(y.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}: {}", y.max_abs_diff(&want));
            let mut ys = Matrix::zeros(m, n);
            matmul_abt_serial_into(&a, &b, &mut ys);
            assert!(ys.max_abs_diff(&want) < 1e-3, "serial {m}x{k}x{n}");
        }
    }

    #[test]
    fn atb_matches_explicit_transpose() {
        let mut rng = Rng::new(17);
        for (m, k, n) in [(6usize, 8usize, 10usize), (180, 128, 144)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(m, n, 1.0, &mut rng);
            let want = matmul_blocked(&a.transpose(), &b);
            let mut y = Matrix::zeros(k, n);
            matmul_atb_into(&a, &b, &mut y);
            assert!(y.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}: {}", y.max_abs_diff(&want));
            let mut ys = Matrix::zeros(k, n);
            matmul_atb_serial_into(&a, &b, &mut ys);
            assert!(ys.max_abs_diff(&want) < 1e-3, "serial {m}x{k}x{n}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(13);
        let x = Matrix::randn(5, 9, 1.0, &mut rng);
        assert_eq!(x.transpose().transpose(), x);
    }

    /// Naive strided transpose (the pre-tiling implementation), kept as
    /// the parity oracle for the cache-blocked kernel.
    fn transpose_naive(m: &Matrix) -> Matrix {
        let mut t = Matrix::zeros(m.cols, m.rows);
        for r in 0..m.rows {
            for c in 0..m.cols {
                t.set(c, r, m.get(r, c));
            }
        }
        t
    }

    #[test]
    fn blocked_transpose_matches_naive() {
        let mut rng = Rng::new(15);
        // exercise exact-tile, sub-tile and ragged-remainder shapes
        for (rows, cols) in [(1usize, 1usize), (1, 7), (7, 1), (32, 32),
                             (33, 31), (65, 33), (30, 100), (128, 96)] {
            let x = Matrix::randn(rows, cols, 1.0, &mut rng);
            assert_eq!(x.transpose(), transpose_naive(&x), "{rows}x{cols}");
        }
    }
}
