//! Linear building blocks: block-sparse engine layer, dense baseline
//! twin, and the [`Linear`] enum giving both one API.
//!
//! Moved here from `coordinator::trainer` when the [`Module`]
//! trait landed (PR 4): the layers now own their pre-activation stash, so
//! a chain driver no longer micromanages aux buffers — it hands the
//! module its input and output back at backward time and the module does
//! the rest.

use crate::patterns::BlockMask;
use crate::sparse::bsr::BsrMatrix;
use crate::sparse::dense::{self, Matrix};
use crate::sparse::exec::{self, Activation, Epilogue, Workspace};
use crate::util::Rng;

use crate::ckpt::{csr_index_tensor, CkptError, StateItem, StateSource};

use super::{ensure_shape, state_name, Module, PhaseFlops};

/// Block-sparse linear layer with a fused bias+activation epilogue and a
/// pattern-frozen gradient: weights, gradient and momentum all live on
/// the stored-block layout, so no phase of training ever densifies.
pub struct SparseLinear {
    pub w: BsrMatrix,
    pub bias: Vec<f32>,
    pub act: Activation,
    dw: Vec<f32>,
    db: Vec<f32>,
    mw: Vec<f32>,
    mb: Vec<f32>,
    /// stashed pre-activation (GELU only), lazily sized on first forward
    pre: Option<Matrix>,
}

impl SparseLinear {
    pub fn random(mask: &BlockMask, block: usize, act: Activation, scale: f32,
                  rng: &mut Rng) -> Self {
        Self::from_parts(BsrMatrix::random(mask, block, scale, rng), act)
    }

    /// Wrap an existing BSR weight matrix (zero bias) as a trainable layer.
    pub fn from_parts(w: BsrMatrix, act: Activation) -> Self {
        let n_out = w.cols_elems();
        let n_blk = w.blocks.len();
        SparseLinear {
            w,
            bias: vec![0.0; n_out],
            act,
            dw: vec![0.0; n_blk],
            db: vec![0.0; n_out],
            mw: vec![0.0; n_blk],
            mb: vec![0.0; n_out],
            pre: None,
        }
    }
}

impl Module for SparseLinear {
    fn in_dim(&self) -> usize {
        self.w.rows()
    }

    fn out_dim(&self) -> usize {
        self.w.cols_elems()
    }

    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix, _ws: &mut Workspace) {
        if self.act.needs_pre() {
            let pre = self.pre.get_or_insert_with(|| Matrix::zeros(0, 0));
            ensure_shape(pre, x.rows, self.w.cols_elems());
        }
        self.w.matmul_fused_into(
            x,
            y,
            &Epilogue { bias: Some(&self.bias), act: self.act },
            self.pre.as_mut(),
        );
    }

    /// `dy` arrives as dL/d(output) and leaves as dL/d(pre-activation)
    /// (the epilogue backward runs in place, folding the bias gradient
    /// into the same sweep); the aux the activation derivative needs is
    /// the caller-returned output `y` (ReLU) or the stashed
    /// pre-activation (GELU), per [`Activation::pick_aux`].
    fn backward_into(&mut self, x: &Matrix, y: &Matrix, dy: &mut Matrix,
                     dx: Option<&mut Matrix>, _ws: &mut Workspace) {
        self.db.fill(0.0);
        let aux = self.act.pick_aux(y, self.pre.as_ref());
        exec::epilogue_backward(dy, aux, self.act, Some(&mut self.db));
        self.w.matmul_dw_into(x, dy, &mut self.dw);
        if let Some(dx) = dx {
            self.w.matmul_dx_into(dy, dx);
        }
    }

    /// Critical path only: epilogue transform (+db) and the dX GEMM.
    /// dW runs in [`Module::backward_dw`] against the same transformed
    /// `dy`; dX and dW both only READ `dy`, so splitting the fused
    /// sweep reorders nothing a float ever sees — bit-identical.
    fn backward_dx(&mut self, _x: &Matrix, y: &Matrix, dy: &mut Matrix,
                   dx: Option<&mut Matrix>, _ws: &mut Workspace) {
        self.db.fill(0.0);
        let aux = self.act.pick_aux(y, self.pre.as_ref());
        exec::epilogue_backward(dy, aux, self.act, Some(&mut self.db));
        if let Some(dx) = dx {
            self.w.matmul_dx_into(dy, dx);
        }
    }

    fn backward_dw(&mut self, x: &Matrix, dy: &Matrix, _ws: &mut Workspace) {
        self.w.matmul_dw_into(x, dy, &mut self.dw);
    }

    fn update(&mut self, lr: f32, momentum: f32) {
        exec::sgd_momentum(&mut self.w.blocks, &self.dw, &mut self.mw, lr, momentum);
        exec::sgd_momentum(&mut self.bias, &self.db, &mut self.mb, lr, momentum);
        // keep the engaged bf16 shadow in sync with the f32 master
        // (no-op — not even a branch per element — when the tier is off)
        self.w.repack_bf16();
    }

    fn param_count(&self) -> usize {
        self.w.blocks.len() + self.bias.len()
    }

    fn apply_precision(&mut self, p: exec::Precision) {
        match p {
            exec::Precision::Bf16 => self.w.refresh_bf16(),
            exec::Precision::Int8 => self.w.quantize_int8(),
            exec::Precision::F32 => self.w.drop_precision_shadows(),
        }
    }

    fn flops(&self, rows: usize) -> PhaseFlops {
        let fwd = 2.0 * (rows * self.w.nnz_blocks()) as f64
            * (self.w.block * self.w.block) as f64;
        PhaseFlops { fwd, bwd: 2.0 * fwd, update: 4.0 * self.param_count() as f64 }
    }

    fn shed_training_state(&mut self) {
        self.dw = Vec::new();
        self.db = Vec::new();
        self.mw = Vec::new();
        self.mb = Vec::new();
    }

    fn training_state_bytes(&self) -> usize {
        4 * (self.dw.capacity() + self.db.capacity() + self.mw.capacity()
             + self.mb.capacity())
    }

    fn state_tensors(&self, prefix: &str, visit: &mut dyn FnMut(&str, StateItem)) {
        // structure first: the loader verifies the sparsity plan before
        // any weight of this layer is touched
        visit(&state_name(prefix, "w.csr"), StateItem::U32(csr_index_tensor(&self.w)));
        visit(&state_name(prefix, "w"), StateItem::F32(&self.w.blocks));
        visit(&state_name(prefix, "b"), StateItem::F32(&self.bias));
        visit(&state_name(prefix, "mw"), StateItem::F32(&self.mw));
        visit(&state_name(prefix, "mb"), StateItem::F32(&self.mb));
    }

    fn load_state(&mut self, prefix: &str, src: &mut dyn StateSource)
                  -> Result<(), CkptError> {
        src.expect_u32(&state_name(prefix, "w.csr"), &csr_index_tensor(&self.w))?;
        src.load_f32(&state_name(prefix, "w"), &mut self.w.blocks)?;
        src.load_f32(&state_name(prefix, "b"), &mut self.bias)?;
        src.load_f32(&state_name(prefix, "mw"), &mut self.mw)?;
        src.load_f32(&state_name(prefix, "mb"), &mut self.mb)?;
        // an engaged bf16 shadow must track the freshly loaded master
        self.w.repack_bf16();
        Ok(())
    }

    fn visit_train_f32(&mut self, which: super::TrainTensors,
                       visit: &mut dyn FnMut(&mut [f32])) {
        match which {
            super::TrainTensors::Grads => {
                visit(&mut self.dw);
                visit(&mut self.db);
            }
            super::TrainTensors::Params => {
                visit(&mut self.w.blocks);
                visit(&mut self.bias);
                visit(&mut self.mw);
                visit(&mut self.mb);
            }
        }
    }
}

/// Dense twin of [`SparseLinear`] — the baseline the fig1 bench compares
/// against. Same API; unfused epilogue (dense GEMM + a separate bias/act
/// pass), backward through the transpose-free `A·Bᵀ` / `Aᵀ·B` kernels.
pub struct DenseLinear {
    /// `[in, out]`
    pub w: Matrix,
    pub bias: Vec<f32>,
    pub act: Activation,
    dw: Matrix,
    db: Vec<f32>,
    mw: Vec<f32>,
    mb: Vec<f32>,
    pre: Option<Matrix>,
}

impl DenseLinear {
    pub fn random(in_dim: usize, out_dim: usize, act: Activation, scale: f32,
                  rng: &mut Rng) -> Self {
        Self::from_parts(Matrix::randn(in_dim, out_dim, scale, rng),
                         vec![0.0; out_dim], act)
    }

    /// Build from explicit weights/bias (tests seed the dense twin with a
    /// sparse layer's materialised weights through this).
    pub fn from_parts(w: Matrix, bias: Vec<f32>, act: Activation) -> Self {
        assert_eq!(bias.len(), w.cols);
        let (in_dim, out_dim) = (w.rows, w.cols);
        DenseLinear {
            w,
            bias,
            act,
            dw: Matrix::zeros(in_dim, out_dim),
            db: vec![0.0; out_dim],
            mw: vec![0.0; in_dim * out_dim],
            mb: vec![0.0; out_dim],
            pre: None,
        }
    }
}

impl Module for DenseLinear {
    fn in_dim(&self) -> usize {
        self.w.rows
    }

    fn out_dim(&self) -> usize {
        self.w.cols
    }

    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix, _ws: &mut Workspace) {
        dense::matmul_blocked_into(x, &self.w, y);
        if self.act.needs_pre() {
            let pre = self.pre.get_or_insert_with(|| Matrix::zeros(0, 0));
            ensure_shape(pre, x.rows, y.cols);
        }
        // `pre` is Some exactly when the activation needs the stash
        super::apply_bias_act(y, self.pre.as_mut(), &self.bias, self.act);
    }

    fn backward_into(&mut self, x: &Matrix, y: &Matrix, dy: &mut Matrix,
                     dx: Option<&mut Matrix>, _ws: &mut Workspace) {
        self.db.fill(0.0);
        let aux = self.act.pick_aux(y, self.pre.as_ref());
        exec::epilogue_backward(dy, aux, self.act, Some(&mut self.db));
        dense::matmul_atb_into(x, dy, &mut self.dw);
        if let Some(dx) = dx {
            dense::matmul_abt_into(dy, &self.w, dx);
        }
    }

    fn backward_dx(&mut self, _x: &Matrix, y: &Matrix, dy: &mut Matrix,
                   dx: Option<&mut Matrix>, _ws: &mut Workspace) {
        self.db.fill(0.0);
        let aux = self.act.pick_aux(y, self.pre.as_ref());
        exec::epilogue_backward(dy, aux, self.act, Some(&mut self.db));
        if let Some(dx) = dx {
            dense::matmul_abt_into(dy, &self.w, dx);
        }
    }

    fn backward_dw(&mut self, x: &Matrix, dy: &Matrix, _ws: &mut Workspace) {
        dense::matmul_atb_into(x, dy, &mut self.dw);
    }

    fn update(&mut self, lr: f32, momentum: f32) {
        exec::sgd_momentum(&mut self.w.data, &self.dw.data, &mut self.mw, lr, momentum);
        exec::sgd_momentum(&mut self.bias, &self.db, &mut self.mb, lr, momentum);
    }

    fn param_count(&self) -> usize {
        self.w.data.len() + self.bias.len()
    }

    fn flops(&self, rows: usize) -> PhaseFlops {
        let fwd = 2.0 * (rows * self.w.rows) as f64 * self.w.cols as f64;
        PhaseFlops { fwd, bwd: 2.0 * fwd, update: 4.0 * self.param_count() as f64 }
    }

    fn shed_training_state(&mut self) {
        self.dw = Matrix::zeros(0, 0);
        self.db = Vec::new();
        self.mw = Vec::new();
        self.mb = Vec::new();
    }

    fn training_state_bytes(&self) -> usize {
        4 * (self.dw.data.capacity() + self.db.capacity() + self.mw.capacity()
             + self.mb.capacity())
    }

    fn state_tensors(&self, prefix: &str, visit: &mut dyn FnMut(&str, StateItem)) {
        visit(&state_name(prefix, "w"), StateItem::F32(&self.w.data));
        visit(&state_name(prefix, "b"), StateItem::F32(&self.bias));
        visit(&state_name(prefix, "mw"), StateItem::F32(&self.mw));
        visit(&state_name(prefix, "mb"), StateItem::F32(&self.mb));
    }

    fn load_state(&mut self, prefix: &str, src: &mut dyn StateSource)
                  -> Result<(), CkptError> {
        src.load_f32(&state_name(prefix, "w"), &mut self.w.data)?;
        src.load_f32(&state_name(prefix, "b"), &mut self.bias)?;
        src.load_f32(&state_name(prefix, "mw"), &mut self.mw)?;
        src.load_f32(&state_name(prefix, "mb"), &mut self.mb)?;
        Ok(())
    }

    fn visit_train_f32(&mut self, which: super::TrainTensors,
                       visit: &mut dyn FnMut(&mut [f32])) {
        match which {
            super::TrainTensors::Grads => {
                visit(&mut self.dw.data);
                visit(&mut self.db);
            }
            super::TrainTensors::Params => {
                visit(&mut self.w.data);
                visit(&mut self.bias);
                visit(&mut self.mw);
                visit(&mut self.mb);
            }
        }
    }
}

/// A linear layer of the substrate — sparse engine path or dense
/// baseline, one API.
pub enum Linear {
    Sparse(SparseLinear),
    Dense(DenseLinear),
}

impl Linear {
    pub fn in_dim(&self) -> usize {
        match self {
            Linear::Sparse(l) => l.w.rows(),
            Linear::Dense(l) => l.w.rows,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            Linear::Sparse(l) => l.w.cols_elems(),
            Linear::Dense(l) => l.w.cols,
        }
    }

    pub fn act(&self) -> Activation {
        match self {
            Linear::Sparse(l) => l.act,
            Linear::Dense(l) => l.act,
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            Linear::Sparse(l) => Module::param_count(l),
            Linear::Dense(l) => Module::param_count(l),
        }
    }

    /// Multiply flops of one forward pass over `m` batch rows (the
    /// epilogue's O(m·n) is noise next to it and left out on both paths).
    pub fn fwd_flops(&self, m: usize) -> f64 {
        match self {
            Linear::Sparse(l) => l.flops(m).fwd,
            Linear::Dense(l) => l.flops(m).fwd,
        }
    }

    /// Backward flops: dX and dW each cost one forward's worth.
    pub fn bwd_flops(&self, m: usize) -> f64 {
        2.0 * self.fwd_flops(m)
    }

    /// Optimizer flops: two FMAs per parameter.
    pub fn update_flops(&self) -> f64 {
        4.0 * self.param_count() as f64
    }
}

impl Module for Linear {
    fn in_dim(&self) -> usize {
        Linear::in_dim(self)
    }

    fn out_dim(&self) -> usize {
        Linear::out_dim(self)
    }

    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix, ws: &mut Workspace) {
        match self {
            Linear::Sparse(l) => l.forward_into(x, y, ws),
            Linear::Dense(l) => l.forward_into(x, y, ws),
        }
    }

    fn backward_into(&mut self, x: &Matrix, y: &Matrix, dy: &mut Matrix,
                     dx: Option<&mut Matrix>, ws: &mut Workspace) {
        match self {
            Linear::Sparse(l) => l.backward_into(x, y, dy, dx, ws),
            Linear::Dense(l) => l.backward_into(x, y, dy, dx, ws),
        }
    }

    fn backward_dx(&mut self, x: &Matrix, y: &Matrix, dy: &mut Matrix,
                   dx: Option<&mut Matrix>, ws: &mut Workspace) {
        match self {
            Linear::Sparse(l) => l.backward_dx(x, y, dy, dx, ws),
            Linear::Dense(l) => l.backward_dx(x, y, dy, dx, ws),
        }
    }

    fn backward_dw(&mut self, x: &Matrix, dy: &Matrix, ws: &mut Workspace) {
        match self {
            Linear::Sparse(l) => l.backward_dw(x, dy, ws),
            Linear::Dense(l) => l.backward_dw(x, dy, ws),
        }
    }

    fn update(&mut self, lr: f32, momentum: f32) {
        match self {
            Linear::Sparse(l) => Module::update(l, lr, momentum),
            Linear::Dense(l) => Module::update(l, lr, momentum),
        }
    }

    fn param_count(&self) -> usize {
        Linear::param_count(self)
    }

    fn flops(&self, rows: usize) -> PhaseFlops {
        match self {
            Linear::Sparse(l) => l.flops(rows),
            Linear::Dense(l) => l.flops(rows),
        }
    }

    fn shed_training_state(&mut self) {
        match self {
            Linear::Sparse(l) => l.shed_training_state(),
            Linear::Dense(l) => l.shed_training_state(),
        }
    }

    fn apply_precision(&mut self, p: exec::Precision) {
        match self {
            Linear::Sparse(l) => l.apply_precision(p),
            Linear::Dense(l) => l.apply_precision(p),
        }
    }

    fn training_state_bytes(&self) -> usize {
        match self {
            Linear::Sparse(l) => l.training_state_bytes(),
            Linear::Dense(l) => l.training_state_bytes(),
        }
    }

    fn state_tensors(&self, prefix: &str, visit: &mut dyn FnMut(&str, StateItem)) {
        match self {
            Linear::Sparse(l) => l.state_tensors(prefix, visit),
            Linear::Dense(l) => l.state_tensors(prefix, visit),
        }
    }

    fn load_state(&mut self, prefix: &str, src: &mut dyn StateSource)
                  -> Result<(), CkptError> {
        match self {
            Linear::Sparse(l) => l.load_state(prefix, src),
            Linear::Dense(l) => l.load_state(prefix, src),
        }
    }

    fn visit_train_f32(&mut self, which: super::TrainTensors,
                       visit: &mut dyn FnMut(&mut [f32])) {
        match self {
            Linear::Sparse(l) => l.visit_train_f32(which, visit),
            Linear::Dense(l) => l.visit_train_f32(which, visit),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::baselines;

    #[test]
    fn sparse_and_dense_forward_agree_on_full_mask() {
        let mut rng = Rng::new(80);
        let (n, block, batch) = (32usize, 8usize, 5usize);
        let mask = BlockMask::ones(n / block, n / block);
        let mut s = SparseLinear::random(&mask, block, Activation::Gelu, 0.4, &mut rng);
        let mut d = DenseLinear::from_parts(s.w.to_dense(), s.bias.clone(),
                                            Activation::Gelu);
        let x = Matrix::randn(batch, n, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut ys = Matrix::zeros(batch, n);
        let mut yd = Matrix::zeros(batch, n);
        s.forward_into(&x, &mut ys, &mut ws);
        d.forward_into(&x, &mut yd, &mut ws);
        assert!(ys.max_abs_diff(&yd) < 1e-4, "{}", ys.max_abs_diff(&yd));
    }

    #[test]
    fn module_backward_matches_dense_analytic_grads() {
        // identity activation: dX = dY·Wᵀ, and the module's dx must match
        // the dense transpose math (the engine's own serial oracles cover
        // the kernels; this pins the Module wiring on top)
        let mut rng = Rng::new(81);
        let (n, block, batch) = (32usize, 8usize, 6usize);
        let mask = baselines::random_mask(n / block, n / block, 0.6, &mut rng);
        let mut s = SparseLinear::random(&mask, block, Activation::Identity, 0.4,
                                         &mut rng);
        let x = Matrix::randn(batch, n, 1.0, &mut rng);
        let dy0 = Matrix::randn(batch, n, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut y = Matrix::zeros(batch, n);
        s.forward_into(&x, &mut y, &mut ws);
        let mut dy = dy0.clone();
        let mut dx = Matrix::zeros(batch, n);
        s.backward_into(&x, &y, &mut dy, Some(&mut dx), &mut ws);
        let want = dense::matmul_blocked(&dy0, &s.w.to_dense().transpose());
        assert!(dx.max_abs_diff(&want) < 1e-4, "{}", dx.max_abs_diff(&want));
    }

    #[test]
    fn split_backward_bit_matches_fused_backward() {
        // overlap-scheduler contract: backward_dx + backward_dw must be
        // BIT-identical to one fused backward_into — dw/db/dx/dy all
        // compared on their u32 bit patterns
        fn bits(v: &[f32]) -> Vec<u32> {
            v.iter().map(|f| f.to_bits()).collect()
        }
        fn grad_bits(m: &mut dyn Module) -> Vec<u32> {
            let mut out = Vec::new();
            m.visit_train_f32(super::super::TrainTensors::Grads,
                              &mut |s| out.extend(s.iter().map(|f| f.to_bits())));
            out
        }
        let (n, block, batch) = (32usize, 8usize, 6usize);
        let mut mrng = Rng::new(84);
        let mask = baselines::random_mask(n / block, n / block, 0.6, &mut mrng);
        let x = Matrix::randn(batch, n, 1.0, &mut mrng);
        let dy0 = Matrix::randn(batch, n, 1.0, &mut mrng);
        let mut ws = Workspace::new();
        for act in [Activation::Identity, Activation::Relu, Activation::Gelu] {
            // same seed twice → bit-identical twin layers
            let mut r1 = Rng::new(85);
            let mut r2 = Rng::new(85);
            let mut a = SparseLinear::random(&mask, block, act, 0.4, &mut r1);
            let mut b = SparseLinear::random(&mask, block, act, 0.4, &mut r2);
            let mut ya = Matrix::zeros(batch, n);
            let mut yb = Matrix::zeros(batch, n);
            a.forward_into(&x, &mut ya, &mut ws);
            b.forward_into(&x, &mut yb, &mut ws);
            let (mut dya, mut dyb) = (dy0.clone(), dy0.clone());
            let mut dxa = Matrix::zeros(batch, n);
            let mut dxb = Matrix::zeros(batch, n);
            a.backward_into(&x, &ya, &mut dya, Some(&mut dxa), &mut ws);
            b.backward_dx(&x, &yb, &mut dyb, Some(&mut dxb), &mut ws);
            b.backward_dw(&x, &dyb, &mut ws);
            assert_eq!(bits(&dya.data), bits(&dyb.data), "{}: dy", act.name());
            assert_eq!(bits(&dxa.data), bits(&dxb.data), "{}: dx", act.name());
            assert_eq!(grad_bits(&mut a), grad_bits(&mut b), "{}: grads", act.name());
        }
        // dense twin, same contract
        let mut r1 = Rng::new(86);
        let mut r2 = Rng::new(86);
        let mut a = DenseLinear::random(n, n, Activation::Gelu, 0.4, &mut r1);
        let mut b = DenseLinear::random(n, n, Activation::Gelu, 0.4, &mut r2);
        let mut ya = Matrix::zeros(batch, n);
        let mut yb = Matrix::zeros(batch, n);
        a.forward_into(&x, &mut ya, &mut ws);
        b.forward_into(&x, &mut yb, &mut ws);
        let (mut dya, mut dyb) = (dy0.clone(), dy0.clone());
        let mut dxa = Matrix::zeros(batch, n);
        let mut dxb = Matrix::zeros(batch, n);
        a.backward_into(&x, &ya, &mut dya, Some(&mut dxa), &mut ws);
        b.backward_dx(&x, &yb, &mut dyb, Some(&mut dxb), &mut ws);
        b.backward_dw(&x, &dyb, &mut ws);
        assert_eq!(bits(&dxa.data), bits(&dxb.data), "dense: dx");
        assert_eq!(grad_bits(&mut a), grad_bits(&mut b), "dense: grads");
    }

    #[test]
    fn gelu_pre_stash_is_module_owned() {
        // backward directly after forward must find its stash without the
        // caller threading any aux buffer through
        let mut rng = Rng::new(82);
        let mut d = DenseLinear::random(16, 16, Activation::Gelu, 0.4, &mut rng);
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut y = Matrix::zeros(3, 16);
        d.forward_into(&x, &mut y, &mut ws);
        let mut dy = Matrix::randn(3, 16, 1.0, &mut rng);
        let mut dx = Matrix::zeros(3, 16);
        d.backward_into(&x, &y, &mut dy, Some(&mut dx), &mut ws);
        assert!(dx.data.iter().all(|v| v.is_finite()));
    }
}
